"""Deterministic synthetic data (LM + captioning proxy).

Design goals:

  * deterministic per (seed, step, host) — restart/resume replays the exact
    same stream, which is what the fault-tolerance tests assert;
  * *learnable* — tokens follow a low-entropy first-order Markov chain so a
    ~100M model shows a clearly decreasing loss within a few hundred steps
    (examples/train_lm.py);
  * cheap — generation is pure numpy on the host, no file IO.

The captioning proxy pairs a "visual" embedding (random but deterministic
per image id) with a caption whose tokens are a noisy function of the image
id — enough structure for the co-inference quality benchmarks to show a
quantization-sensitive signal.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


def _chain(vocab: int, branching: int, seed: int) -> np.ndarray:
    """Transition table: each token can be followed by `branching` tokens."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(vocab, branching), dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class MarkovLMConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-host batch
    branching: int = 4         # successors per token (entropy = log2(b) bits)
    table_seed: int = 1234     # the "language" (fixed across hosts/steps)


class MarkovLMDataset:
    """Stateless batch generator: ``batch_at(step)`` is pure."""

    def __init__(self, cfg: MarkovLMConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.table = _chain(cfg.vocab_size, cfg.branching, cfg.table_seed)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        # unique stream per (step, host)
        rng = np.random.default_rng(
            (step * self.num_hosts + self.host_id) * 2654435761 % (2 ** 63))
        b, s = cfg.batch_size, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, cfg.branching, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self.table[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume-aware iterator (checkpoint stores the step)."""
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class CaptionProxyConfig:
    vocab_size: int
    seq_len: int               # caption length
    d_model: int               # visual embedding width
    n_vis: int                 # visual tokens per sample
    batch_size: int
    n_images: int = 4096       # distinct "images"
    table_seed: int = 77


class CaptionProxyDataset:
    """(visual embeds, caption tokens) pairs with a deterministic mapping.

    Caption token t of image i is ``caption_table[i, t]`` with 10% noise —
    a captioner must use the visual embedding, so output quality degrades
    measurably when the agent-side encoder is quantized too hard (this is
    the signal the Fig. 5-8 proxy benchmark sweeps).
    """

    def __init__(self, cfg: CaptionProxyConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        rng = np.random.default_rng(cfg.table_seed)
        self.captions = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_images, cfg.seq_len),
            dtype=np.int32)
        # visual embeddings: fixed random per image, unit-ish scale
        self.vis_basis = rng.normal(
            0, 1, size=(cfg.n_images, cfg.n_vis, cfg.d_model)
        ).astype(np.float32) / np.sqrt(cfg.d_model)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (step * self.num_hosts + self.host_id) * 1099511628211
            % (2 ** 63))
        ids = rng.integers(0, cfg.n_images, size=cfg.batch_size)
        caps = self.captions[ids].copy()
        noise = rng.random(caps.shape) < 0.1
        caps[noise] = rng.integers(0, cfg.vocab_size, size=int(noise.sum()))
        # teacher forcing: inputs are BOS-shifted so position t predicts
        # caption[t] from the *image* + caption[<t] (no identity shortcut)
        bos = np.zeros((cfg.batch_size, 1), np.int32)
        tokens = np.concatenate([bos, caps[:, :-1]], axis=1)
        return {"image_id": ids.astype(np.int32),
                "embeds": self.vis_basis[ids],
                "tokens": tokens,
                "labels": caps}

    def references(self, ids: np.ndarray) -> np.ndarray:
        """Ground-truth captions for CIDEr-style scoring."""
        return self.captions[ids]
