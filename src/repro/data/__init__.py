"""Deterministic synthetic data pipeline (LM + caption proxy) + loader."""

from .loader import ShardedLoader  # noqa: F401
from .synthetic import (CaptionProxyConfig, CaptionProxyDataset,  # noqa: F401
                        MarkovLMConfig, MarkovLMDataset)
