"""Sharded host loader: global batch -> per-host slice -> device arrays.

In a real multi-host pod each process feeds its addressable shard of the
globally-sharded batch (``jax.make_array_from_process_local_data``).  On the
single-process CPU container the same code path degrades to "one host owns
the whole batch" — the *interface* (global batch semantics, deterministic
step indexing, resume) is what the framework layers above depend on.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ShardedLoader:
    """Wraps a ``batch_at(step)`` dataset with device placement.

    ``sharding``: optional pytree (or single) ``NamedSharding`` for batches;
    when None, arrays land on the default device.
    """

    def __init__(self, dataset, sharding: Optional[Any] = None,
                 start_step: int = 0):
        self.dataset = dataset
        self.sharding = sharding
        self.step = start_step

    def peek_structure(self) -> Dict[str, Any]:
        b = self.dataset.batch_at(0)
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in b.items()}

    def _place(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self.sharding is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}

        def put(k, v):
            sh = (self.sharding[k] if isinstance(self.sharding, dict)
                  else self.sharding)
            return jax.device_put(v, sh)
        return {k: put(k, v) for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self.dataset.batch_at(self.step)
        self.step += 1
        return self._place(batch)

    def seek(self, step: int) -> None:
        """Resume point (used after checkpoint restore)."""
        self.step = step
