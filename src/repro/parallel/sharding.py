"""Logical-axis -> mesh-axis sharding rules (GSPMD/pjit).

Every model exposes a pytree of logical axis names mirroring its params
(see models/*.logical_axes) and its cache.  This module maps those to
``NamedSharding`` for a concrete mesh, with:

  * per-arch rule overrides (``ModelConfig.sharding_overrides`` is not a
    config field — overrides are passed explicitly to keep configs data-only);
  * a divisibility guard: a dim whose size does not divide the mapped mesh
    axes is replicated instead (e.g. granite's kv=1 head, jamba's 16 experts
    on a 16-way axis are fine, qwen2's 14 q-heads are not and fall back);
  * shape-dependent overrides (long_500k re-maps ``cache_seq`` to 'data').

Default physical mapping (DESIGN.md §5):

  batch       -> ('pod', 'data')     activations / cache batch
  heads/kv/ffn/vocab/experts -> 'model'   (tensor / expert parallelism)
  embed       -> 'data' iff cfg.fsdp (ZeRO-3-style weight sharding)
  cache_seq   -> None (decode_32k) or 'data' (long_500k)
  everything else -> replicated
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalRules = Dict[str, Any]  # logical name -> mesh axis | tuple | None

# ---------------------------------------------------------------------------
# Activation-sharding context (Megatron-style sequence parallelism)
# ---------------------------------------------------------------------------
# When set, models constrain the [B, S, D] residual stream at every layer
# boundary to this PartitionSpec — typically P(('pod','data'), 'model'),
# which shards the sequence axis over the TP group between attention/MLP
# blocks.  GSPMD inserts the all-gather before attention and the
# reduce-scatter after, and the activations *saved for backward* shrink by
# the TP degree.  This is what lets the >=200B configs fit (DESIGN.md §5).

_ACTIVATION_SPEC: Optional[P] = None


@contextlib.contextmanager
def activation_sharding(spec: Optional[P]):
    """Trace-time context: models constrain per-layer activations to spec."""
    global _ACTIVATION_SPEC
    prev = _ACTIVATION_SPEC
    _ACTIVATION_SPEC = spec
    try:
        yield
    finally:
        _ACTIVATION_SPEC = prev


def constrain_activations(x: jax.Array) -> jax.Array:
    """Apply the ambient activation spec (no-op outside the context or when
    the sharded dims do not divide)."""
    if _ACTIVATION_SPEC is None or x.ndim < 2:
        return x
    return jax.lax.with_sharding_constraint(x, _ACTIVATION_SPEC)


# ---------------------------------------------------------------------------
# Fused-attention (flash) mode
# ---------------------------------------------------------------------------
# When set to a Mesh, models route long-sequence attention through the
# fused-kernel accounting path (models/layers.fused_attention_acct): the
# whole online-softmax recurrence runs inside one shard_map'd callback, so
# the compiled HLO carries exactly the flash-kernel HBM interface (q, k, v
# -> out per shard) instead of the blockwise scan's score-block traffic.
# On TPU the same call site dispatches kernels/flash.py (pl.pallas_call).

_FLASH_MESH = None


@contextlib.contextmanager
def flash_attention_mode(mesh):
    global _FLASH_MESH
    prev = _FLASH_MESH
    _FLASH_MESH = mesh
    try:
        yield
    finally:
        _FLASH_MESH = prev


def flash_mesh():
    return _FLASH_MESH


def default_rules(cfg, *, long_context: bool = False) -> LogicalRules:
    rules: LogicalRules = {
        "batch": ("pod", "data"),
        "heads": "model",
        "kv": "model",
        "kv_heads": "model",
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "gates": "model",
        "cache_seq": "data" if long_context else None,
        "embed": "data" if cfg.fsdp else None,
    }
    return rules


def _physical_axes(rule, mesh: Mesh):
    """Normalize a rule entry to a tuple of axes present in the mesh."""
    if rule is None:
        return ()
    if isinstance(rule, str):
        rule = (rule,)
    return tuple(a for a in rule if a in mesh.axis_names)


def spec_for(axes: Tuple[str, ...], shape: Tuple[int, ...], rules: LogicalRules,
             mesh: Mesh) -> P:
    """PartitionSpec for one leaf, with divisibility fallback."""
    entries = []
    used: set = set()
    for dim, name in zip(shape, axes):
        phys = _physical_axes(rules.get(name), mesh)
        phys = tuple(a for a in phys if a not in used)
        size = int(np.prod([mesh.shape[a] for a in phys])) if phys else 1
        if phys and dim % size == 0:
            entries.append(phys if len(phys) > 1 else phys[0])
            used.update(phys)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(axes_tree, struct_tree, rules: LogicalRules, mesh: Mesh):
    """NamedSharding pytree matching ``struct_tree`` (arrays or SDS)."""
    def one(axes, struct):
        if not isinstance(axes, tuple):
            raise TypeError(f"expected axis tuple, got {axes!r}")
        if len(axes) != len(struct.shape):
            raise ValueError(
                f"axes {axes} rank != shape {struct.shape}")
        return NamedSharding(mesh, spec_for(axes, struct.shape, rules, mesh))

    return jax.tree_util.tree_map(
        one, axes_tree, struct_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) for e in x))


def batch_shardings(batch_specs: Dict[str, Any], rules: LogicalRules,
                    mesh: Mesh):
    """Shard every input leaf along its leading (batch) dimension."""
    def one(struct):
        axes = ("batch",) + (None,) * (len(struct.shape) - 1)
        entries = []
        phys = _physical_axes(rules.get("batch"), mesh)
        size = int(np.prod([mesh.shape[a] for a in phys])) if phys else 1
        if phys and struct.shape and struct.shape[0] % size == 0:
            entries.append(phys if len(phys) > 1 else phys[0])
        else:
            entries.append(None)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(one, batch_specs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
