"""Compiled fast-path serving (DESIGN.md §10).

The eager serving path dispatches the agent stage as a Python loop over
layers — ~6 independently-jitted quantized matmuls per layer plus unjitted
glue — then an eager uplink quantizer and an eager server stage.  On the
smoke-scale models that host dispatch, not compute, dominates wall clock.
This module turns the whole agent -> transport -> server forward into a
small, bounded set of XLA executables:

* :func:`restack_segments` regroups the engine's per-layer
  ``QuantizedLinear`` records into *layer-stacked* pytrees — one segment
  per run of consecutive layers sharing a kernel container (int4-packed /
  int8 / fp16-fake), so each segment scans over homogeneous leaves;
* :func:`quantized_block` is the per-layer decoder block and
  :func:`scan_segment` scans it over a segment — shared verbatim by the
  eager engine (``CoInferenceEngine._agent_forward_kernel``) and the
  compiled forward, so both execute identical XLA sub-computations;
* :func:`transport_quantize` moves the per-row absmax uplink quantizer
  from a vmap-of-Python-QuantConfig into the traced graph, masked over
  the bucket padding;
* :func:`build_forward` closes the agent loops, the transport, and the
  server stack + head into one function for ``jax.jit``, with every
  stage's layer/row loop bound shipped as a *runtime* int32 argument —
  XLA then cannot unroll a loop body and re-fuse it into its neighbors,
  which is what keeps every stage a fixed, context-independent
  sub-computation (the bitwise-identity mechanism);
* :func:`compile_forward` AOT-compiles it (``jit(...).lower().compile()``)
  with the per-batch token/length buffers donated;
* :class:`CompiledForwardCache` memoizes executables keyed on
  ``(plan key, container signature, (B, S) bucket, split, b_emb)`` with
  hit/miss counters surfaced in ``EngineReport`` — together with the
  engine's shape bucketing (``kernels.bucketing``) the number of compiled
  variants is bounded by ``len(bucket ladder) x active plans``, and
  ``BatchedCoInferenceEngine.warmup()`` precompiles them all up front.

Bitwise identity with the eager path is the invariant throughout: bucket
right-padding is invisible by the DESIGN.md §7/§10 argument (row-independent
forward, causal attention, transport masking extended over the bucket tail),
and the scan body is the same per-layer block the eager loop runs.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..core.quantization import QuantConfig, quantize_dequantize
from ..kernels import ops as kops
from ..models import layers as L

__all__ = ["CompiledForwardCache", "SegmentDesc", "restack_segments",
           "layer_side_tree", "quantized_block", "scan_segment",
           "transport_quantize", "forward_bounds", "build_forward",
           "compile_forward", "aot_compile"]


# ---------------------------------------------------------------------------
# layer restacking
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentDesc:
    """One homogeneous run of agent layers: ``length`` consecutive layers
    from ``start`` whose weights all live in the same kernel container
    (``int4`` nibble-packed, ``int8``, or ``fake`` full-precision
    matrices from >8-bit plan entries)."""
    kind: str
    start: int
    length: int


def _container_kind(rec: dict) -> str:
    probe = next(iter(rec["attn"].values()))
    if isinstance(probe, kops.QuantizedLinear):
        return "int4" if probe.bits <= 4 else "int8"
    return "fake"


def restack_segments(qlinears: List[dict]):
    """Per-layer weight records -> (segment descriptors, stacked arrays).

    Consecutive layers sharing a container are stacked leaf-wise along a
    new leading layer axis so ``jax.lax.scan`` can drive them with one
    compiled body per segment.  Quantized containers stack to
    ``{"codes": [L, ...], "scales": [L, ...]}`` (the dequantization is
    bits-independent, so int8 layers of *different* plan bits still share
    a segment); ``fake`` layers stack the dense matrices directly.
    """
    groups: List[Tuple[str, int, List[dict]]] = []
    for i, rec in enumerate(qlinears):
        kind = _container_kind(rec)
        if groups and groups[-1][0] == kind:
            groups[-1][2].append(rec)
        else:
            groups.append((kind, i, [rec]))
    descs, arrays = [], []
    for kind, start, recs in groups:
        descs.append(SegmentDesc(kind=kind, start=start, length=len(recs)))
        stacked: Dict[str, Dict[str, Any]] = {}
        for part in ("attn", "ffn"):
            stacked[part] = {}
            for name in recs[0][part]:
                ws = [r[part][name] for r in recs]
                if kind == "fake":
                    stacked[part][name] = jnp.stack(
                        [jnp.asarray(w) for w in ws])
                else:
                    stacked[part][name] = {
                        "codes": jnp.stack([w.codes for w in ws]),
                        "scales": jnp.stack([w.scales for w in ws]),
                    }
        arrays.append(stacked)
    return tuple(descs), arrays


def _segment_apply(kind: str) -> Callable[[Any, jax.Array], jax.Array]:
    """The matmul a segment's scan body applies to its stacked slices:
    the Pallas quantized matmul for kernel containers, a plain matmul for
    fake-quantized (>8-bit) layers."""
    if kind == "int4":
        return lambda w, x: kops.quantized_matmul_int4(
            x, w["codes"], w["scales"])
    if kind == "int8":
        return lambda w, x: kops.quantized_matmul(x, w["codes"], w["scales"])
    return lambda w, x: x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# the shared per-layer block
# ---------------------------------------------------------------------------

def layer_side_tree(lp: dict, cfg) -> dict:
    """The non-matmul per-layer parameters the block body needs (norm
    gains and, where the family has them, QKV biases) — still stacked on
    the leading layer axis; callers index or scan-slice it."""
    t = {"ln1": lp["ln1"], "ln2": lp["ln2"]}
    if cfg.qkv_bias:
        t["attn"] = {k: lp["attn"][k] for k in ("bq", "bk", "bv")}
    return t


def quantized_block(cfg, apply_w, w, lp_i, x, positions):
    """One dense decoder block with quantized matmuls.

    ``w`` holds this layer's matmul weights (``{"attn": ..., "ffn": ...}``
    — ``QuantizedLinear``/dense leaves in the eager loop, stacked-slice
    dicts in the scanned fast path) applied through ``apply_w(w, x)``;
    ``lp_i`` is this layer's :func:`layer_side_tree` slice.  Shared by
    ``CoInferenceEngine._agent_forward_kernel`` and the compiled scan so
    eager and compiled serving execute identical ops (the bitwise-identity
    invariant of DESIGN.md §10).
    """
    h = L.apply_norm(cfg, x, lp_i["ln1"])
    q = apply_w(w["attn"]["wq"], h)
    k = apply_w(w["attn"]["wk"], h)
    v = apply_w(w["attn"]["wv"], h)
    if cfg.qkv_bias:
        q = q + lp_i["attn"]["bq"].astype(x.dtype)
        k = k + lp_i["attn"]["bk"].astype(x.dtype)
        v = v + lp_i["attn"]["bv"].astype(x.dtype)
    q = q.reshape(q.shape[:-1] + (cfg.n_heads, cfg.head_dim))
    k = k.reshape(k.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
    v = v.reshape(v.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.blockwise_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window)
    x = x + apply_w(w["attn"]["wo"],
                    attn.reshape(x.shape[:2] + (cfg.q_dim,)))
    h2 = L.apply_norm(cfg, x, lp_i["ln2"])
    if cfg.act == "silu":
        y = jax.nn.silu(apply_w(w["ffn"]["wi_gate"], h2)) \
            * apply_w(w["ffn"]["wi_up"], h2)
    else:
        y = jax.nn.gelu(apply_w(w["ffn"]["wi"], h2))
    return x + apply_w(w["ffn"]["wo"], y)


def scan_segment(cfg, desc: SegmentDesc, seg_arrays, side_tree, x,
                 positions, n_layers):
    """Loop :func:`quantized_block` over one homogeneous layer segment.

    Used by *both* the eager engine (``_agent_forward_kernel``) and the
    compiled forward.  The loop is a ``lax.while_loop`` over a *runtime*
    bound ``n_layers`` (an int32 array: concrete in eager mode, a traced
    argument inside the end-to-end jit): XLA cannot see the trip count,
    so the per-layer block compiles to one isolated sub-computation whose
    bits are identical in every execution context — a static-length scan
    would be unrolled and re-fused into its neighbors at short segment
    lengths, letting FMA contraction change the rounding.  This is the
    foundation of the fast path's bitwise-identity invariant."""
    ap = _segment_apply(desc.kind)
    lp_slice = jax.tree_util.tree_map(
        lambda a: a[desc.start:desc.start + desc.length], side_tree)
    n = jnp.asarray(n_layers, jnp.int32)

    def cond(carry):
        return carry[0] < n

    def body(carry):
        i, x = carry
        pick = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                      keepdims=False)
        w = jax.tree_util.tree_map(pick, seg_arrays)
        lp_i = jax.tree_util.tree_map(pick, lp_slice)
        return (i + 1, quantized_block(cfg, ap, w, lp_i, x, positions))

    _, x = jax.lax.while_loop(cond, body, (jnp.int32(0), x))
    return x


def transport_quantize(emb, lengths, b_emb: int, n_rows):
    """The uplink fake-quantizer as traced ops (DESIGN.md §10).

    Masks every position past a row's true length (so bucket padding can
    never raise a row's absmax), then applies the per-request per-tensor
    absmax quantize-dequantize at ``b_emb`` row by row inside a
    ``lax.while_loop`` over the *runtime* row count ``n_rows`` — the same
    isolation trick as :func:`scan_segment`, keeping the quantizer's
    rounding decisions bit-identical between the eager engine and the
    compiled forward.  Shared verbatim by both
    (``CoInferenceEngine.transport`` and :func:`build_forward`).
    """
    s = emb.shape[1]
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    emb = emb * mask[..., None].astype(emb.dtype)
    if b_emb >= 16:
        return emb
    qcfg = QuantConfig(bits=b_emb, scheme="uniform",
                       granularity="per-tensor")
    n = jnp.asarray(n_rows, jnp.int32)

    def cond(carry):
        return carry[0] < n

    def body(carry):
        i, out = carry
        row = jax.lax.dynamic_index_in_dim(emb, i, 0, keepdims=False)
        q = quantize_dequantize(row, qcfg)
        return (i + 1, jax.lax.dynamic_update_index_in_dim(out, q, i, 0))

    _, out = jax.lax.while_loop(cond, body,
                                (jnp.int32(0), jnp.zeros_like(emb)))
    return out


# ---------------------------------------------------------------------------
# the end-to-end forward
# ---------------------------------------------------------------------------

def forward_bounds(descs, split: int, n_layers: int, n_rows: int):
    """The runtime loop-bound vector a compiled forward consumes:
    ``[split, n_layers, n_rows, seg_len_0, seg_len_1, ...]``.

    Shipped as an int32 *argument* (never baked in as a constant) so XLA
    cannot unroll any of the stage loops — see :func:`scan_segment`.
    """
    segs = [d.length for d in descs] if descs is not None else []
    import numpy as _np
    return _np.asarray([split, n_layers, n_rows] + segs, _np.int32)


def build_forward(model, split: int, b_emb: int, descs, path: str):
    """Close agent stage + transport + server stage over ``model`` into one
    jittable ``forward(params, agent, tokens, lengths, bounds) -> logits``.

    ``path`` is ``"kernel"`` (``agent`` = restacked segment arrays, looped
    per ``descs``) or ``"fake"`` (``agent`` = the fake-quantized parameter
    tree, run through ``model.run_layers_window``).  ``lengths`` [B] marks
    each row's true token count: the transport mask zeroes every
    bucket-padded position so a row's per-request absmax — and hence its
    quantization — cannot depend on the padding.  ``bounds`` is the
    :func:`forward_bounds` vector of runtime loop bounds (DESIGN.md §10).
    """
    cfg = model.cfg

    def forward(params, agent, tokens, lengths, bounds):
        batch = {"tokens": tokens}
        if path == "kernel":
            x, positions = model.embed(params, batch)
            side = layer_side_tree(params["layers"], cfg)
            for i, (desc, seg) in enumerate(zip(descs, agent)):
                x = scan_segment(cfg, desc, seg, side, x, positions,
                                 bounds[3 + i])
        else:
            x, positions = model.embed(agent, batch)
            x, _ = model.run_layers_window(agent, x, positions,
                                           jnp.int32(0), bounds[0])
        x = transport_quantize(x, lengths, b_emb, bounds[2])
        x, _ = model.run_layers_window(params, x, positions, bounds[0],
                                       bounds[1])
        x = L.apply_norm(cfg, x, params["final_norm"])
        return L.unembed(cfg, params["embed"], x)

    return forward


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def aot_compile(fn, args, *, donate_argnums=()):
    """``jit(fn, donate_argnums).lower(*args).compile()`` with the
    donation-advisory noise suppressed.

    ``args`` are ShapeDtypeStructs (or arrays).  On backends that cannot
    alias a donated buffer (CPU for small int arrays) XLA simply drops
    the donation and emits an advisory UserWarning; the executables this
    repo builds donate deliberately chosen scratch, so the warning is
    noise.  Shared by :func:`compile_forward` and the decode engine's
    prefill/fused-step compiles (DESIGN.md §13).
    """
    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message=".*donated.*", category=UserWarning)
        return jitted.lower(*args).compile()


def compile_forward(forward, params, agent, batch: int, seq: int,
                    n_bounds: int):
    """AOT-compile ``forward`` for one (batch, seq) bucket.

    The token and length buffers are donated — they are per-batch scratch
    the engine rebuilds every step, so XLA may reuse them for activations.
    Returns the compiled executable (callable with concrete arrays).
    """
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    bounds = jax.ShapeDtypeStruct((n_bounds,), jnp.int32)
    return aot_compile(forward,
                       (_sds(params), _sds(agent), tok, lens, bounds),
                       donate_argnums=(2, 3))


# ---------------------------------------------------------------------------
# the compile cache
# ---------------------------------------------------------------------------

class CompiledForwardCache:
    """Memoizes AOT-compiled end-to-end forwards.

    Keys are ``(plan/weight key, container signature, (B, S) bucket,
    split, b_emb)`` — everything that changes the compiled graph.  With
    the engine's shape bucketing the reachable keyspace is
    ``len(bucket ladder) x active plans`` per engine, so warm traffic
    never misses; ``hits``/``misses`` are surfaced in ``EngineReport``
    and asserted by tests/benchmarks (every miss is exactly one XLA
    compile).
    """

    def __init__(self):
        self._exe: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._exe)

    def __contains__(self, key: tuple) -> bool:
        """Membership probe that does NOT touch the hit/miss counters —
        engines use it to decide whether an upcoming :meth:`get` will
        compile, so the compile can be wrapped in a trace span
        (DESIGN.md §14) without double-counting."""
        return key in self._exe

    @property
    def compiled_variants(self) -> int:
        return len(self._exe)

    def get(self, key: tuple, build: Callable[[], Any]):
        """The executable for ``key``, building (compiling) it on miss."""
        if key in self._exe:
            self.hits += 1
        else:
            self.misses += 1
            self._exe[key] = build()
        return self._exe[key]
