"""Runtime layer: training loop, co-inference serving (static + online
adaptive), fault tolerance."""

from .adaptive import (AdaptiveCoInferenceEngine, AdaptiveReport,  # noqa: F401
                       ReplanEvent)
from .fastpath import CompiledForwardCache  # noqa: F401
from .fault_tolerance import (HostFailure, HostSet, StragglerMonitor,  # noqa: F401
                              Supervisor, SupervisorReport)
from .serve_engine import (BatchedCoInferenceEngine, BatchStats,  # noqa: F401
                           CodesignCache, CoInferenceEngine, EngineReport,
                           QosClass, RequestStats, ServeRequest,
                           ServeResponse, ServeStats)
from .train_loop import TrainConfig, Trainer  # noqa: F401
