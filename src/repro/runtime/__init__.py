"""Runtime layer: training loop, co-inference serving (static + online
adaptive + multi-agent fleet), fault tolerance."""

from .adaptive import (AdaptiveCoInferenceEngine, AdaptiveReport,  # noqa: F401
                       ReplanEvent)
from .decode_engine import (ClassDecodeStats, DecodeEngine,  # noqa: F401
                            DecodeReport, DecodeRequest, DecodeResponse,
                            fit_kv_lambda, greedy_decode_reference)
from .fastpath import CompiledForwardCache  # noqa: F401
from .fault_tolerance import (HostFailure, HostSet, StragglerMonitor,  # noqa: F401
                              Supervisor, SupervisorReport)
from .fleet_engine import (AgentServeStats, FleetAgentSpec,  # noqa: F401
                           FleetCoInferenceEngine, FleetReport)
from .serve_engine import (BatchedCoInferenceEngine, BatchStats,  # noqa: F401
                           CodesignCache, CoInferenceEngine, EngineReport,
                           QosClass, RequestStats, ServeRequest,
                           ServeResponse, ServeStats, fit_lambda)
from .speculative import (SpecRoundStats,  # noqa: F401
                          SpeculativeDecodeEngine)
from .supervisor import (ResilienceReport, ServingSupervisor,  # noqa: F401
                         flip_bit, payload_checksum)
from .train_loop import TrainConfig, Trainer  # noqa: F401
