"""Runtime layer: training loop, co-inference serving, fault tolerance."""

from .fault_tolerance import (HostFailure, HostSet, StragglerMonitor,  # noqa: F401
                              Supervisor, SupervisorReport)
from .serve_engine import (BatchedCoInferenceEngine, BatchStats,  # noqa: F401
                           CodesignCache, CoInferenceEngine, EngineReport,
                           QosClass, RequestStats, ServeRequest,
                           ServeResponse, ServeStats)
from .train_loop import TrainConfig, Trainer  # noqa: F401
