"""Multi-agent fleet co-inference serving (DESIGN.md §11).

:class:`FleetCoInferenceEngine` serves N heterogeneous agents — each
with its own model config, parameters, hardware constants, QoS budget,
and optionally its own dynamic-environment trace — from one shared edge
server.  The server split is decided once, up front, by the fleet
allocator of ``core.fleet``: either the water-filling joint allocation
(``allocator="joint"``) or the equal-split baseline
(``allocator="equal"``); each agent then serves through its own
:class:`~repro.runtime.serve_engine.BatchedCoInferenceEngine` (or
:class:`~repro.runtime.adaptive.AdaptiveCoInferenceEngine` when the
agent carries an environment) built against
``core.fleet.shared_params(sysp_i, α_i)`` — the agent's constants with
its server slice baked into ``f_server_max``.

Sharing that matters:

* one :class:`~repro.runtime.serve_engine.CodesignCache` spans the
  fleet — two agents with the same decision inputs (λ, scaled params,
  (T0, E0), b_emb) share one (P1) solve;
* one :class:`~repro.runtime.fastpath.CompiledForwardCache` spans the
  fleet — agents over the same ``ModelConfig`` whose classes land on
  the same (plan, bucket) reuse the PR-4 AOT executables (weights are
  call arguments, so different parameter values still share the
  compiled code; DESIGN.md §10).

Contention model: the frequency-partitioned server means each agent's
slice is always available — per-agent virtual clocks advance
independently and the fleet makespan is their max.  Cross-agent
queueing inside one slice is deliberately out of scope (DESIGN.md §11
records the limitation).

A single-agent fleet receives share exactly 1.0, so its engine is
constructed with ``SystemParams`` equal to the agent's own and serves
**bitwise identically** to a directly-built ``BatchedCoInferenceEngine``
(enforced by ``benchmarks/fleet.py`` and ``tests/test_fleet.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core import fleet as fl
from ..core.cost_model import SystemParams
from ..env.environment import Environment
from ..obs import NULL_METRICS, NULL_TRACER, ReportBase
from . import fastpath as fp
from .adaptive import AdaptiveCoInferenceEngine
from .serve_engine import (BatchedCoInferenceEngine, CodesignCache,
                           EngineReport, QosClass, ServeResponse, fit_lambda)

__all__ = ["FleetAgentSpec", "AgentServeStats", "FleetReport",
           "FleetCoInferenceEngine"]


@dataclasses.dataclass(frozen=True)
class FleetAgentSpec:
    """One fleet member, as handed to :class:`FleetCoInferenceEngine`.

    ``model``/``params`` may differ freely across agents (different
    architectures serve side by side).  ``sysp`` holds the agent's own
    constants with ``f_server_max`` at the **full** server frequency —
    the engine applies the allocated share, callers never pre-scale.
    ``qos`` is the agent's service class ((T0, E0) per request);
    ``weight`` its term in the fleet objective.  An ``environment``
    turns the agent's member engine into an adaptive one (DESIGN.md §9)
    driven by ``policy``, closing the loop per agent while the share
    split stays fixed.
    """

    name: str
    model: Any
    params: Any
    sysp: SystemParams
    qos: QosClass
    weight: float = 1.0
    b_emb: int = 8
    environment: Optional[Environment] = None
    policy: str = "adaptive"


@dataclasses.dataclass(frozen=True)
class AgentServeStats(ReportBase):
    """Per-agent slice of a fleet run (the fleet-level analogue of
    ``ServeStats``: allocation + realized serving aggregates)."""

    name: str
    share: float                # fraction of the server's frequency
    b_hat: int                  # uniform b̂ / rounded mean plan bits
    plan_bits: tuple            # per-layer bits in mixed mode (else ())
    bound: float                # this agent's weighted objective term
    requests_served: int
    batches_served: int
    mean_occupancy: float
    clock_s: float              # the agent's virtual clock at the end
    energy_j: float
    deadline_violations: int    # responses with wait + delay > T0_i
    throughput_rps: float


@dataclasses.dataclass(frozen=True)
class FleetReport(ReportBase):
    """Whole-fleet aggregates plus the per-agent breakdown."""

    allocator: str              # "joint" | "equal"
    n_agents: int
    shares: tuple
    aggregate_bound: float      # Σ w_i · objective_i (the (P-fleet) value)
    requests_served: int
    batches_served: int
    total_energy_j: float
    makespan_s: float           # max over per-agent virtual clocks
    throughput_rps: float       # fleet requests / makespan
    deadline_violations: int
    codesign_hits: int          # shared-cache totals across the fleet
    codesign_misses: int
    compile_hits: int = 0
    compile_misses: int = 0
    compiled_variants: int = 0
    per_agent: tuple = ()       # AgentServeStats, in spec order


class FleetCoInferenceEngine:
    """N agent queues, one shared edge server, one allocation."""

    def __init__(self, agents: Sequence[FleetAgentSpec], *,
                 allocator: str = "joint",
                 max_batch: int = 8,
                 path: str = "fake",
                 scheme: str = "uniform",
                 mixed_precision: bool = False,
                 compiled: bool = False,
                 share_link: bool = False,
                 codesign_cache: Optional[CodesignCache] = None,
                 compile_cache: Optional[fp.CompiledForwardCache] = None,
                 pad_token: int = 0,
                 tracer=None, metrics=None):
        if allocator not in ("joint", "equal"):
            raise ValueError(f"unknown allocator {allocator!r} "
                             "(want 'joint' or 'equal')")
        if not agents:
            raise ValueError("need at least one FleetAgentSpec")
        names = [a.name for a in agents]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate agent names: {sorted(names)}")
        self.specs: Tuple[FleetAgentSpec, ...] = tuple(agents)
        self.allocator = allocator
        self.share_link = bool(share_link)
        self.mixed_precision = bool(mixed_precision)
        self.compiled = bool(compiled)
        self.codesign_cache = codesign_cache \
            if codesign_cache is not None else CodesignCache()
        self.compile_cache = compile_cache if compile_cache is not None \
            else (fp.CompiledForwardCache() if compiled else None)
        # observability (DESIGN.md §14): shared by every member engine,
        # so one trace/metrics sink sees the whole fleet
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_METRICS

        # the share split (core.fleet): per-agent λ via the engines' own
        # statistic, then water-filling or equal split over the server
        core_agents = [
            fl.FleetAgent(name=a.name,
                          lam=fit_lambda(a.params, a.model.cfg.split_layer),
                          sysp=a.sysp, t0=a.qos.t0, e0=a.qos.e0,
                          weight=a.weight, b_emb=a.b_emb)
            for a in agents]
        solve = fl.solve_fleet if allocator == "joint" \
            else fl.solve_equal_split
        alloc = solve(core_agents, share_link=self.share_link)
        if alloc is None:
            raise ValueError(
                f"fleet allocation infeasible ({allocator}): the agents' "
                "(T0, E0) budgets cannot all be met from one server — "
                "loosen a budget or shrink the fleet")
        self.allocation: fl.FleetSolution = alloc
        # replay the allocator's decisions into the trace: each greedy
        # water-filling upgrade, then every agent's final share
        for aname, new_b, cost, ratio in alloc.upgrade_log:
            self.tracer.instant("fleet.upgrade", agent=aname,
                                new_bits=new_b, share_cost=cost,
                                ratio=ratio)
        for spec, share in zip(self.specs, alloc.shares):
            self.tracer.instant("fleet.share", agent=spec.name,
                                share=share, allocator=allocator)
            self.metrics.gauge("fleet.agent_share",
                               agent=spec.name).set(share)
        self.metrics.counter("fleet.upgrades").inc(alloc.upgrades)

        # one member engine per agent, against its server slice, over
        # the shared caches
        self.engines: Dict[str, BatchedCoInferenceEngine] = {}
        for spec, share in zip(self.specs, alloc.shares):
            p = fl.shared_params(spec.sysp, share,
                                 share_link=self.share_link)
            kwargs = dict(classes=[spec.qos], max_batch=max_batch,
                          path=path, b_emb=spec.b_emb, scheme=scheme,
                          codesign_cache=self.codesign_cache,
                          mixed_precision=mixed_precision,
                          compiled=compiled,
                          compile_cache=self.compile_cache,
                          pad_token=pad_token,
                          tracer=self.tracer, metrics=self.metrics)
            if spec.environment is not None:
                eng = AdaptiveCoInferenceEngine(
                    spec.model, spec.params, p,
                    environment=spec.environment, policy=spec.policy,
                    **kwargs)
            else:
                eng = BatchedCoInferenceEngine(spec.model, spec.params, p,
                                               **kwargs)
            self.engines[spec.name] = eng
        self._violations: Dict[str, int] = {a.name: 0 for a in self.specs}
        # membership (DESIGN.md §15): dropped agents keep their queues
        # but are skipped by step() until they rejoin; reallocate()
        # re-water-fills the server among whoever is present
        self._active = {a.name for a in self.specs}
        self._reallocations = 0

    # ------------------------------------------------------------------
    # allocation views
    # ------------------------------------------------------------------
    def share_of(self, agent: str) -> float:
        """The agent's allocated fraction of the server frequency."""
        return self.allocation.shares[self._index(agent)]

    def solution_for(self, agent: str):
        """The agent's operating point as its member engine serves it
        (a ``CodesignSolution``, or a ``MixedSolution`` in
        mixed-precision mode)."""
        spec = self.specs[self._index(agent)]
        return self.engines[agent].solution_for(spec.qos.name)

    def _index(self, agent: str) -> int:
        for i, a in enumerate(self.specs):
            if a.name == agent:
                return i
        raise KeyError(f"unknown agent {agent!r}; have "
                       f"{[a.name for a in self.specs]}")

    # ------------------------------------------------------------------
    # membership churn (DESIGN.md §15)
    # ------------------------------------------------------------------
    @property
    def active_agents(self) -> tuple:
        """Currently-present members, in spec order."""
        return tuple(a.name for a in self.specs if a.name in self._active)

    @property
    def reallocations(self) -> int:
        """How many times the share split was re-solved — the churn
        bound is one per membership change, enforced by the supervisor
        calling :meth:`reallocate` only on a dropout/rejoin edge."""
        return self._reallocations

    def reallocate(self, active: "Sequence[str]") -> fl.FleetSolution:
        """Re-water-fill the server among ``active`` and retune each
        present member engine to its new share.

        A dropout hands its slice to the survivors (their ``f̃`` slices
        grow, possibly upgrading their b̂); a rejoin takes it back.
        Dropped agents keep their queues — their requests wait for the
        rejoin rather than being silently dropped — and their engines
        keep the last operating point.  Raises ``ValueError`` when the
        surviving subset is empty or its budgets can no longer be met
        from the shared server."""
        names = list(dict.fromkeys(active))
        for name in names:
            self._index(name)
        if not names:
            raise ValueError("fleet reallocation needs at least one "
                             "active agent")
        core = [
            fl.FleetAgent(name=a.name,
                          lam=fit_lambda(a.params, a.model.cfg.split_layer),
                          sysp=a.sysp, t0=a.qos.t0, e0=a.qos.e0,
                          weight=a.weight, b_emb=a.b_emb)
            for a in self.specs if a.name in names]
        solve = fl.solve_fleet if self.allocator == "joint" \
            else fl.solve_equal_split
        alloc = solve(core, share_link=self.share_link)
        if alloc is None:
            raise ValueError(
                f"fleet reallocation infeasible over {sorted(names)}: "
                "the surviving agents' (T0, E0) budgets cannot be met")
        shares = dict(zip([a.name for a in core], alloc.shares))
        for spec in self.specs:
            if spec.name not in shares:
                continue
            share = shares[spec.name]
            eng = self.engines[spec.name]
            p = fl.shared_params(spec.sysp, share,
                                 share_link=self.share_link)
            # retune in place: the member keeps its queue, clock, and
            # caches; only the operating point moves with the share
            eng.sysp = p
            eng.engine.sysp = p
            sol = eng._counted_solution(spec.qos, sysp=p)
            if sol is None:
                raise ValueError(
                    f"agent {spec.name!r} infeasible at share "
                    f"{share:.3f} after reallocation")
            eng._solutions[spec.qos.name] = sol
            if self.mixed_precision:
                eng._plans[spec.qos.name] = eng.engine.plan_of(sol)
            self.tracer.instant("fleet.share", agent=spec.name,
                                share=share, allocator=self.allocator,
                                reallocation=True)
            self.metrics.gauge("fleet.agent_share",
                               agent=spec.name).set(share)
        self._active = set(names)
        self._reallocations += 1
        self.metrics.counter("fleet.reallocations").inc()
        return alloc

    # ------------------------------------------------------------------
    # queue API (delegates to the member engines)
    # ------------------------------------------------------------------
    def submit(self, agent: str, tokens, arrival_s: Optional[float] = None
               ) -> int:
        """Enqueue one request on ``agent``'s queue; returns its id
        (unique per agent, not fleet-wide)."""
        spec = self.specs[self._index(agent)]
        return self.engines[agent].submit(tokens, spec.qos.name,
                                          arrival_s=arrival_s)

    def pending(self) -> int:
        return sum(e.pending() for e in self.engines.values())

    def warmup(self, max_seq: int) -> int:
        """Precompile every member engine's (plan, bucket) variants
        (DESIGN.md §10); agents sharing a config and plan hit the shared
        compile cache instead of recompiling.  Returns total misses
        added."""
        return sum(e.warmup(max_seq) for e in self.engines.values())

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def step(self) -> Tuple[Optional[str], List[ServeResponse]]:
        """Serve one batch from the agent whose oldest pending request
        arrived first (fleet-level FIFO across independent slices);
        returns ``(agent name, responses)`` or ``(None, [])`` when every
        queue is empty."""
        best_name, best_t = None, None
        for spec in self.specs:
            if spec.name not in self._active:
                continue  # dropped member: queue holds until rejoin
            t = self.engines[spec.name].oldest_pending_arrival()
            if t is not None and (best_t is None or t < best_t):
                best_name, best_t = spec.name, t
        if best_name is None:
            return None, []
        spec = self.specs[self._index(best_name)]
        responses = self.engines[best_name].step()
        self._violations[best_name] += sum(
            1 for r in responses
            if r.stats.total_delay_s > spec.qos.t0 * (1.0 + 1e-9))
        return best_name, responses

    def drain(self) -> Dict[str, List[ServeResponse]]:
        """Serve until every agent's queue is empty; responses grouped
        per agent, in completion order."""
        out: Dict[str, List[ServeResponse]] = {a.name: []
                                               for a in self.specs}
        # count only active queues: a dropped member's requests wait for
        # its rejoin and must not spin the drain loop
        while sum(self.engines[n].pending() for n in self.active_agents):
            name, responses = self.step()
            if name is not None:
                out[name].extend(responses)
        return out

    # ------------------------------------------------------------------
    def report(self) -> FleetReport:
        """Fleet aggregates plus per-agent :class:`AgentServeStats`."""
        per = []
        total_req = total_batches = total_viol = 0
        total_energy = 0.0
        makespan = 0.0
        agg_bound = 0.0
        for spec, share in zip(self.specs, self.allocation.shares):
            eng = self.engines[spec.name]
            rep: EngineReport = eng.report()
            sol = eng.solution_for(spec.qos.name)
            bound = spec.weight * float(sol.objective)
            agg_bound += bound
            plan = eng.plan_for(spec.qos.name)
            per.append(AgentServeStats(
                name=spec.name, share=share,
                b_hat=int(getattr(sol, "b_hat")),
                plan_bits=(plan.layer_bit_list(spec.model.cfg.split_layer)
                           if plan is not None else ()),
                bound=bound,
                requests_served=rep.requests_served,
                batches_served=rep.batches_served,
                mean_occupancy=rep.mean_occupancy,
                clock_s=rep.total_delay_s,
                energy_j=rep.total_energy_j,
                deadline_violations=self._violations[spec.name],
                throughput_rps=rep.throughput_rps))
            total_req += rep.requests_served
            total_batches += rep.batches_served
            total_energy += rep.total_energy_j
            makespan = max(makespan, rep.total_delay_s)
            total_viol += self._violations[spec.name]
        cc = self.compile_cache
        return FleetReport(
            allocator=self.allocator,
            n_agents=len(self.specs),
            shares=self.allocation.shares,
            aggregate_bound=agg_bound,
            requests_served=total_req,
            batches_served=total_batches,
            total_energy_j=total_energy,
            makespan_s=makespan,
            throughput_rps=total_req / makespan if makespan > 0 else 0.0,
            deadline_violations=total_viol,
            codesign_hits=self.codesign_cache.hits,
            codesign_misses=self.codesign_cache.misses,
            compile_hits=sum(e.engine._own_compile_hits
                             for e in self.engines.values()),
            compile_misses=sum(e.engine._own_compile_misses
                               for e in self.engines.values()),
            compiled_variants=len(cc) if cc is not None else 0,
            per_agent=tuple(per))
