"""Continuous-batching decode over a device-resident quantized KV cache
(DESIGN.md §12, §13).

Everything the engines of ``serve_engine.py`` do is one prefill-style
forward per request.  Embodied-agent traffic is token-by-token decode:
a request prefills once, then occupies the accelerator for dozens of
single-token steps whose cost is dominated by streaming the KV cache.
This module adds that serving mode on top of the PR-4 compiled fast
path, with four commitments:

1.  **Continuous batching.**  A request is admitted into a free decode
    slot the moment one exists and retires the moment its budget is
    spent — there is no batch barrier.  The FIFO-barrier policy (admit a
    full batch, run it to completion, only then refill) is kept as
    ``admission="barrier"`` on the same engine, so the benchmark's
    throughput comparison is policy-for-policy on identical code.

2.  **Quantized KV cache, attended directly.**  Cache entries are stored
    as int8-held codes plus one f32 scale per head vector
    (``kernels.quantize.kv_quantize`` — the weight quantizers' exact
    scale/round/clip rule) at a stored bit-width ``b_kv`` from the
    realizable container ladder.  The decode step never materializes a
    dequantized copy: ``DecoderLM.decode_step_q`` quantizes the fresh
    entry *before* writing it and attends through
    ``kernels.decode_attn.quantized_decode_attention``, which
    dequantizes per-tile in VMEM.  ``b_kv`` stays the third codesign
    variable (``codesign.solve_decode`` /
    ``mixed_precision.allocate_bits_decode``).

3.  **Device residency (DESIGN.md §13).**  Each slot block's
    ``k_codes/v_codes/k_scales/v_scales/pos/tok`` live as on-device
    arrays that persist across engine steps and are *donated* to each
    executable (XLA updates them in place).  The host syncs only at the
    real serving boundaries: prompt tokens in at admission, generated
    token blocks out for streaming/retirement.  ``DecodeReport`` counts
    the actual h2d/d2h bytes so the benchmark can show the per-token
    transfer volume collapsing.

4.  **Bitwise parity.**  Greedy decode through the batched engine equals
    the non-batched sequential reference token-for-token.  The load-
    bearing invariants: each request's cache length is bucketed from its
    *own* parameters (``T = seq_bucket(prompt_len + max_new_tokens)``,
    never a batch max); every per-row op in the decode graph is
    row-independent, so batch width B does not change row values (the
    §7 house invariant); and multi-token stepping is fused through a
    ``lax.while_loop`` whose trip count is a *runtime* argument — the
    §10 isolation trick, so each token step compiles to one fixed XLA
    sub-computation and any chunking of the same step sequence (engine
    chunks vs reference chunks vs an elastic split/resume) produces
    identical bits.  Engine and reference share the same traced
    functions at different batch widths.

Executables are AOT-compiled (``fastpath.aot_compile``) and memoized in
a :class:`~repro.runtime.fastpath.CompiledForwardCache`: prefill+scatter
is keyed on (prompt bucket, cache bucket, batch, b_kv), the fused decode
chunk on (batch, cache bucket, b_kv), so the post-warmup compile count
is bounded by the (prompt, cache)-bucket pairs plus cache rungs, times
the distinct cache bit-widths.

Costs are virtual-clock, billed at the *padded* workload exactly as
before: each token step inside a fused chunk bills all ``max_batch``
slots plus the full cache read at ``b_kv``.  A chunk never overruns a
scheduling boundary — its step count is clamped to the tightest of the
live slots' remaining budgets, the next queued arrival, and the EOS
early-exit inside the executable — so admission and retirement timing
on the virtual clock are identical to stepping one token at a time.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixed_precision as mp
from repro.core.cost_model import (SystemParams, agent_delay, agent_energy,
                                   kv_delay, kv_energy, server_delay,
                                   server_energy)
from repro.core.quantization import QuantConfig, QuantPlan
from repro.core.rate_distortion import exponential_mle
from repro.kernels.bucketing import DEFAULT_SEQ_BASE, seq_bucket, seq_ladder
from repro.kernels.quantize import kv_cache_bytes, kv_quantize
from repro.obs import NULL_METRICS, NULL_TRACER, ReportBase

from .fastpath import CompiledForwardCache, _sds, aot_compile
from .qat import fake_quantize_agent
from .serve_engine import CodesignCache, QosClass, fit_lambda

__all__ = [
    "DecodeRequest",
    "DecodeResponse",
    "ClassDecodeStats",
    "DecodeReport",
    "DecodeEngine",
    "fit_kv_lambda",
    "greedy_decode_reference",
]

# the fused decode executable's fixed output-block width: one compiled
# chunk emits up to this many tokens per slot.  A constant (never a
# compile key) so chunk size costs no extra executables and — by the
# while-loop isolation argument — no bitwise risk: a chunk of k steps is
# the same k loop iterations regardless of where the host cuts them.
_CHUNK = 64

# the KV-cache layout this engine manages slots in; models exposing the
# decode hooks over a different state shape (conv streams, recurrent
# cells, cross-attention caches) cannot be sloted into it
_DECODE_CACHE_AXES = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    "len": ("batch",),
}


def decode_protocol_gap(model) -> Optional[str]:
    """Why ``model`` cannot be decode-served (None when it can).

    Requires the full DecoderLM decode protocol — ``prefill`` /
    ``init_cache`` / ``decode_step`` / ``decode_step_q`` — *and* the
    [L, B, T, KV, dh] KV-cache layout this engine's slot arrays assume.
    Hybrid/xLSTM/enc-dec families expose same-named hooks over different
    state shapes; they are rejected here, not by a shape error three
    calls in.
    """
    missing = [h for h in ("prefill", "init_cache", "decode_step",
                           "decode_step_q", "cache_axes")
               if not hasattr(model, h)]
    if missing:
        return f"lacks the {'/'.join(missing)} decode hook(s)"
    axes = model.cache_axes()
    if axes != _DECODE_CACHE_AXES:
        return ("decode state is not the [layers, batch, cache_seq, "
                "kv_heads, head_dim] KV cache")
    return None


# ---------------------------------------------------------------------------
# records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeRequest:
    """One queued decode request: a prompt plus a generation budget."""
    request_id: int
    tokens: np.ndarray          # int32 [P] prompt
    qos: str
    max_new_tokens: int
    arrival_s: float            # virtual arrival time


@dataclasses.dataclass(frozen=True)
class DecodeResponse:
    """A retired request: greedy continuation + its latency accounting."""
    request_id: int
    qos: str
    tokens: np.ndarray          # int32, generated greedily (<= max_new)
    prompt_len: int
    b_kv: int                   # stored cache bit-width it decoded under
    ttft_s: float               # arrival -> first token (virtual clock)
    itl_mean_s: float           # mean inter-token latency (0 if 1 token)
    finished_s: float
    cancelled: bool = False     # retired mid-decode by cancel()


@dataclasses.dataclass(frozen=True)
class ClassDecodeStats(ReportBase):
    """Per-QoS-class latency aggregates of a :class:`DecodeReport`."""
    qos: str
    b_hat: int
    b_kv: int
    requests: int
    tokens: int
    ttft_mean_s: float
    ttft_max_s: float
    itl_mean_s: float
    plan_bits: tuple = ()       # per-agent-layer bits under a mixed plan
    itl_p50_s: float = 0.0      # inter-token latency percentiles
    itl_p95_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class DecodeReport(ReportBase):
    """Whole-run aggregates of a :class:`DecodeEngine` (the decode
    counterpart of ``serve_engine.EngineReport``, streamed per class)."""
    requests_served: int
    cancelled: int
    tokens_generated: int
    prefills: int
    decode_rounds: int
    total_delay_s: float        # virtual clock at the end of the run
    total_energy_j: float
    throughput_tps: float       # generated tokens / modeled second
    throughput_rps: float
    admission: str              # "continuous" | "barrier"
    classes: tuple = ()         # ClassDecodeStats per QoS class
    kv_bytes: int = 0           # stored cache bytes across admissions
    kv_bytes_full: int = 0      # same cache at full precision
    codesign_hits: int = 0      # this engine's cache attribution
    codesign_misses: int = 0
    compile_hits: int = 0
    compile_misses: int = 0
    compiled_variants: int = 0
    h2d_bytes: int = 0          # measured host->device traffic (§13)
    d2h_bytes: int = 0          # measured device->host traffic


# ---------------------------------------------------------------------------
# cache-activation statistic
# ---------------------------------------------------------------------------

_KV_LAMBDA_MEMO: Dict[tuple, float] = {}


def _params_fingerprint(params) -> tuple:
    """A cheap hashable identity for a parameter tree: every leaf's
    (shape, dtype) plus the first leaf's head bytes.  Distinguishes
    differently-initialized trees of the same architecture without
    hashing gigabytes; collisions would need identical leading weights
    on identical structures."""
    leaves = jax.tree_util.tree_leaves(params)
    head = np.asarray(leaves[0]).reshape(-1)[:8].tobytes()
    return (tuple((tuple(lf.shape), str(lf.dtype)) for lf in leaves), head)


def fit_kv_lambda(model, params, *, seq: int = 16) -> float:
    """MLE λ_kv over K/V cache magnitudes from one calibration prefill.

    The decode codesign needs a rate parameter for the *cached
    activations*, symmetric with ``fit_lambda``'s weight statistic.  One
    deterministic prompt (``arange % vocab``) at full precision is
    calibration enough at the fidelity of the exponential model — and
    determinism keeps the codesign cache key stable across runs.

    Memoized per (arch config, seq, parameter fingerprint): the prefill
    is a real forward pass, and every :class:`DecodeEngine` construction
    over the same model/params would otherwise re-run it.
    """
    key = (model.cfg, int(seq), _params_fingerprint(params))
    if key not in _KV_LAMBDA_MEMO:
        cfg = model.cfg
        toks = (np.arange(seq, dtype=np.int64)
                % int(cfg.vocab_size)).astype(np.int32)[None]
        _, cache = model.prefill(params, {"tokens": jnp.asarray(toks)})
        mags = jnp.concatenate([jnp.abs(cache["k"]).reshape(-1),
                                jnp.abs(cache["v"]).reshape(-1)])
        _KV_LAMBDA_MEMO[key] = float(exponential_mle(mags))
    return _KV_LAMBDA_MEMO[key]


# ---------------------------------------------------------------------------
# traced decode functions (shared by the engine and the reference)
# ---------------------------------------------------------------------------

def _build_prefill(model, b_kv: int) -> Callable:
    """Fused prefill + quantize + slot scatter (DESIGN.md §13).

    (weights, tokens [1, S], last_idx [1], slot [], k_codes, v_codes,
    k_scales, v_scales, pos [B], tok [B]) -> (first greedy token [1],
    updated buffers).  The prompt's cache block is quantized and written
    into decode slot ``slot`` of the group's device-resident buffers
    inside one executable — the quantization arithmetic is in-trace, so
    engine and reference share it exactly, and the cache block never
    visits the host.  Buffer positions past the prompt keep the previous
    occupant's stale entries: attention masks positions >= the row's
    cache length, so they are never read before this occupant overwrites
    them token by token.
    """
    raw = b_kv >= 16

    def fn(weights, tokens, last_idx, slot, kc, vc, ks, vs, pos, tok):
        logits, cache = model.prefill(weights, {"tokens": tokens},
                                      last_index=last_idx)
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k, v = cache["k"], cache["v"]       # [L, 1, S, KV, dh]
        if raw:
            kq, vq = k.astype(kc.dtype), v.astype(vc.dtype)
            ksn = jnp.ones(k.shape[:-1], jnp.float32)
            vsn = jnp.ones(v.shape[:-1], jnp.float32)
        else:
            kq, ksn = kv_quantize(k, b_kv)
            vq, vsn = kv_quantize(v, b_kv)
            kq, vq = kq.astype(kc.dtype), vq.astype(vc.dtype)
        at5 = (0, slot, 0, 0, 0)
        kc = jax.lax.dynamic_update_slice(kc, kq, at5)
        vc = jax.lax.dynamic_update_slice(vc, vq, at5)
        ks = jax.lax.dynamic_update_slice(ks, ksn, at5[:-1])
        vs = jax.lax.dynamic_update_slice(vs, vsn, at5[:-1])
        pos = jax.lax.dynamic_update_slice(pos, last_idx + 1, (slot,))
        tok = jax.lax.dynamic_update_slice(tok, tok0, (slot,))
        return tok0, kc, vc, ks, vs, pos, tok

    return fn


def _build_fused_decode(model, b_kv: int) -> Callable:
    """Multi-token decode chunk as ONE executable (DESIGN.md §13).

    (weights, k_codes, v_codes, k_scales, v_scales, tok [B], pos [B],
    live [B] i32, eos [], n_steps []) -> (token block [B, _CHUNK] i32,
    steps done [], updated buffers).  A ``lax.while_loop`` whose trip
    count ``n_steps`` is a *runtime* argument steps
    ``DecoderLM.decode_step_q`` up to ``n_steps`` times, exiting early
    once every live slot has emitted ``eos`` (pass eos = -1 to disable —
    greedy tokens are always >= 0).  The §10 isolation argument makes
    each iteration one fixed XLA sub-computation, so chunk boundaries
    cannot change bits; dead slots (live = 0) still compute, but every
    op is row-independent so their garbage never escapes the row.
    """

    def fn(weights, kc, vc, ks, vs, tok, pos, live, eos, n_steps):
        b = tok.shape[0]
        live_m = live > 0
        n = jnp.asarray(n_steps, jnp.int32)

        def cond(carry):
            i = carry[0]
            eos_hit = carry[7]
            return (i < n) & jnp.any(live_m & ~eos_hit)

        def body(carry):
            i, tok, pos, kc, vc, ks, vs, eos_hit, out = carry
            logits, qc = model.decode_step_q(
                weights,
                {"k_codes": kc, "v_codes": vc, "k_scales": ks,
                 "v_scales": vs, "len": pos},
                {"token": tok[:, None], "pos": pos}, b_kv=b_kv)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = jax.lax.dynamic_update_slice(out, nxt[:, None], (0, i))
            eos_hit = eos_hit | (nxt == eos)
            return (i + 1, nxt, qc["len"], qc["k_codes"], qc["v_codes"],
                    qc["k_scales"], qc["v_scales"], eos_hit, out)

        carry = (jnp.int32(0), tok, pos, kc, vc, ks, vs,
                 jnp.zeros((b,), bool), jnp.zeros((b, _CHUNK), jnp.int32))
        i, tok, pos, kc, vc, ks, vs, _, out = jax.lax.while_loop(
            cond, body, carry)
        return out, i, kc, vc, ks, vs, tok, pos

    return fn


# the speculative executables' fixed draft-column width
# (``runtime/speculative.py``): lookahead k is a *runtime* argument up
# to this many columns, never a compile key, so sweeping k costs no
# extra executables — the same isolation trick as ``_CHUNK``.
_SPEC_MAX_K = 16


def _build_spec_draft(model, b_kv: int) -> Callable:
    """``k`` greedy draft steps under the DRAFT weight tree
    (DESIGN.md §16).

    (draft_weights, k_codes, v_codes, k_scales, v_scales, tok [B],
    pos [B], n_draft []) -> drafts [B, _SPEC_MAX_K] i32.  The chain
    steps ``decode_step_q`` ``n_draft`` times from the canonical cache
    state, carrying the cache *functionally* in the while-loop and
    discarding it at the end: draft writes are speculative scratch that
    must never reach the canonical slot buffers, so the buffers are NOT
    donated here — rollback is realized as commit-on-verify (only the
    verify executable writes the canonical cache), not as truncation
    after the fact.
    """

    def fn(weights, kc, vc, ks, vs, tok, pos, n_draft):
        b = tok.shape[0]
        n = jnp.asarray(n_draft, jnp.int32)

        def cond(carry):
            return carry[0] < n

        def body(carry):
            i, tok, pos, kc, vc, ks, vs, out = carry
            logits, qc = model.decode_step_q(
                weights,
                {"k_codes": kc, "v_codes": vc, "k_scales": ks,
                 "v_scales": vs, "len": pos},
                {"token": tok[:, None], "pos": pos}, b_kv=b_kv)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = jax.lax.dynamic_update_slice(out, nxt[:, None], (0, i))
            return (i + 1, nxt, qc["len"], qc["k_codes"], qc["v_codes"],
                    qc["k_scales"], qc["v_scales"], out)

        carry = (jnp.int32(0), tok, pos, kc, vc, ks, vs,
                 jnp.zeros((b, _SPEC_MAX_K), jnp.int32))
        return jax.lax.while_loop(cond, body, carry)[-1]

    return fn


def _build_spec_verify(model, b_kv: int) -> Callable:
    """Verify a round of drafts with the TARGET weights, longest-
    accepted-prefix semantics (DESIGN.md §16).

    (weights, k_codes, v_codes, k_scales, v_scales, tok [B], pos [B],
    live [B] i32, drafts [B, _SPEC_MAX_K] i32, n_draft [], rem [B] i32,
    eos []) -> (token block [B, _SPEC_MAX_K + 1] i32, emitted [B] i32,
    accepted [B] i32, updated buffers).

    Iteration ``i`` feeds each still-active row's current token at its
    position through ``decode_step_q`` — *exactly* the sequential
    reference's next step, so every cache entry an active row writes is
    the entry the reference writes, and every emitted token ``g`` is
    the reference's token.  A row goes inactive after emitting when its
    ``g`` diverges from ``drafts[:, i]`` (``g`` is the correction and
    is already committed), when ``i == n_draft`` (the bonus token), at
    ``eos``, or when its generation budget ``rem`` is spent.  Inactive
    rows are frozen: cache writes are reverted row-wise, ``pos``/``tok``
    held, so a round never commits anything the reference would not —
    delivered tokens per row per round = accepted prefix + 1, bitwise
    the reference stream (the house invariant, extended).
    """

    def fn(weights, kc, vc, ks, vs, tok, pos, live, drafts, n_draft,
           rem, eos):
        b = tok.shape[0]
        n = jnp.asarray(n_draft, jnp.int32)

        def cond(carry):
            return (carry[0] <= n) & jnp.any(carry[1])

        def body(carry):
            i, act, tok, pos, kc, vc, ks, vs, cnt, acc, out = carry
            logits, qc = model.decode_step_q(
                weights,
                {"k_codes": kc, "v_codes": vc, "k_scales": ks,
                 "v_scales": vs, "len": pos},
                {"token": tok[:, None], "pos": pos}, b_kv=b_kv)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            m5 = act[None, :, None, None, None]
            m4 = act[None, :, None, None]
            kc = jnp.where(m5, qc["k_codes"], kc)
            vc = jnp.where(m5, qc["v_codes"], vc)
            ks = jnp.where(m4, qc["k_scales"], ks)
            vs = jnp.where(m4, qc["v_scales"], vs)
            pos = jnp.where(act, qc["len"], pos)
            tok = jnp.where(act, g, tok)
            # all active rows share emission column i (== their cnt);
            # inactive rows' stale columns are never read by the host
            out = jax.lax.dynamic_update_slice(out, g[:, None], (0, i))
            cnt = cnt + act.astype(jnp.int32)
            draft_i = jax.lax.dynamic_index_in_dim(drafts, i, axis=1,
                                                   keepdims=False)
            match = (i < n) & (g == draft_i)
            acc = acc + (act & match).astype(jnp.int32)
            act = act & match & (g != eos) & (cnt < rem)
            return (i + 1, act, tok, pos, kc, vc, ks, vs, cnt, acc, out)

        carry = (jnp.int32(0), live > 0, tok, pos, kc, vc, ks, vs,
                 jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32),
                 jnp.zeros((b, _SPEC_MAX_K + 1), jnp.int32))
        (_, _, tok, pos, kc, vc, ks, vs, cnt, acc, out) = \
            jax.lax.while_loop(cond, body, carry)
        return out, cnt, acc, kc, vc, ks, vs, tok, pos

    return fn


def _build_spec_round(model, b_kv: int) -> Callable:
    """One full speculative round — draft chain + verify chain — in a
    single executable (DESIGN.md §16).

    (draft_weights, weights, k_codes, v_codes, k_scales, v_scales,
    tok [B], pos [B], live [B] i32, n_draft [], rem [B] i32, eos []) ->
    ``_build_spec_verify``'s outputs.  Semantically this is exactly
    ``_build_spec_draft`` piped into ``_build_spec_verify`` — the draft
    chain still carries the cache functionally and discards it, the
    verify chain still commits only reference tokens — but fused into
    one dispatch: a speculative round is launch-overhead bound (two
    short chains per round), and measured wall throughput is what the
    ``benchmarks/speculative.py`` gate holds against fused decode.  The
    standalone builders above stay as the unit-testable pieces (the
    rejection-position tests drive ``_build_spec_verify`` with crafted
    draft blocks no honest draft chain would produce).
    """
    draft_fn = _build_spec_draft(model, b_kv)
    verify_fn = _build_spec_verify(model, b_kv)

    def fn(draft_weights, weights, kc, vc, ks, vs, tok, pos, live,
           n_draft, rem, eos):
        drafts = draft_fn(draft_weights, kc, vc, ks, vs, tok, pos,
                          n_draft)
        return verify_fn(weights, kc, vc, ks, vs, tok, pos, live,
                         drafts, n_draft, rem, eos)

    return fn


def _compile_spec_round(model, params, b_kv: int, batch: int,
                        t_bucket: int):
    codes, scales, vec = _cache_sds(model.cfg, b_kv, batch, t_bucket)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return aot_compile(
        _build_spec_round(model, b_kv),
        (_sds(params), _sds(params), codes, codes, scales, scales, vec,
         vec, vec, scalar, vec, scalar),
        donate_argnums=(2, 3, 4, 5, 6, 7))


def _compile_spec_draft(model, params, b_kv: int, batch: int,
                        t_bucket: int):
    codes, scales, vec = _cache_sds(model.cfg, b_kv, batch, t_bucket)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    # no donation: the canonical cache buffers must survive for verify
    return aot_compile(
        _build_spec_draft(model, b_kv),
        (_sds(params), codes, codes, scales, scales, vec, vec, scalar))


def _compile_spec_verify(model, params, b_kv: int, batch: int,
                         t_bucket: int):
    codes, scales, vec = _cache_sds(model.cfg, b_kv, batch, t_bucket)
    drafts = jax.ShapeDtypeStruct((batch, _SPEC_MAX_K), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return aot_compile(
        _build_spec_verify(model, b_kv),
        (_sds(params), codes, codes, scales, scales, vec, vec, vec,
         drafts, scalar, vec, scalar),
        donate_argnums=(1, 2, 3, 4, 5, 6))


def _container_dtype(cfg, b_kv: int) -> np.dtype:
    return np.dtype("int8") if b_kv < 16 else np.dtype(cfg.dtype)


def _cache_sds(cfg, b_kv: int, batch: int, t_bucket: int):
    cont = _container_dtype(cfg, b_kv)
    shape = (cfg.n_layers, batch, t_bucket, cfg.n_kv_heads, cfg.head_dim)
    codes = jax.ShapeDtypeStruct(shape, cont)
    scales = jax.ShapeDtypeStruct(shape[:-1], jnp.float32)
    vec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return codes, scales, vec


def _compile_prefill(model, params, b_kv: int, s_bucket: int,
                     t_bucket: int, batch: int):
    codes, scales, vec = _cache_sds(model.cfg, b_kv, batch, t_bucket)
    tokens = jax.ShapeDtypeStruct((1, s_bucket), jnp.int32)
    li = jax.ShapeDtypeStruct((1,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return aot_compile(
        _build_prefill(model, b_kv),
        (_sds(params), tokens, li, scalar, codes, codes, scales, scales,
         vec, vec),
        donate_argnums=(4, 5, 6, 7, 8, 9))


def _compile_fused(model, params, b_kv: int, batch: int, t_bucket: int):
    codes, scales, vec = _cache_sds(model.cfg, b_kv, batch, t_bucket)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return aot_compile(
        _build_fused_decode(model, b_kv),
        (_sds(params), codes, codes, scales, scales, vec, vec, vec,
         scalar, scalar),
        donate_argnums=(1, 2, 3, 4, 5, 6))


# ---------------------------------------------------------------------------
# engine internals
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _ClassState:
    """One QoS class's resolved operating point."""
    qos: QosClass
    b_hat: int
    b_eff: float                # mean agent bits (= b_hat when uniform)
    b_kv: int
    f: float
    f_server: float
    plan_key: tuple             # keys the materialized weight tree
    plan_bits: tuple
    solution: Any = None        # DecodeSolution / MixedDecodeSolution


@dataclasses.dataclass
class _Active:
    """One in-flight request occupying a decode slot."""
    req: DecodeRequest
    generated: List[int]
    admitted_s: float
    ttft_s: float
    last_emit_s: float
    itls: List[float]
    on_token: Optional[Callable]


class _Group:
    """One (QoS class, cache bucket) slot block: a fixed-width batched
    cache of ``max_batch`` decode slots at cache length ``t_bucket``.

    All buffers are ON-DEVICE jax arrays (DESIGN.md §13) that persist
    across steps and are donated to every prefill/decode executable —
    the host never copies the cache.  Inactive rows hold pos=0/token=0:
    their (garbage, row-independent) computation never escapes the row,
    and the next admission overwrites the prompt span before position 0
    is ever attended.
    """

    def __init__(self, cfg, qos_name: str, t_bucket: int, max_batch: int,
                 b_kv: int):
        self.qos_name = qos_name
        self.t_bucket = int(t_bucket)
        cont = _container_dtype(cfg, b_kv)
        shape = (cfg.n_layers, max_batch, t_bucket, cfg.n_kv_heads,
                 cfg.head_dim)
        self.k_codes = jnp.zeros(shape, cont)
        self.v_codes = jnp.zeros(shape, cont)
        self.k_scales = jnp.ones(shape[:-1], jnp.float32)
        self.v_scales = jnp.ones(shape[:-1], jnp.float32)
        self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.tok = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[_Active]] = [None] * max_batch
        self.barrier_open = True

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class DecodeEngine:
    """Continuous-batching greedy decode over quantized KV-cache slots.

    ``classes`` are resolved at construction: per class one
    ``solve_decode`` (or ``allocate_bits_decode`` under
    ``mixed_precision``) picks (b̂ or a per-layer plan, f, f̃, b_kv); the
    class's agent partition is then materialized once as a
    fake-quantized weight tree (``runtime.qat.fake_quantize_agent``,
    memoized across classes on the plan key).  Construction raises
    ``ValueError`` for an infeasible class, matching
    ``BatchedCoInferenceEngine``.  ``auto=False`` skips the solve
    (default operating point b̂=8/b_kv=8 at max frequencies) so tests
    and calibration runs can pin operating points via
    :meth:`set_operating_point`.

    ``admission`` picks the scheduling policy on otherwise identical
    code: ``"continuous"`` admits into any free slot every step and
    retires mid-flight; ``"barrier"`` refills a slot block only once it
    has fully drained (the FIFO-barrier baseline the benchmark beats).

    ``eos_id`` (optional) retires a request at its first emission of
    that token: the fused chunk executable exits early once every live
    slot has hit it, and the host truncates the row's stream there.
    """

    def __init__(self, model, params, sysp: SystemParams, *,
                 classes: Sequence[QosClass],
                 max_batch: int = 4,
                 max_new_tokens: int = 16,
                 admission: str = "continuous",
                 mixed_precision: bool = False,
                 kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                 kv_weight: float = 1.0,
                 b_emb: Optional[int] = None,
                 auto: bool = True,
                 lam: Optional[float] = None,
                 lam_kv: Optional[float] = None,
                 eos_id: Optional[int] = None,
                 codesign_cache: Optional[CodesignCache] = None,
                 compile_cache: Optional[CompiledForwardCache] = None,
                 seq_bucket_base: int = DEFAULT_SEQ_BASE,
                 tracer=None, metrics=None):
        gap = decode_protocol_gap(model)
        if gap is not None:
            raise TypeError(f"{type(model).__name__} {gap}; the decode "
                            "engine needs the DecoderLM decode protocol "
                            "(DESIGN.md §12)")
        if admission not in ("continuous", "barrier"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if not classes:
            raise ValueError("need at least one QoS class")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.sysp = sysp
        self.split = self.cfg.split_layer
        self.max_batch = int(max_batch)
        self.max_new_tokens = int(max_new_tokens)
        self.admission = admission
        self.mixed_precision = bool(mixed_precision)
        self.kv_ladder = tuple(int(b) for b in kv_ladder)
        self.kv_weight = float(kv_weight)
        self.b_emb = b_emb
        self.eos_id = int(eos_id) if eos_id is not None else None
        self.seq_bucket_base = int(seq_bucket_base)
        self._axes = model.logical_axes()
        self.lam = float(lam) if lam is not None \
            else fit_lambda(params, self.split)
        self.lam_kv = float(lam_kv) if lam_kv is not None \
            else fit_kv_lambda(model, params)
        self.codesign_cache = codesign_cache if codesign_cache is not None \
            else CodesignCache()
        self.compile_cache = compile_cache if compile_cache is not None \
            else CompiledForwardCache()
        # observability (DESIGN.md §14): the no-op singletons by default,
        # so an uninstrumented engine pays nothing on the decode path
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_METRICS
        self._own_hits = self._own_misses = 0
        self._own_compile_hits = self._own_compile_misses = 0
        self._layer_stats: Optional[mp.LayerStats] = None
        self._weights: Dict[tuple, Any] = {}
        self._classes: Dict[str, _ClassState] = {}
        self._groups: Dict[tuple, _Group] = {}
        self._rr: List[tuple] = []          # round-robin group order
        self._queue: List[DecodeRequest] = []
        self._on_token: Dict[int, Optional[Callable]] = {}
        self._next_rid = 0
        self._clock = 0.0
        self._energy = 0.0
        self._prefills = 0
        self._rounds = 0
        self._served = 0
        self._cancelled = 0
        self._tokens_out = 0
        self._kv_bytes = 0
        self._kv_bytes_full = 0
        self._h2d = 0
        self._d2h = 0
        self._class_lat: Dict[str, Dict[str, list]] = {}
        for c in classes:
            if auto:
                self._resolve_class(c)
            else:
                self._classes[c.name] = None  # placeholder until set below
                self.set_operating_point(c.name, 8, 8, qos=c)
            self._class_lat[c.name] = {"ttft": [], "itl": [], "tokens": []}

    # ------------------------------------------------------------------
    # operating points
    # ------------------------------------------------------------------
    def flop_split(self, tokens: int):
        """(agent_flops, server_flops) for ``tokens`` positions —
        ``CoInferenceEngine.flop_split``'s exact accounting."""
        per_layer = self.cfg.active_param_count() / max(self.cfg.n_layers, 1)
        n_agent = 2.0 * per_layer * self.split * tokens
        n_server = 2.0 * per_layer * (self.cfg.n_layers - self.split) \
            * tokens
        return n_agent, n_server

    def layer_stats(self) -> mp.LayerStats:
        if self._layer_stats is None:
            self._layer_stats = mp.decoder_layer_stats(self.params,
                                                       self.split)
        return self._layer_stats

    def _resolve_class(self, c: QosClass) -> None:
        b_max = int(self.sysp.b_full)
        h0, m0 = self.codesign_cache.hits, self.codesign_cache.misses
        if self.mixed_precision:
            sol = self.codesign_cache.solve_decode_mixed(
                self.layer_stats(), self.lam_kv, self.sysp, c, b_max,
                b_emb=self.b_emb, kv_ladder=self.kv_ladder,
                kv_weight=self.kv_weight)
        else:
            sol = self.codesign_cache.solve_decode(
                self.lam, self.lam_kv, self.sysp, c, b_max,
                b_emb=self.b_emb, kv_ladder=self.kv_ladder,
                kv_weight=self.kv_weight)
        dh = self.codesign_cache.hits - h0
        dm = self.codesign_cache.misses - m0
        self._own_hits += dh
        self._own_misses += dm
        if dh:
            self.metrics.counter("codesign.cache_hits",
                                 engine="DecodeEngine", qos=c.name).inc(dh)
        if dm:
            self.metrics.counter("codesign.cache_misses",
                                 engine="DecodeEngine", qos=c.name).inc(dm)
        if sol is None:
            raise ValueError(
                f"QoS class {c.name!r} (T0={c.t0}, E0={c.e0}) is "
                "infeasible at every KV-cache bit-width "
                f"{self.kv_ladder}")
        target = mp.plan_from_bits(sol.inner.bits) \
            if self.mixed_precision else sol.b_hat
        self._classes[c.name] = None
        self.set_operating_point(c.name, target, sol.b_kv,
                                 f=sol.f, f_server=sol.f_server,
                                 qos=c, solution=sol)

    def set_operating_point(self, qos_name: str, target, b_kv: int, *,
                            f: Optional[float] = None,
                            f_server: Optional[float] = None,
                            qos: Optional[QosClass] = None,
                            solution=None) -> None:
        """Pin a class's (weights bit target, b_kv, frequencies).

        ``target`` is a uniform b̂ (int) or a :class:`QuantPlan` over the
        agent partition.  Must be called before the class's first
        admission — live slots hold caches produced under the previous
        weights.  Materialized weight trees are memoized on the plan
        key, so classes sharing a plan share one tree.
        """
        if qos is None:
            prev = self._classes.get(qos_name)
            if prev is None:
                raise KeyError(f"unknown QoS class {qos_name!r}")
            qos = prev.qos
        b_kv = int(b_kv)
        if b_kv < 2:
            raise ValueError(f"b_kv={b_kv} below the 2-bit floor")
        if isinstance(target, QuantPlan):
            plan_key = target.key()
            b_eff = float(target.mean_bits(self.split))
            b_hat = int(round(b_eff))
            plan_bits = tuple(target.layer_bit_list(self.split))
            qcfg: Any = target
        else:
            b_hat = int(target)
            b_eff = float(b_hat)
            plan_key = ("uniform", b_hat)
            plan_bits = ()
            qcfg = QuantConfig(bits=b_hat, scheme="uniform",
                               granularity="per-channel")
        if plan_key not in self._weights:
            self._weights[plan_key] = fake_quantize_agent(
                self.params, self._axes, self.cfg, qcfg, ste=False)
        self._classes[qos_name] = _ClassState(
            qos=qos, b_hat=b_hat, b_eff=b_eff, b_kv=b_kv,
            f=float(f) if f is not None else self.sysp.f_max,
            f_server=float(f_server) if f_server is not None
            else self.sysp.f_server_max,
            plan_key=plan_key, plan_bits=plan_bits, solution=solution)

    def solution_for(self, qos_name: str):
        """The class's decode codesign solution (None when pinned)."""
        return self._classes[qos_name].solution

    def b_kv_for(self, qos_name: str) -> int:
        return self._classes[qos_name].b_kv

    def class_params(self, qos_name: str):
        """The class's materialized (fake-quantized) weight tree — what
        the sequential reference must decode with for parity."""
        return self._weights[self._classes[qos_name].plan_key]

    # ------------------------------------------------------------------
    # executables
    # ------------------------------------------------------------------
    def _cached(self, key: tuple, build: Callable,
                plan: str = "", bucket: str = ""):
        cc = self.compile_cache
        h0, m0 = cc.hits, cc.misses
        if key in cc:
            exe = cc.get(key, build)
        else:
            # one XLA compile: traced + timed under its (plan, bucket)
            # attribution (DESIGN.md §14)
            with self.tracer.span("xla.compile", plan=plan, bucket=bucket):
                t0 = time.monotonic()
                exe = cc.get(key, build)
                self.metrics.histogram(
                    "compile.seconds", plan=plan,
                    bucket=bucket).observe(time.monotonic() - t0)
        dh, dm = cc.hits - h0, cc.misses - m0
        self._own_compile_hits += dh
        self._own_compile_misses += dm
        if dh:
            self.metrics.counter("compile.cache_hits",
                                 engine="DecodeEngine").inc(dh)
        if dm:
            self.metrics.counter("compile.cache_misses",
                                 engine="DecodeEngine").inc(dm)
        return exe

    def _prefill_exe(self, c: _ClassState, s_bucket: int, t_bucket: int):
        return self._cached(
            ("decode-prefill", self.cfg, s_bucket, t_bucket,
             self.max_batch, c.b_kv),
            lambda: _compile_prefill(self.model, self.params, c.b_kv,
                                     s_bucket, t_bucket, self.max_batch),
            plan=f"decode-prefill/bkv{c.b_kv}",
            bucket=f"{s_bucket}->{t_bucket}x{self.max_batch}")

    def _decode_exe(self, c: _ClassState, t_bucket: int):
        return self._cached(
            ("decode-fused", self.cfg, self.max_batch, t_bucket, c.b_kv),
            lambda: _compile_fused(self.model, self.params, c.b_kv,
                                   self.max_batch, t_bucket),
            plan=f"decode-fused/bkv{c.b_kv}",
            bucket=f"{t_bucket}x{self.max_batch}")

    def warmup(self, max_prompt: int, max_new: Optional[int] = None) -> int:
        """Precompile every reachable variant; returns the number of XLA
        compiles this triggered.  Prefill executables are keyed on the
        (prompt bucket, cache bucket) PAIR — the in-executable scatter
        makes the slot block's shape part of the graph — so the reachable
        set is every s <= t from the two ladders, plus one fused-chunk
        executable per cache bucket, times the classes' b_kv rungs.
        After a warmup covering the traffic's prompt/generation bounds,
        steady-state serving never compiles (asserted by tests and
        ``benchmarks/decode.py``)."""
        m0 = self._own_compile_misses
        mn = int(max_new) if max_new is not None else self.max_new_tokens
        for c in self._classes.values():
            t_rungs = seq_ladder(max_prompt + mn, self.seq_bucket_base)
            for t in t_rungs:
                self._decode_exe(c, t)
            for s in seq_ladder(max_prompt, self.seq_bucket_base):
                for t in t_rungs:
                    if t >= s:
                        self._prefill_exe(c, s, t)
        return self._own_compile_misses - m0

    # ------------------------------------------------------------------
    # queue API
    # ------------------------------------------------------------------
    def submit(self, tokens, qos: str,
               max_new_tokens: Optional[int] = None,
               arrival_s: Optional[float] = None,
               on_token: Optional[Callable] = None) -> int:
        """Queue a prompt; returns its request id.

        ``on_token(request_id, token, t_s)`` streams each generated
        token at its virtual emission time."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty prompt")
        if qos not in self._classes:
            raise KeyError(f"unknown QoS class {qos!r}")
        m = int(max_new_tokens) if max_new_tokens is not None \
            else self.max_new_tokens
        if m < 1:
            raise ValueError("max_new_tokens must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        arr = float(arrival_s) if arrival_s is not None else self._clock
        self._queue.append(DecodeRequest(
            request_id=rid, tokens=toks, qos=qos, max_new_tokens=m,
            arrival_s=arr))
        self._on_token[rid] = on_token
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        return sum(g.active_count() for g in self._groups.values())

    @property
    def clock_s(self) -> float:
        return self._clock

    def request_bucket(self, req: DecodeRequest) -> int:
        """A request's cache bucket — a pure function of its OWN prompt
        length and generation budget (never of its batch-mates), which
        is what makes batched and sequential reductions shape-identical
        and therefore bitwise comparable."""
        return int(seq_bucket(req.tokens.size + req.max_new_tokens,
                              self.seq_bucket_base))

    def cancel(self, request_id: int) -> Optional[DecodeResponse]:
        """Retire a request mid-decode (or drop it from the queue).

        Frees the slot immediately — the next admission reuses it —
        and returns the partial response; None if the id is unknown or
        already retired."""
        for i, r in enumerate(self._queue):
            if r.request_id == request_id:
                del self._queue[i]
                self._cancelled += 1
                self._on_token.pop(request_id, None)
                return DecodeResponse(
                    request_id=request_id, qos=r.qos,
                    tokens=np.zeros((0,), np.int32),
                    prompt_len=r.tokens.size,
                    b_kv=self._classes[r.qos].b_kv,
                    ttft_s=float("nan"), itl_mean_s=0.0,
                    finished_s=self._clock, cancelled=True)
        for g in self._groups.values():
            for i, act in enumerate(g.slots):
                if act is not None and act.req.request_id == request_id:
                    return self._retire(g, i, cancelled=True)
        return None

    # ------------------------------------------------------------------
    # supervisor hooks (DESIGN.md §15)
    # ------------------------------------------------------------------
    def fast_forward(self, t_s: float) -> None:
        """Advance the virtual clock to ``t_s`` (never backwards) — the
        supervisor's hook for billing fault wait time (backoff sleeps,
        server repair windows) on the same clock every modeled cost
        lands on."""
        self._clock = max(self._clock, float(t_s))

    def decode_round_cost(self, qos_name: str, t_bucket: int):
        """Public (seconds, joules) of one fused decode step for the
        class at cache bucket ``t_bucket`` — what the supervisor bills
        per token when it finishes a recovered request through the
        sequential reference instead of a slot block."""
        return self._round_cost(self._classes[qos_name], int(t_bucket))

    def snapshot_request(self, request_id: int) -> Optional[dict]:
        """Freeze one in-flight request into a host-side snapshot the
        sequential reference can resume bitwise.

        The per-slot slice of the group's device buffers IS the
        reference's batch-width-1 state: the kernels are row-independent,
        so slot ``s`` of a ``max_batch``-wide cache holds exactly what a
        width-1 run over the same request holds, and
        ``greedy_decode_reference(state=...)`` continues it
        token-for-token (the crash-recovery contract of DESIGN.md §15,
        proven in ``tests/test_fault_tolerance.py``).  Returns None for
        unknown, still-queued, or already-retired ids — only in-flight
        requests have cache state to save.
        """
        for g in self._groups.values():
            for slot, act in enumerate(g.slots):
                if act is None or act.req.request_id != request_id:
                    continue
                c = self._classes[act.req.qos]
                state = {
                    "k_codes": np.asarray(g.k_codes[:, slot:slot + 1]),
                    "v_codes": np.asarray(g.v_codes[:, slot:slot + 1]),
                    "k_scales": np.asarray(g.k_scales[:, slot:slot + 1]),
                    "v_scales": np.asarray(g.v_scales[:, slot:slot + 1]),
                    "pos": np.int32(np.asarray(g.pos)[slot]),
                    "last_token": np.int32(np.asarray(g.tok)[slot]),
                    "t_bucket": np.int32(g.t_bucket),
                }
                self._d2h += sum(getattr(v, "nbytes", 0)
                                 for v in state.values())
                return {"request": act.req, "qos": act.req.qos,
                        "b_kv": c.b_kv, "generated": list(act.generated),
                        "ttft_s": act.ttft_s, "itls": list(act.itls),
                        "last_emit_s": act.last_emit_s,
                        "t_bucket": int(g.t_bucket), "state": state}
        return None

    # ------------------------------------------------------------------
    # the decode loop
    # ------------------------------------------------------------------
    def step(self, max_decode_steps: Optional[int] = None) \
            -> List[DecodeResponse]:
        """One engine round: admit what the policy allows, then run one
        fused decode chunk for the next non-empty slot block
        (round-robin).  Returns the requests that retired.

        The chunk is clamped so it never overruns a scheduling boundary
        (see :meth:`_decode_round`); ``max_decode_steps`` caps it
        further — ``max_decode_steps=1`` reproduces the one-token-per-
        step cadence (used by tests that interleave cancel/step).
        """
        out: List[DecodeResponse] = []
        if self.in_flight == 0 and self._queue:
            nxt = min(r.arrival_s for r in self._queue)
            if nxt > self._clock:
                self._clock = nxt         # fast-forward an idle engine
        self._admit(out)
        g = self._next_group()
        if g is not None:
            self._decode_round(g, out, max_decode_steps)
        return out

    def drain(self) -> List[DecodeResponse]:
        out: List[DecodeResponse] = []
        while self._queue or self.in_flight:
            out.extend(self.step())
        return out

    def _group_for(self, req: DecodeRequest) -> _Group:
        t = self.request_bucket(req)
        key = (req.qos, t)
        if key not in self._groups:
            self._groups[key] = _Group(self.cfg, req.qos, t,
                                       self.max_batch,
                                       self._classes[req.qos].b_kv)
            self._rr.append(key)
        return self._groups[key]

    def _admit(self, out: List[DecodeResponse]) -> None:
        admitted = True
        while admitted:
            admitted = False
            for qi, req in enumerate(self._queue):
                if req.arrival_s > self._clock:
                    continue
                g = self._group_for(req)
                if self.admission == "barrier" and not g.barrier_open:
                    continue
                slot = g.free_slot()
                if slot is None:
                    continue
                del self._queue[qi]
                self._prefill_into(g, slot, req, out)
                admitted = True
                break
        if self.admission == "barrier":
            for g in self._groups.values():
                if g.active_count() > 0:
                    g.barrier_open = False

    def _prefill_into(self, g: _Group, slot: int, req: DecodeRequest,
                      out: List[DecodeResponse]) -> None:
        c = self._classes[req.qos]
        p_len = req.tokens.size
        s_bucket = int(seq_bucket(p_len, self.seq_bucket_base))
        self.tracer.instant("decode.admit", rid=req.request_id,
                            qos=req.qos, slot=slot, prompt_len=p_len,
                            t_bucket=g.t_bucket)
        padded = np.zeros((1, s_bucket), np.int32)
        padded[0, :p_len] = req.tokens
        exe = self._prefill_exe(c, s_bucket, g.t_bucket)
        with self.tracer.span("decode.prefill", rid=req.request_id,
                              qos=req.qos, s_bucket=s_bucket,
                              t_bucket=g.t_bucket):
            (tok0, g.k_codes, g.v_codes, g.k_scales, g.v_scales, g.pos,
             g.tok) = exe(
                self._weights[c.plan_key], jnp.asarray(padded),
                jnp.asarray([p_len - 1], jnp.int32),
                jnp.asarray(slot, jnp.int32),
                g.k_codes, g.v_codes, g.k_scales, g.v_scales, g.pos, g.tok)
            first = int(np.asarray(tok0)[0])
        # the only host<->device traffic an admission causes: the padded
        # prompt + two scalars in, the streamed first token out
        self._h2d += padded.nbytes + 8
        self._d2h += 4
        # bill the prefill at its bucketed workload, sequentially on the
        # virtual clock (prefills occupy the same accelerator)
        t_pre, e_pre = self._prefill_cost(c, s_bucket)
        self._clock += t_pre
        self._energy += e_pre
        self._prefills += 1
        shape = (self.cfg.n_layers, 1, g.t_bucket, self.cfg.n_kv_heads,
                 self.cfg.head_dim)
        self._kv_bytes += 2 * kv_cache_bytes(shape, c.b_kv)
        self._kv_bytes_full += int(2 * np.prod(shape)
                                   * self.sysp.b_full / 8.0)
        act = _Active(req=req, generated=[first],
                      admitted_s=self._clock,
                      ttft_s=self._clock - req.arrival_s,
                      last_emit_s=self._clock, itls=[],
                      on_token=self._on_token.pop(req.request_id, None))
        g.slots[slot] = act
        m = self.metrics
        if m.enabled:
            m.counter("decode.prefills", engine="DecodeEngine",
                      qos=req.qos).inc()
            m.counter("decode.h2d_bytes",
                      engine="DecodeEngine").inc(padded.nbytes + 8)
            m.counter("decode.d2h_bytes", engine="DecodeEngine").inc(4)
            m.histogram("decode.ttft_s", engine="DecodeEngine",
                        qos=req.qos).observe(act.ttft_s)
        if act.on_token is not None:
            act.on_token(req.request_id, first, self._clock)
        if len(act.generated) >= req.max_new_tokens:
            out.append(self._retire(g, slot))

    def _next_group(self) -> Optional[_Group]:
        for _ in range(len(self._rr)):
            key = self._rr.pop(0)
            self._rr.append(key)
            g = self._groups[key]
            if g.active_count() > 0:
                return g
        return None

    def _chunk_steps(self, g: _Group, t_round: float,
                     max_steps: Optional[int]) -> int:
        """How many fused steps this chunk may run: the tightest of the
        live slots' remaining budgets (the chunk then ends exactly at
        the first retirement), the next queued arrival (so admission
        timing matches one-token-at-a-time stepping), the fixed output
        block width, and the caller's cap."""
        rem = min(a.req.max_new_tokens - len(a.generated)
                  for a in g.slots if a is not None)
        k = max(1, min(rem, _CHUNK))
        future = [r.arrival_s for r in self._queue
                  if r.arrival_s > self._clock]
        if future:
            due = (min(future) - self._clock) / max(t_round, 1e-12)
            k = min(k, max(1, int(math.ceil(due))))
        if max_steps is not None:
            k = min(k, max(1, int(max_steps)))
        return k

    def _decode_round(self, g: _Group, out: List[DecodeResponse],
                      max_steps: Optional[int] = None) -> None:
        c = self._classes[g.qos_name]
        t_round, e_round = self._round_cost(c, g.t_bucket)
        k = self._chunk_steps(g, t_round, max_steps)
        live = np.zeros((self.max_batch,), np.int32)
        live_rows = [i for i, a in enumerate(g.slots) if a is not None]
        live[live_rows] = 1
        eos = self.eos_id if self.eos_id is not None else -1
        exe = self._decode_exe(c, g.t_bucket)
        with self.tracer.span("decode.chunk", qos=g.qos_name,
                              live_rows=len(live_rows),
                              t_bucket=g.t_bucket, max_steps=k):
            (blk, steps, g.k_codes, g.v_codes, g.k_scales, g.v_scales,
             g.tok, g.pos) = exe(
                self._weights[c.plan_key], g.k_codes, g.v_codes,
                g.k_scales, g.v_scales, g.tok, g.pos, jnp.asarray(live),
                jnp.asarray(eos, jnp.int32), jnp.asarray(k, jnp.int32))
            blk = np.asarray(blk)
            steps = int(steps)
        # the only host<->device traffic a chunk causes, independent of
        # the cache size: the live mask + two scalars in, the token
        # block + step count out
        self._h2d += live.nbytes + 8
        self._d2h += blk.nbytes + 4
        m = self.metrics
        if m.enabled:
            m.counter("decode.chunks", engine="DecodeEngine",
                      qos=g.qos_name).inc()
            m.counter("decode.chunk_steps", engine="DecodeEngine",
                      qos=g.qos_name).inc(steps)
            m.counter("decode.h2d_bytes",
                      engine="DecodeEngine").inc(live.nbytes + 8)
            m.counter("decode.d2h_bytes",
                      engine="DecodeEngine").inc(blk.nbytes + 4)
            m.gauge("decode.live_rows", engine="DecodeEngine",
                    qos=g.qos_name).set(len(live_rows))
        clock0 = self._clock
        self._clock += steps * t_round
        self._energy += steps * e_round
        self._rounds += steps
        finished: List[int] = []
        done = set()
        for j in range(steps):
            t_emit = clock0 + (j + 1) * t_round
            for i in live_rows:
                if i in done:
                    continue
                act = g.slots[i]
                tok_ij = int(blk[i, j])
                act.generated.append(tok_ij)
                act.itls.append(t_emit - act.last_emit_s)
                act.last_emit_s = t_emit
                if act.on_token is not None:
                    act.on_token(act.req.request_id, tok_ij, t_emit)
                if (self.eos_id is not None and tok_ij == self.eos_id) \
                        or len(act.generated) >= act.req.max_new_tokens:
                    done.add(i)
                    finished.append(i)
        for i in finished:
            out.append(self._retire(g, i))

    def _retire(self, g: _Group, slot: int,
                cancelled: bool = False) -> DecodeResponse:
        act = g.slots[slot]
        g.slots[slot] = None
        g.pos = g.pos.at[slot].set(0)
        g.tok = g.tok.at[slot].set(0)
        if g.active_count() == 0:
            g.barrier_open = True
        c = self._classes[act.req.qos]
        itl = float(np.mean(act.itls)) if act.itls else 0.0
        if cancelled:
            self._cancelled += 1
        else:
            self._served += 1
            lat = self._class_lat[act.req.qos]
            lat["ttft"].append(act.ttft_s)
            lat["itl"].extend(act.itls)
            lat["tokens"].append(len(act.generated))
        self._tokens_out += len(act.generated)
        self.tracer.instant("decode.retire", rid=act.req.request_id,
                            qos=act.req.qos, tokens=len(act.generated),
                            cancelled=cancelled)
        m = self.metrics
        if m.enabled:
            m.counter("decode.retired", engine="DecodeEngine",
                      qos=act.req.qos).inc()
            m.counter("decode.tokens", engine="DecodeEngine",
                      qos=act.req.qos).inc(len(act.generated))
            # per-token ITL, observed in one batch at retirement so the
            # hot emission loop above stays instrument-free
            h = m.histogram("decode.itl_s", engine="DecodeEngine",
                            qos=act.req.qos)
            for v in act.itls:
                h.observe(v)
        return DecodeResponse(
            request_id=act.req.request_id, qos=act.req.qos,
            tokens=np.asarray(act.generated, np.int32),
            prompt_len=act.req.tokens.size, b_kv=c.b_kv,
            ttft_s=act.ttft_s, itl_mean_s=itl,
            finished_s=act.last_emit_s, cancelled=cancelled)

    # ------------------------------------------------------------------
    # billing
    # ------------------------------------------------------------------
    def _prefill_cost(self, c: _ClassState, s_bucket: int):
        n_a, n_s = self.flop_split(s_bucket)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s)
        t = float(agent_delay(c.b_eff, c.f, p)) \
            + float(server_delay(c.f_server, p))
        e = float(agent_energy(c.b_eff, c.f, p)) \
            + float(server_energy(c.f_server, p))
        return t, e

    def _round_cost(self, c: _ClassState, t_bucket: int):
        """One decode step over the FULL slot block: all ``max_batch``
        rows and the whole [L, B, T] cache read at b_kv are billed
        whether or not every slot is live — padding is compute/traffic
        the hardware really runs, which is exactly the waste continuous
        admission exists to avoid.  A fused chunk of k steps bills k of
        these."""
        n_a, n_s = self.flop_split(self.max_batch)
        kv_full = 2.0 * self.cfg.n_layers * self.max_batch * t_bucket \
            * self.cfg.n_kv_heads * self.cfg.head_dim \
            * (self.sysp.b_full / 8.0)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s, kv_bytes_full=kv_full)
        t = float(agent_delay(c.b_eff, c.f, p)) \
            + float(server_delay(c.f_server, p)) \
            + float(kv_delay(c.b_kv, p))
        e = float(agent_energy(c.b_eff, c.f, p)) \
            + float(server_energy(c.f_server, p)) \
            + float(kv_energy(c.b_kv, p))
        return t, e

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def report(self) -> DecodeReport:
        classes = []
        for name, c in self._classes.items():
            lat = self._class_lat[name]
            itls = np.asarray(lat["itl"], np.float64)
            classes.append(ClassDecodeStats(
                qos=name, b_hat=c.b_hat, b_kv=c.b_kv,
                requests=len(lat["ttft"]),
                tokens=int(sum(lat["tokens"])),
                ttft_mean_s=float(np.mean(lat["ttft"]))
                if lat["ttft"] else 0.0,
                ttft_max_s=float(np.max(lat["ttft"]))
                if lat["ttft"] else 0.0,
                itl_mean_s=float(np.mean(itls)) if itls.size else 0.0,
                plan_bits=c.plan_bits,
                itl_p50_s=float(np.percentile(itls, 50))
                if itls.size else 0.0,
                itl_p95_s=float(np.percentile(itls, 95))
                if itls.size else 0.0))
        clock = max(self._clock, 1e-12)
        return DecodeReport(
            requests_served=self._served, cancelled=self._cancelled,
            tokens_generated=self._tokens_out, prefills=self._prefills,
            decode_rounds=self._rounds, total_delay_s=self._clock,
            total_energy_j=self._energy,
            throughput_tps=self._tokens_out / clock,
            throughput_rps=self._served / clock,
            admission=self.admission, classes=tuple(classes),
            kv_bytes=self._kv_bytes, kv_bytes_full=self._kv_bytes_full,
            codesign_hits=self._own_hits,
            codesign_misses=self._own_misses,
            compile_hits=self._own_compile_hits,
            compile_misses=self._own_compile_misses,
            compiled_variants=self.compile_cache.compiled_variants,
            h2d_bytes=self._h2d, d2h_bytes=self._d2h)


# ---------------------------------------------------------------------------
# the non-batched sequential reference
# ---------------------------------------------------------------------------

def greedy_decode_reference(model, weights, tokens, max_new_tokens: int, *,
                            b_kv: int,
                            seq_bucket_base: int = DEFAULT_SEQ_BASE,
                            reserve_tokens: Optional[int] = None,
                            compile_cache: Optional[
                                CompiledForwardCache] = None,
                            state: Optional[dict] = None,
                            return_state: bool = False):
    """One request, batch width 1 — the parity oracle.

    Decodes ``max_new_tokens`` greedy tokens from ``tokens`` under the
    same bucketing, quantized-cache step (``decode_step_q`` through the
    fused while-loop executable), and prefill+scatter as
    :class:`DecodeEngine`, at batch width 1; the engine must reproduce
    its output token-for-token at any batch width, admission order, and
    chunking (the while-loop iterations are isolated sub-computations,
    so where the host cuts a chunk cannot change bits).

    ``reserve_tokens`` fixes the cache bucket from a larger planned
    generation budget (``T = seq_bucket(prompt + reserve)``) so a
    decode can be split across calls: pass ``return_state=True``,
    serialize the returned state dict (plain numpy arrays), and resume
    by passing it back as ``state`` — the continuation is bitwise the
    uninterrupted run, which is how decode state survives an elastic
    re-mesh (``tests/test_elastic.py``).
    """
    cfg = model.cfg
    cache = compile_cache if compile_cache is not None \
        else CompiledForwardCache()
    cont = _container_dtype(cfg, b_kv)
    out: List[int] = []
    if state is None:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        p_len = toks.size
        if p_len == 0:
            raise ValueError("empty prompt")
        t_bucket = int(seq_bucket(
            p_len + (reserve_tokens if reserve_tokens is not None
                     else max_new_tokens), seq_bucket_base))
        s_bucket = int(seq_bucket(p_len, seq_bucket_base))
        padded = np.zeros((1, s_bucket), np.int32)
        padded[0, :p_len] = toks
        shape = (cfg.n_layers, 1, t_bucket, cfg.n_kv_heads, cfg.head_dim)
        k_codes = jnp.zeros(shape, cont)
        v_codes = jnp.zeros(shape, cont)
        k_scales = jnp.ones(shape[:-1], jnp.float32)
        v_scales = jnp.ones(shape[:-1], jnp.float32)
        pos = jnp.zeros((1,), jnp.int32)
        tok = jnp.zeros((1,), jnp.int32)
        exe = cache.get(
            ("decode-prefill", cfg, s_bucket, t_bucket, 1, b_kv),
            lambda: _compile_prefill(model, weights, b_kv, s_bucket,
                                     t_bucket, 1))
        tok0, k_codes, v_codes, k_scales, v_scales, pos, tok = exe(
            weights, jnp.asarray(padded),
            jnp.asarray([p_len - 1], jnp.int32), jnp.asarray(0, jnp.int32),
            k_codes, v_codes, k_scales, v_scales, pos, tok)
        out.append(int(np.asarray(tok0)[0]))
        remaining = max_new_tokens - 1
    else:
        k_codes = jnp.asarray(np.asarray(state["k_codes"]))
        v_codes = jnp.asarray(np.asarray(state["v_codes"]))
        k_scales = jnp.asarray(np.asarray(state["k_scales"]))
        v_scales = jnp.asarray(np.asarray(state["v_scales"]))
        pos = jnp.asarray([int(state["pos"])], jnp.int32)
        tok = jnp.asarray([int(state["last_token"])], jnp.int32)
        t_bucket = int(state["t_bucket"])
        remaining = max_new_tokens
    live = jnp.ones((1,), jnp.int32)
    eos = jnp.asarray(-1, jnp.int32)
    while remaining > 0:
        exe = cache.get(
            ("decode-fused", cfg, 1, t_bucket, b_kv),
            lambda: _compile_fused(model, weights, b_kv, 1, t_bucket))
        blk, steps, k_codes, v_codes, k_scales, v_scales, tok, pos = exe(
            weights, k_codes, v_codes, k_scales, v_scales, tok, pos,
            live, eos, jnp.asarray(min(remaining, _CHUNK), jnp.int32))
        blk = np.asarray(blk)
        steps = int(steps)
        out.extend(int(blk[0, j]) for j in range(steps))
        remaining -= steps
    result = np.asarray(out, np.int32)
    if return_state:
        return result, {"k_codes": np.asarray(k_codes),
                        "v_codes": np.asarray(v_codes),
                        "k_scales": np.asarray(k_scales),
                        "v_scales": np.asarray(v_scales),
                        "pos": np.int32(np.asarray(pos)[0]),
                        "last_token": np.int32(np.asarray(tok)[0]),
                        "t_bucket": np.int32(t_bucket)}
    return result
