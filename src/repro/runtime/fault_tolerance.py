"""**Training-side** fault tolerance: checkpoint/restart, straggler
detection, elastic re-mesh.

Everything in this module supervises the *training loop* — it restarts
sessions, not requests.  The serving-side counterpart (retry, degraded
device-only fallback, crash-recoverable decode) is
:class:`~repro.runtime.supervisor.ServingSupervisor` (DESIGN.md §15);
the two layers share :class:`~repro.obs.ReportBase` for their reports
and this module's :class:`StragglerMonitor` for slow-step/slow-batch
detection.

At 1000+ nodes the mean time between host failures drops below the job
length, so the training loop must survive: (i) host loss -> restore the
newest intact checkpoint *onto a smaller mesh* and continue; (ii) stragglers
-> detect from step-time telemetry and report/exclude; (iii) checkpoint
corruption -> manifest sha mismatch falls back to the previous step
(checkpoint/store.py).

On this single-process container the *cluster* is simulated (a
``HostSet`` of logical hosts with an injectable failure schedule), but the
recovery machinery is real: checkpoints actually round-trip through disk,
the mesh is actually rebuilt over the surviving device count, and params are
actually re-sharded via ``device_put`` with the new NamedShardings.  The
elastic test runs under ``--xla_force_host_platform_device_count=8`` and
drops from an 8-device to a 4-device mesh mid-run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import (Callable, Dict, Hashable, List, Optional,
                    Sequence)

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..obs import ReportBase


class HostFailure(RuntimeError):
    def __init__(self, host_id: int):
        super().__init__(f"host {host_id} failed")
        self.host_id = host_id


@dataclasses.dataclass
class HostSet:
    """Simulated cluster membership with failure injection."""

    n_hosts: int
    fail_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    # step -> host_id to kill at that step

    def __post_init__(self):
        self.alive = list(range(self.n_hosts))

    def check(self, step: int) -> None:
        if step in self.fail_at:
            host = self.fail_at.pop(step)
            if host in self.alive:
                self.alive.remove(host)
                raise HostFailure(host)

    @property
    def n_alive(self) -> int:
        return len(self.alive)


@dataclasses.dataclass
class StragglerMonitor:
    """Per-lane duration telemetry with a relative deadline.

    A lane is flagged when its reported duration exceeds ``factor`` x
    the rolling median across lanes.  Training feeds it per-host step
    times (lane = host id); serving reuses it unchanged for slow-batch
    detection (lane = QoS class or fleet agent name — any hashable id
    works).  Real pods feed this from per-host heartbeats; the tests
    feed synthetic durations.
    """

    factor: float = 3.0
    window: int = 20

    def __post_init__(self):
        self._times: Dict[Hashable, List[float]] = {}

    def report(self, lane: Hashable, duration_s: float) -> None:
        self._times.setdefault(lane, []).append(duration_s)
        self._times[lane] = self._times[lane][-self.window:]

    def stragglers(self) -> List[Hashable]:
        if not self._times:
            return []
        meds = {h: float(np.median(t)) for h, t in self._times.items()
                if t}
        global_med = float(np.median(list(meds.values())))
        if global_med <= 0:
            return []
        return [h for h, m in meds.items() if m > self.factor * global_med]


@dataclasses.dataclass
class SupervisorReport(ReportBase):
    steps_run: int
    restarts: int
    failures: List[int]
    stragglers_seen: List[int]
    final_step: int
    remesh_history: List[int]   # device count after each (re)build


class Supervisor:
    """Wraps a restartable **training** session (serving has its own
    :class:`~repro.runtime.supervisor.ServingSupervisor`).

    The user supplies ``make_session(n_devices) -> session`` where a session
    exposes ``run(steps) -> None`` (raising on failure), ``step`` (current
    step), and persists through the shared ``CheckpointManager``.  On a
    ``HostFailure`` the supervisor rebuilds the session over the surviving
    hosts (elastic re-mesh + checkpoint restore happen inside
    ``make_session``) and resumes until the target step count is reached.
    """

    def __init__(self, make_session: Callable[[int], "object"],
                 hosts: HostSet,
                 monitor: Optional[StragglerMonitor] = None,
                 max_restarts: int = 8):
        self.make_session = make_session
        self.hosts = hosts
        self.monitor = monitor or StragglerMonitor()
        self.max_restarts = max_restarts

    def run(self, target_steps: int) -> SupervisorReport:
        restarts = 0
        failures: List[int] = []
        remesh: List[int] = []
        session = self.make_session(self.hosts.n_alive)
        remesh.append(self.hosts.n_alive)
        while session.step < target_steps:
            try:
                session.run_until(target_steps, self.hosts)
            except HostFailure as e:
                failures.append(e.host_id)
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                if self.hosts.n_alive == 0:
                    raise RuntimeError("no hosts left") from e
                # elastic re-mesh over the survivors + restore
                session = self.make_session(self.hosts.n_alive)
                remesh.append(self.hosts.n_alive)
        return SupervisorReport(
            steps_run=session.step, restarts=restarts, failures=failures,
            stragglers_seen=self.monitor.stragglers(),
            final_step=session.step, remesh_history=remesh)
