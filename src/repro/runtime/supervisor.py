"""Resilient serving under injected faults (DESIGN.md §15).

:class:`ServingSupervisor` wraps any of the four serving engines —
:class:`~repro.runtime.serve_engine.BatchedCoInferenceEngine`,
:class:`~repro.runtime.adaptive.AdaptiveCoInferenceEngine`,
:class:`~repro.runtime.decode_engine.DecodeEngine`, and
:class:`~repro.runtime.fleet_engine.FleetCoInferenceEngine` — and
mediates a seeded :class:`~repro.env.faults.ChaosTrace` between the
client and the engine, entirely on the engines' virtual clocks: fault
handling *bills time* (backoff sleeps, retransmits, repair windows,
degraded service) through the same clock the cost model bills serving
on, so a supervised run is deterministic and replayable.

The state machine per scheduling boundary (one ``step()``):

1. **shed** — a queued request whose deadline has already passed even
   under instantaneous service is dropped (``shed`` instant).  A
   feasible request is never shed.
2. **unreachable** (link outage or server preemption) — seeded
   exponential backoff with jitter probes until the path returns
   (``retry`` instants).  Past the retry budget, prefill-style engines
   **fail over to device-only serving**: the codesign re-solves with
   the split pinned fully on-agent
   (:func:`~repro.core.codesign.solve_device_only`) and the batch is
   served and billed at that degraded operating point
   (``failover.local`` span).  A decode engine instead snapshots every
   in-flight request (:meth:`DecodeEngine.snapshot_request`), waits out
   the window, and resumes each through the sequential reference —
   the resumed stream is **bitwise identical** to an uninterrupted run
   (``recover.restore`` instants; proven in
   ``tests/test_fault_tolerance.py``).
3. **corruption** — the chaos trace marks which uplink payloads arrive
   bit-flipped; detection is the CRC-32 :func:`payload_checksum` over
   the payload bytes (any single flip changes it —
   ``tests/test_chaos.py``), and the supervisor bills one retransmit
   and serves clean.
4. **fleet churn** — a dropout/rejoin edge triggers exactly one
   re-water-filling of the server shares
   (:meth:`FleetCoInferenceEngine.reallocate`); churn is bounded by
   membership edges, never by steps.

The house invariant extends here: on a fault-free trace (or with no
trace at all) every ``step()`` is a pure delegation — no rng draw, no
fault lookup — so the supervised engine is **bitwise identical** to
the bare engine and inside the §14 3% overhead budget
(``benchmarks/chaos.py`` gates both).

An *unsupervised* baseline (``supervised=False``) applies the same
physics with none of the defenses: requests touched by a fault fail,
in-flight decode state is lost on a crash.  ``benchmarks/chaos.py``
compares the two on one seeded trace.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from ..core import codesign as cd
from ..env.faults import ChaosTrace, FaultState
from ..obs import NULL_METRICS, NULL_TRACER, ReportBase
from .decode_engine import (DecodeEngine, DecodeResponse,
                            greedy_decode_reference)
from .fault_tolerance import StragglerMonitor
from .fleet_engine import FleetCoInferenceEngine

__all__ = ["ServingSupervisor", "ResilienceReport", "payload_checksum",
           "flip_bit"]


def payload_checksum(payload) -> int:
    """CRC-32 over a payload's bytes — the uplink integrity check of
    DESIGN.md §15.  Cheap (one pass, no crypto), order-sensitive, and
    any single bit-flip changes it, which is all detect-and-retransmit
    needs; collisions only matter adversarially, and the link is not an
    adversary here."""
    arr = np.ascontiguousarray(np.asarray(payload))
    return zlib.crc32(arr.tobytes()) & 0xFFFFFFFF


def flip_bit(payload, bit_index: int) -> np.ndarray:
    """Return a copy of ``payload`` with one bit flipped — the
    corruption model of :class:`~repro.env.faults.PacketCorruption`,
    used by tests and ``benchmarks/chaos.py`` to prove
    :func:`payload_checksum` detects every single-bit error."""
    arr = np.ascontiguousarray(np.asarray(payload))
    flat = np.frombuffer(arr.tobytes(), np.uint8).copy()
    flat[bit_index // 8] ^= np.uint8(1 << (bit_index % 8))
    return np.frombuffer(flat.tobytes(), arr.dtype).reshape(arr.shape)


@dataclasses.dataclass(frozen=True)
class ResilienceReport(ReportBase):
    """What a supervised (or bare) run delivered, lost, and spent
    (the §15 sibling of ``EngineReport``/``SupervisorReport``)."""

    mode: str                   # "supervised" | "bare"
    engine: str                 # wrapped engine class name
    clean: bool                 # fault-free trace -> pure pass-through
    requests_total: int         # submitted through the supervisor
    delivered: int              # responses handed to the client
    failed: int                 # lost to faults (bare mode, mostly)
    shed: int                   # dropped: deadline already unmeetable
    retries: int                # backoff probes while unreachable
    retransmits: int            # checksum-detected corrupt payloads
    failovers: int              # batches served device-only
    recoveries: int             # decode requests resumed after a crash
    reallocations: int          # fleet re-water-fillings (churn bound)
    faults_seen: int            # fault edges encountered
    stragglers_seen: int        # slow-batch flags (StragglerMonitor)
    tokens_delivered: int       # decode only (0 for prefill engines)
    tokens_lost: int            # must be 0 supervised (gated)
    tokens_duplicated: int      # must be 0 always (gated)
    clock_s: float              # final virtual clock / fleet makespan
    goodput: float              # delivered work per virtual second
    goodput_unit: str           # "tokens/s" (decode) | "requests/s"


_CLEAN = FaultState(t_s=0.0)


class ServingSupervisor:
    """Fault-mediating wrapper around one serving engine.

    Parameters
    ----------
    engine:
        A built Batched/Adaptive/Decode/Fleet engine.  The supervisor
        owns its stepping; submit and step through the supervisor.
    chaos:
        The :class:`ChaosTrace` to run under; ``None`` (or a clean
        trace) selects the pass-through fast path.
    supervised:
        ``False`` builds the unsupervised baseline: same fault physics,
        no retry/failover/recovery/shedding — faults lose work.
    seed:
        Seeds the backoff-jitter stream (``SeedSequence``-spawned, so
        runs are replayable).
    max_retries:
        Backoff probes before a prefill engine fails over to
        device-only serving.  Decode never fails over mid-stream (its
        KV split is pinned); it keeps probing at the capped delay.
    deadline_factor:
        A request's hard deadline is ``arrival + factor * T0`` — shed
        only when the deadline has passed (service time could not
        matter), never speculatively.
    """

    def __init__(self, engine, *, chaos: Optional[ChaosTrace] = None,
                 supervised: bool = True, seed: int = 0,
                 max_retries: int = 3, backoff_base_s: float = 0.05,
                 backoff_jitter: float = 0.5,
                 retransmit_penalty_s: float = 0.02,
                 deadline_factor: float = 8.0, shed: bool = True,
                 straggler_factor: float = 3.0,
                 max_decode_steps: Optional[int] = None,
                 tracer=None, metrics=None):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.engine = engine
        self.chaos = chaos
        self.supervised = bool(supervised)
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_jitter = float(backoff_jitter)
        self.retransmit_penalty_s = float(retransmit_penalty_s)
        self.deadline_factor = float(deadline_factor)
        self.shed_enabled = bool(shed)
        # fault-check cadence for decode: faults are observed at engine
        # scheduling boundaries, so the per-step chunk bounds how much
        # virtual time passes between trace lookups.  Under a faulty
        # trace the default is 1 — every inter-token boundary observes
        # the trace (an unbounded chunk could tunnel through a whole
        # outage); chunking does not change the tokens (PR-6 invariant),
        # only where the supervisor may interrupt.  Clean traces keep
        # the engine's own chunking unless overridden.
        self.max_decode_steps = max_decode_steps
        self.tracer = tracer if tracer is not None else \
            getattr(engine, "tracer", NULL_TRACER)
        self.metrics = metrics if metrics is not None else \
            getattr(engine, "metrics", NULL_METRICS)
        self._rng = np.random.default_rng(np.random.SeedSequence(seed))
        self._is_decode = isinstance(engine, DecodeEngine)
        self._is_fleet = isinstance(engine, FleetCoInferenceEngine)
        # pass-through: decided ONCE, so the clean path never pays a
        # per-step fault lookup (the §15 identity + overhead contract)
        self.clean = chaos is None or chaos.is_clean()
        # slow-batch detection: the training-era StragglerMonitor reused
        # verbatim — a "host" here is a QoS class / fleet agent, and a
        # "step" is one billed engine round
        self.straggler = StragglerMonitor(factor=straggler_factor)
        self._prev = _CLEAN
        self._expected: Dict[int, int] = {}   # rid -> generation budget
        self._failed_rids = set()
        # counters
        self._submitted = 0
        self._delivered = 0
        self._failed = 0
        self._shed = 0
        self._retries = 0
        self._retransmits = 0
        self._failovers = 0
        self._recoveries = 0
        self._faults = 0
        self._tokens_delivered = 0
        self._tokens_lost = 0
        self._tokens_dup = 0
        self._device_only: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    # submit (records per-request expectations for loss accounting)
    # ------------------------------------------------------------------
    def submit(self, *args, **kwargs) -> int:
        """Delegates to the engine's ``submit`` (same signature per
        engine kind) and records the request's generation budget so the
        report can prove zero lost / zero duplicated tokens."""
        rid = self.engine.submit(*args, **kwargs)
        self._submitted += 1
        if self._is_decode:
            m = kwargs.get("max_new_tokens")
            if m is None and len(args) >= 3:
                m = args[2]
            self._expected[rid] = int(m) if m is not None \
                else self.engine.max_new_tokens
        return rid

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self, *args, **kwargs):
        if self.clean:
            # pure delegation (the identity + overhead contract), with
            # counter-only accounting so the report stays meaningful
            if self._is_decode and not args and "max_decode_steps" \
                    not in kwargs:
                args = (self.max_decode_steps,)
            res = self.engine.step(*args, **kwargs)
            self._account_clean(res)
            return res
        if self._is_decode:
            return self._step_decode(*args, **kwargs)
        if self._is_fleet:
            return self._step_fleet()
        return self._step_batched()

    def drain(self, max_steps: int = 100_000):
        """Step until every queue is empty; ``max_steps`` is a safety
        net against a trace whose tail never recovers (the supervisor
        fails the stranded requests before giving up)."""
        out: List[Any] = []
        for _ in range(max_steps):
            if self._is_decode:
                if not (self.engine.pending or self.engine.in_flight):
                    break
                out.extend(self.step())
            elif self._is_fleet:
                if not self.engine.pending():
                    break
                name, responses = self.step()
                out.extend(responses)
            else:
                if not self.engine._queue:
                    break
                out.extend(self.step())
        return out

    def _account_clean(self, res) -> None:
        """Counters only — never touches the responses themselves."""
        if self._is_fleet:
            responses = res[1]
        else:
            responses = res
        for r in responses:
            if getattr(r, "cancelled", False):
                continue
            self._delivered += 1
            if self._is_decode:
                self._tokens_delivered += int(r.tokens.size)
                self._expected.pop(r.request_id, None)

    # ------------------------------------------------------------------
    # shared fault machinery
    # ------------------------------------------------------------------
    def _edge(self, f: FaultState, t_s: float) -> None:
        """Emit one ``fault.inject`` per rising fault edge (a 40-step
        outage is one fault, not 40)."""
        p = self._prev
        for kind, bad_now, bad_prev in (
                ("outage", not f.link_up, not p.link_up),
                ("corruption", f.corrupt, p.corrupt),
                ("preemption", not f.server_up, not p.server_up)):
            if bad_now and not bad_prev:
                self._faults += 1
                self.tracer.instant("fault.inject", kind=kind,
                                    t_s=round(t_s, 6))
                self.metrics.counter("chaos.faults", kind=kind).inc()
        if f.agents_up != p.agents_up and any(
                not a and b for a, b in zip(f.agents_up, p.agents_up)):
            self._faults += 1
            self.tracer.instant("fault.inject", kind="dropout",
                                t_s=round(t_s, 6))
            self.metrics.counter("chaos.faults", kind="dropout").inc()
        self._prev = f

    def _probe(self, t_s: float, check, budget: Optional[int]):
        """Seeded exponential backoff with jitter from ``t_s`` until
        ``check(fault_at(t))`` holds.  Returns the recovery time, or
        None when the budget runs out first or the trace's clamped tail
        never recovers.  Every probe costs virtual time and emits a
        ``retry`` instant."""
        tw, k = t_s, 0
        while True:
            f = self.chaos.fault_at(tw)
            if check(f):
                return tw
            if tw >= self.chaos.end_s:
                return None          # permanent within this trace
            if budget is not None and k >= budget:
                return None          # budget exhausted -> caller decides
            d = self.backoff_base_s * (2.0 ** min(k, self.max_retries))
            d *= 1.0 + self.backoff_jitter * float(self._rng.random())
            tw += d
            k += 1
            self._retries += 1
            self.tracer.instant("retry", attempt=k, t_s=round(tw, 6))

    def _t0(self, qos: str) -> float:
        if self._is_decode:
            return self.engine._classes[qos].qos.t0
        return self.engine.classes[qos].t0

    def _shed_pass(self, now_s: float) -> None:
        """Drop queued requests whose hard deadline has already passed:
        ``now > arrival + factor * T0`` means even zero-delay service
        would miss, so shedding can never sacrifice a feasible
        request."""
        eng = self.engine
        for r in list(eng._queue):
            if r.arrival_s > now_s:
                continue
            deadline = r.arrival_s + self.deadline_factor * self._t0(r.qos)
            if now_s > deadline:
                eng.cancel(r.request_id)
                self._shed += 1
                self._expected.pop(r.request_id, None)
                self.tracer.instant("shed", rid=r.request_id, qos=r.qos,
                                    deadline_s=round(deadline, 6),
                                    t_s=round(now_s, 6))
                self.metrics.counter("chaos.shed", qos=r.qos).inc()

    # ------------------------------------------------------------------
    # decode engine
    # ------------------------------------------------------------------
    def _live_rids(self) -> List[int]:
        return [a.req.request_id for g in self.engine._groups.values()
                for a in g.slots if a is not None]

    def _step_decode(self, max_decode_steps: Optional[int] = None
                     ) -> List[DecodeResponse]:
        if max_decode_steps is None:
            max_decode_steps = self.max_decode_steps \
                if self.max_decode_steps is not None else 1
        eng = self.engine
        out: List[DecodeResponse] = []
        # mirror the engine's idle fast-forward so the fault lookup sees
        # the time the engine will actually run at
        if eng.in_flight == 0 and eng.pending:
            nxt = min(r.arrival_s for r in eng._queue)
            eng.fast_forward(nxt)
        t = eng.clock_s
        f = self.chaos.fault_at(t)
        self._edge(f, t)

        if not f.server_up:
            # server crash: recover (supervised) or lose the in-flight
            # work (bare), then wait out the repair window
            if self.supervised:
                out.extend(self._recover_decode(t))
            else:
                self._crash_fail_inflight()
                eng.fast_forward(self.chaos.next_server_up(t))
            return out

        if not f.link_up:
            if self.supervised:
                t_up = self._probe(t, lambda fv: fv.link_up, budget=None)
                if t_up is None:
                    self._abandon_decode("link never recovered")
                    return out
                eng.fast_forward(t_up)
            else:
                # the bare engine pushes through a dark uplink: every
                # in-flight stream takes garbage into its cache
                for rid in self._live_rids():
                    self._failed_rids.add(rid)
                eng.fast_forward(self.chaos.next_link_up(t))
        elif f.corrupt:
            if self.supervised:
                # checksum mismatch on the boundary payload -> bill one
                # retransmit and serve the clean copy
                self._retransmits += 1
                self.tracer.instant("retry", kind="retransmit",
                                    t_s=round(t, 6))
                self.metrics.counter("chaos.retransmits").inc()
                eng.fast_forward(t + self.retransmit_penalty_s)
            else:
                for rid in self._live_rids():
                    self._failed_rids.add(rid)

        if self.supervised and self.shed_enabled:
            self._shed_pass(eng.clock_s)
        c0 = eng.clock_s
        responses = eng.step(max_decode_steps)
        if eng.clock_s > c0:
            self.straggler.report("decode", eng.clock_s - c0)
        for r in responses:
            self._account_decode(r, out)
        return out

    def _account_decode(self, r: DecodeResponse,
                        out: List[DecodeResponse]) -> None:
        if r.request_id in self._failed_rids:
            self._failed_rids.discard(r.request_id)
            self._failed += 1
            self._tokens_lost += int(r.tokens.size)
            self._expected.pop(r.request_id, None)
            return
        exp = self._expected.pop(r.request_id, None)
        self._delivered += 1
        self._tokens_delivered += int(r.tokens.size)
        if exp is not None:
            if r.tokens.size > exp:
                self._tokens_dup += int(r.tokens.size) - exp
            elif r.tokens.size < exp and self.engine.eos_id is None:
                # without EOS the only legitimate stop is the budget
                self._tokens_lost += exp - int(r.tokens.size)
        out.append(r)

    def _crash_fail_inflight(self) -> None:
        for rid in self._live_rids():
            resp = self.engine.cancel(rid)
            self._failed += 1
            if resp is not None:
                self._tokens_lost += int(resp.tokens.size)
            self._expected.pop(rid, None)

    def _abandon_decode(self, why: str) -> None:
        """The trace's clamped tail never recovers: fail whatever is
        stranded rather than spinning forever."""
        self._crash_fail_inflight()
        for r in list(self.engine._queue):
            self.engine.cancel(r.request_id)
            self._failed += 1
            self._expected.pop(r.request_id, None)
        self.tracer.instant("fault.inject", kind="abandon", reason=why)

    def _recover_decode(self, t_s: float) -> List[DecodeResponse]:
        """Crash-recoverable decode: snapshot -> wait -> restore.

        Each in-flight request's per-slot cache state is snapshot
        (host-side numpy), its slot freed, the repair window waited out
        on the virtual clock, and the stream finished through
        ``greedy_decode_reference(state=...)`` — billed per token at
        the class's round cost.  The stitched stream is bitwise the
        uninterrupted run: zero tokens lost, zero duplicated."""
        eng = self.engine
        out: List[DecodeResponse] = []
        t_up = self.chaos.next_server_up(t_s)
        snaps = []
        for rid in self._live_rids():
            snap = eng.snapshot_request(rid)
            if snap is not None:
                snaps.append(snap)
                eng.cancel(rid)   # frees the slot; partial not delivered
        if t_up >= self.chaos.end_s and not \
                self.chaos.fault_at(self.chaos.end_s).server_up:
            # the server never comes back within this trace
            for s in snaps:
                self._failed += 1
                self._tokens_lost += len(s["generated"])
                self._expected.pop(s["request"].request_id, None)
            self._abandon_decode("server never restarted")
            return out
        eng.fast_forward(t_up)
        for s in snaps:
            req = s["request"]
            remaining = req.max_new_tokens - len(s["generated"])
            toks = list(s["generated"])
            if remaining > 0:
                resumed = greedy_decode_reference(
                    eng.model, eng.class_params(s["qos"]), req.tokens,
                    remaining, b_kv=s["b_kv"],
                    seq_bucket_base=eng.seq_bucket_base,
                    compile_cache=eng.compile_cache, state=s["state"])
                toks.extend(int(x) for x in resumed)
                t_round, e_round = eng.decode_round_cost(s["qos"],
                                                         s["t_bucket"])
                eng.fast_forward(eng.clock_s + remaining * t_round)
                eng._energy += remaining * e_round
            self._recoveries += 1
            self.tracer.instant("recover.restore", rid=req.request_id,
                                resumed=max(0, remaining),
                                t_s=round(eng.clock_s, 6))
            self.metrics.counter("chaos.recoveries").inc()
            itl = float(np.mean(s["itls"])) if s["itls"] else 0.0
            self._account_decode(DecodeResponse(
                request_id=req.request_id, qos=s["qos"],
                tokens=np.asarray(toks, np.int32),
                prompt_len=req.tokens.size, b_kv=s["b_kv"],
                ttft_s=s["ttft_s"], itl_mean_s=itl,
                finished_s=eng.clock_s, cancelled=False), out)
        return out

    # ------------------------------------------------------------------
    # batched / adaptive engines
    # ------------------------------------------------------------------
    def _step_batched(self) -> List[Any]:
        eng = self.engine
        if not eng._queue:
            return []
        t = max(eng.clock_s, eng._queue[0].arrival_s)
        f = self.chaos.fault_at(t)
        self._edge(f, t)
        if self.supervised and self.shed_enabled:
            self._shed_pass(t)
            if not eng._queue:
                return []
            t = max(eng.clock_s, eng._queue[0].arrival_s)

        if not f.server_reachable:
            if not self.supervised:
                return self._deliver_batched(self._timed_step(), ok=False)
            t_up = self._probe(t, lambda fv: fv.server_reachable,
                               budget=self.max_retries)
            if t_up is not None:
                eng.fast_forward(t_up)
                return self._deliver_batched(self._timed_step(), ok=True)
            return self._failover_batched(t)

        if f.corrupt:
            if not self.supervised:
                return self._deliver_batched(self._timed_step(), ok=False)
            self._retransmits += 1
            self.tracer.instant("retry", kind="retransmit",
                                t_s=round(t, 6))
            self.metrics.counter("chaos.retransmits").inc()
            responses = self._timed_step()
            eng.fast_forward(eng.clock_s + self.retransmit_penalty_s)
            return self._deliver_batched(responses, ok=True)

        return self._deliver_batched(self._timed_step(), ok=True)

    def _timed_step(self):
        eng = self.engine
        c0 = eng.clock_s
        responses = eng.step()
        if responses and eng.clock_s > c0:
            self.straggler.report(responses[0].stats.qos,
                                  eng.clock_s - c0)
        return responses

    def _deliver_batched(self, responses, ok: bool) -> List[Any]:
        if ok:
            self._delivered += len(responses)
            return responses
        self._failed += len(responses)
        return []

    def _device_only_solution(self, qos: str):
        sol = self._device_only.get(qos)
        if sol is None:
            eng = self.engine
            c = eng.classes[qos]
            sol = cd.solve_device_only(eng.engine.lam, eng.sysp,
                                       c.t0, c.e0,
                                       b_max=int(eng.sysp.b_full))
            self._device_only[qos] = sol
        return sol

    def _failover_batched(self, t_s: float) -> List[Any]:
        """Degraded device-only service: the head batch is served and
        billed with the split pinned fully on-agent at the best
        feasible bit-width (DESIGN.md §15) — the agent keeps acting
        instead of holding work for a server that is not coming back
        soon."""
        eng = self.engine
        qos = eng._queue[0].qos
        sol = self._device_only_solution(qos)
        saved = (eng.sysp, eng.engine.sysp,
                 eng._solutions[qos], eng._plans.pop(qos, None))
        pl = cd.device_only_params(eng.sysp)
        with self.tracer.span("failover.local", qos=qos,
                              b_hat=sol.b_hat,
                              feasible=bool(sol.feasible)):
            eng.sysp = pl
            eng.engine.sysp = pl
            eng._solutions[qos] = sol
            try:
                responses = self._timed_step()
            finally:
                eng.sysp, eng.engine.sysp = saved[0], saved[1]
                eng._solutions[qos] = saved[2]
                if saved[3] is not None:
                    eng._plans[qos] = saved[3]
        self._failovers += 1
        self.metrics.counter("chaos.failovers", qos=qos).inc()
        return self._deliver_batched(responses, ok=True)

    # ------------------------------------------------------------------
    # fleet engine
    # ------------------------------------------------------------------
    def _membership(self, f: FaultState) -> set:
        """Desired active set: agents_up index i maps to spec i; a trace
        built with fewer agents than the fleet leaves the rest up."""
        specs = self.engine.specs
        up = f.agents_up
        return {spec.name for i, spec in enumerate(specs)
                if i >= len(up) or up[i]}

    def _step_fleet(self):
        eng = self.engine
        frontier = max(e.clock_s for e in eng.engines.values())
        f = self.chaos.fault_at(frontier)
        self._edge(f, frontier)
        desired = self._membership(f)
        if self.supervised and desired and desired != eng._active:
            # one reallocation per membership edge — the churn bound
            eng.reallocate([s.name for s in eng.specs
                            if s.name in desired])
        if self.supervised:
            # nothing serveable now, but a dropped member holds work:
            # advance to its rejoin instead of spinning
            active_pending = sum(eng.engines[n].pending()
                                 for n in eng.active_agents)
            if active_pending == 0 and eng.pending():
                waiting = [i for i, s in enumerate(eng.specs)
                           if s.name not in eng._active
                           and eng.engines[s.name].pending()]
                t_next = min(self.chaos.next_agent_up(i, frontier)
                             for i in waiting)
                if t_next >= self.chaos.end_s:
                    for i in waiting:   # stranded: never rejoins
                        member = eng.engines[eng.specs[i].name]
                        for r in list(member._queue):
                            member.cancel(r.request_id)
                            self._failed += 1
                    return None, []
                for e in eng.engines.values():
                    e.fast_forward(t_next)
                return None, []
            name, responses = eng.step()
            if responses:
                self.straggler.report(name, max(
                    r.stats.batch_delay_s for r in responses))
            self._delivered += len(responses)
            return name, responses
        # bare fleet: scheduling ignores membership — a batch served on
        # an absent agent is work the clients never receive
        name, responses = eng.step()
        if name is None:
            return name, responses
        present = name in self._membership(
            self.chaos.fault_at(eng.engines[name].clock_s))
        if present:
            self._delivered += len(responses)
            return name, responses
        self._failed += len(responses)
        return name, []

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def report(self) -> ResilienceReport:
        if self._is_fleet:
            clock = max(e.clock_s for e in self.engine.engines.values())
        else:
            clock = self.engine.clock_s
        if self._is_decode:
            goodput = self._tokens_delivered / clock if clock > 0 else 0.0
            unit = "tokens/s"
        else:
            goodput = self._delivered / clock if clock > 0 else 0.0
            unit = "requests/s"
        return ResilienceReport(
            mode="supervised" if self.supervised else "bare",
            engine=type(self.engine).__name__,
            clean=self.clean,
            requests_total=self._submitted,
            delivered=self._delivered,
            failed=self._failed,
            shed=self._shed,
            retries=self._retries,
            retransmits=self._retransmits,
            failovers=self._failovers,
            recoveries=self._recoveries,
            reallocations=getattr(self.engine, "_reallocations", 0),
            faults_seen=self._faults,
            stragglers_seen=len(self.straggler.stragglers()),
            tokens_delivered=self._tokens_delivered,
            tokens_lost=self._tokens_lost,
            tokens_duplicated=self._tokens_dup,
            clock_s=float(clock),
            goodput=goodput,
            goodput_unit=unit)
