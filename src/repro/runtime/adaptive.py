"""Online adaptive co-inference serving (DESIGN.md §9).

:class:`AdaptiveCoInferenceEngine` extends the batched engine with a
closed loop over a dynamic environment (``repro.env``): before each
batch it observes the environment at the virtual-clock decision instant,
detects drift, and — policy permitting — re-solves the class's operating
point ((P1) or the layer-wise allocation) against the *quantized*
environment state through the extended ``CodesignCache``; realized
delay/energy are then billed against the *unquantized* current state
with the plan's frequencies clipped to the thermal cap, so accounting
reflects what the hardware would actually do, plan lag included.

Three policies share the one serving path (``benchmarks/adaptive_serve``
compares them on identical request streams):

* ``static``   — solve once under the initial state, never replan; the
                 environment still bills it (frequency caps clip f).
* ``adaptive`` — quantized-state drift detection + realized-QoS-miss
                 monitoring, debounced by ``hysteresis_steps`` and
                 ``min_replan_interval_s``, so re-quantization churn is
                 bounded: one replan needs that many consecutive
                 discrepant observations, and a boundary-oscillating
                 state never sustains a streak.
* ``oracle``   — re-solve on every change of the *exact* per-step state:
                 the clairvoyant per-step upper bound (no hysteresis, no
                 quantization).

Infeasible windows degrade instead of raising: when a class's (T0,
E0·battery-scale) has no solution under the current state, the engine
falls back to the lowest-distortion plan that still meets the deadline
alone, and past that to b̂ = 1 flat out — service continues best-effort
and the violation counters tell the truth about it.

With ``environment=None`` — or any environment whose per-step state is
constant and leaves the base ``SystemParams`` unchanged — every decision
reduces to the static engine's, and responses are bitwise identical to
``BatchedCoInferenceEngine`` (tests/test_adaptive.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Literal, Optional, Sequence

from ..core import codesign as cd
from ..core import mixed_precision as mp
from ..core.cost_model import SystemParams, total_delay, total_energy
from ..env.environment import Environment, EnvState
from ..obs import ReportBase
from .serve_engine import (BatchedCoInferenceEngine, QosClass,
                           ServeResponse)

__all__ = ["AdaptiveCoInferenceEngine", "AdaptiveReport", "ReplanEvent"]


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One controller decision that re-solved a class's operating point."""
    t_s: float
    qos: str
    reason: str                 # "env-drift" | "qos-miss" | "oracle"
    env_key: tuple              # quantized state solved against
    b_before: float             # mean agent bits before/after — equal when
    b_after: float              # the new state maps to the same plan
    degraded: bool              # fell back to a best-effort plan


@dataclasses.dataclass(frozen=True)
class AdaptiveReport(ReportBase):
    """Whole-run controller accounting, complementing ``EngineReport``."""
    policy: str
    requests_served: int
    deadline_violations: int    # responses with wait + batch delay > T0
    deadline_violation_rate: float
    energy_violations: int      # batches whose per-request energy > E0
    replans: int                # controller re-solves after construction
    plan_switches: int          # replans that actually changed the plan
    degraded_batches: int       # batches served on a best-effort plan
    weight_variants: int        # distinct materialized agent weight sets
    env_keys_seen: int          # distinct quantized states observed
    hysteresis_steps: int


class AdaptiveCoInferenceEngine(BatchedCoInferenceEngine):
    """Batched co-inference serving under a dynamic environment."""

    def __init__(self, model, params, sysp: SystemParams, *,
                 classes: Sequence[QosClass],
                 environment: Optional[Environment] = None,
                 policy: Literal["static", "adaptive", "oracle"]
                 = "adaptive",
                 hysteresis_steps: int = 2,
                 min_replan_interval_s: float = 0.0,
                 **kwargs):
        if policy not in ("static", "adaptive", "oracle"):
            raise ValueError(f"unknown policy {policy!r}")
        if hysteresis_steps < 1:
            raise ValueError("hysteresis_steps must be >= 1")
        self.environment = environment
        self.policy = policy
        self.hysteresis_steps = int(hysteresis_steps)
        self.min_replan_interval_s = float(min_replan_interval_s)
        self.base_sysp = sysp
        self.replan_events: List[ReplanEvent] = []
        self._plan_keys: Dict[str, tuple] = {}
        self._drift_streak: Dict[str, int] = {}
        self._miss_streak: Dict[str, int] = {}
        self._last_replan_t: Dict[str, float] = {}
        self._env_keys_seen: set = set()
        self._violations = 0
        self._energy_violations = 0
        self._degraded_batches = 0
        super().__init__(model, params, sysp, classes=classes, **kwargs)
        # canonical per-class plans; _solutions additionally carries the
        # per-step frequency clipping applied just before each batch
        self._base_solutions: Dict[str, Any] = dict(self._solutions)

    # ------------------------------------------------------------------
    # operating-point resolution against an environment state
    # ------------------------------------------------------------------
    def _resolve_class(self, c: QosClass):
        """Constructor-time resolution of a class's operating point:
        solved under the environment's state at the (zero) clock instead
        of the static params, and degrading instead of returning None —
        so an engine whose *initial* window is infeasible still
        constructs and serves best-effort."""
        if self.environment is None:
            return super()._resolve_class(c)
        sol, key = self._solve_under(c, self.environment.state_at(
            self._clock))
        self._plan_keys[c.name] = key
        return sol

    def _observed(self, state: EnvState) -> "tuple[EnvState, tuple]":
        """What the controller sees: the exact state for the oracle, the
        quantized state for everyone else."""
        sq = state if self.policy == "oracle" else state.quantize()
        return sq, sq.key()

    def _solve_under(self, c: QosClass, state: EnvState,
                     exact: bool = False):
        """Solve class ``c`` against an environment state (quantized per
        policy); never returns None — infeasible windows degrade.

        ``exact=True`` bypasses the quantizer: used by qos-miss replans,
        where the quantized view is precisely what misled the last plan
        (e.g. a frequency cap rounded up), so re-solving on the same
        coarse key would be a cache-hit no-op — the correction must see
        the true state.
        """
        if exact:
            sq, key = state, state.key()
        else:
            sq, key = self._observed(state)
            self._env_keys_seen.add(key)
        sysp = sq.apply(self.base_sysp)
        c_eff = QosClass(c.name, c.t0, c.e0 * sq.energy_scale)
        sol = self._counted_solution(c_eff, sysp=sysp, env_key=key)
        if sol is None:
            sol = self._degraded_solution(c_eff, sysp)
        return sol, key

    def _degraded_solution(self, c: QosClass, sysp: SystemParams):
        """Best-effort fallback for an infeasible window: the largest b̂
        (lowest distortion) whose *deadline* alone is meetable — the
        energy budget is forfeit, the deadline is not — else b̂ = 1 at
        max frequencies (the fastest plan that exists).  Marked
        ``feasible=False`` so batches served on it are reported."""
        b_emb = self.engine.b_emb
        b_max = int(sysp.b_full)
        lam = self.engine.lam
        for b_hat in range(b_max, 0, -1):
            ok, f, fs, _ = cd.feasible_bitwidth(b_hat, sysp, c.t0,
                                                math.inf, b_emb=b_emb)
            if ok:
                sol = cd._pack(b_hat, f, fs, lam, sysp, feasible=False,
                               b_emb=b_emb)
                break
        else:
            sol = cd._pack(1, sysp.f_max, sysp.f_server_max, lam, sysp,
                           feasible=False, b_emb=b_emb)
        if not self.mixed_precision:
            return sol
        # mixed mode wants a per-layer allocation: spend the degraded
        # uniform b̂ as a flat budget (deadline-only feasibility already
        # collapsed the frontier to that mean)
        stats = self.engine.layer_stats()
        bits = (sol.b_hat,) * stats.n_layers
        return mp.MixedSolution(
            bits=bits, f=sol.f, f_server=sol.f_server,
            objective=mp.allocation_objective(stats, bits),
            uniform_b=sol.b_hat,
            uniform_objective=mp.uniform_objective(stats, sol.b_hat),
            mean_bits=float(sol.b_hat),
            delay=float(total_delay(sol.b_hat, sol.f, sol.f_server, sysp,
                                    b_emb=b_emb)),
            energy=float(total_energy(sol.b_hat, sol.f, sol.f_server,
                                      sysp, b_emb=b_emb)),
            feasible=False)

    # ------------------------------------------------------------------
    # the control loop
    # ------------------------------------------------------------------
    @staticmethod
    def _mean_bits(sol) -> float:
        """Mean agent bits of either solution type (plan mean when
        mixed, b̂ when uniform) — the scalar the replan log compares."""
        return float(getattr(sol, "mean_bits", None) or sol.b_hat)

    def _replan(self, name: str, t: float, state: EnvState,
                reason: str) -> None:
        """Re-solve class ``name`` against ``state`` and install the new
        plan: updates the canonical solution (and, in mixed mode, the
        class's ``QuantPlan``), resets both debounce streaks, stamps the
        replan time, and records a :class:`ReplanEvent` for the report.
        """
        c = self.classes[name]
        old = self._base_solutions[name]
        # qos-miss: the plan's quantized state still matches the world's,
        # yet deadlines are being missed — solve against the exact state
        # (a quantized re-solve would hit the cache and change nothing);
        # bookkeeping keeps the *quantized* key so drift detection stays
        # in the coarse keyspace
        sol, _ = self._solve_under(c, state, exact=reason == "qos-miss")
        _, key = self._observed(state)
        self._plan_keys[name] = key
        self._base_solutions[name] = sol
        if self.mixed_precision:
            self._plans[name] = self.engine.plan_of(sol)
        self._drift_streak[name] = 0
        self._miss_streak[name] = 0
        self._last_replan_t[name] = t
        degraded = not getattr(sol, "feasible", True)
        self.replan_events.append(ReplanEvent(
            t_s=t, qos=name, reason=reason, env_key=key,
            b_before=self._mean_bits(old), b_after=self._mean_bits(sol),
            degraded=degraded))
        self.tracer.instant("adaptive.replan", qos=name, reason=reason,
                            env_key=str(key),
                            b_before=self._mean_bits(old),
                            b_after=self._mean_bits(sol),
                            degraded=degraded)
        self.metrics.counter("adaptive.replans", engine="Adaptive",
                             qos=name, reason=reason).inc()

    def _maybe_replan(self, name: str, state: EnvState, t: float) -> None:
        """The per-batch controller decision: never for ``static``, on
        any quantized-key change for ``oracle``, and for ``adaptive``
        only after ``hysteresis_steps`` consecutive discrepant
        observations (env drift or realized QoS misses) and at most once
        per ``min_replan_interval_s`` — the debouncing that bounds
        re-quantization churn (DESIGN.md §9)."""
        if self.policy == "static":
            return
        _, key = self._observed(state)
        self._env_keys_seen.add(key)
        current = self._plan_keys.get(name)
        if self.policy == "oracle":
            if key != current:
                self._replan(name, t, state, reason="oracle")
            return
        # hysteresis: a replan needs `hysteresis_steps` *consecutive*
        # observations disagreeing with the plan's state — an oscillation
        # across a quantization boundary keeps resetting the streak and
        # never triggers (tests/test_adaptive.py)
        if key != current:
            self._drift_streak[name] = self._drift_streak.get(name, 0) + 1
            self.tracer.instant("adaptive.env_drift", qos=name,
                                env_key=str(key),
                                streak=self._drift_streak[name])
            self.metrics.counter("adaptive.drift_observations",
                                 engine="Adaptive", qos=name).inc()
        else:
            self._drift_streak[name] = 0
        drift = self._drift_streak.get(name, 0) >= self.hysteresis_steps
        miss = self._miss_streak.get(name, 0) >= self.hysteresis_steps
        if not (drift or miss):
            if key != current:
                # a drift observation the hysteresis debounce swallowed
                self.tracer.instant("adaptive.replan_suppressed",
                                    qos=name, reason="hysteresis",
                                    env_key=str(key),
                                    streak=self._drift_streak[name])
                self.metrics.counter("adaptive.replans_suppressed",
                                     engine="Adaptive", qos=name,
                                     reason="hysteresis").inc()
            return
        if t - self._last_replan_t.get(name, -math.inf) \
                < self.min_replan_interval_s:
            self.tracer.instant("adaptive.replan_suppressed", qos=name,
                                reason="min-interval", env_key=str(key))
            self.metrics.counter("adaptive.replans_suppressed",
                                 engine="Adaptive", qos=name,
                                 reason="min-interval").inc()
            return
        self._replan(name, t, state,
                     reason="env-drift" if drift else "qos-miss")

    def step(self) -> List[ServeResponse]:
        """Serve one batch under the environment: observe the state at
        the batch's earliest possible start, maybe replan (see
        :meth:`_maybe_replan`), bill the batch under the *true* current
        state with the plan's frequencies clipped to the live caps, then
        feed realized deadline/energy outcomes back into the miss
        streaks.  Reduces to ``BatchedCoInferenceEngine.step`` with no
        environment attached."""
        if self.environment is None or not self._queue:
            return super().step()
        # the decision instant: when this batch could start at the earliest
        t = max(self._clock, self._queue[0].arrival_s)
        name = self._queue[0].qos
        state = self.environment.state_at(t)
        self._maybe_replan(name, state, t)

        # bill the batch under the true (unquantized) current state; the
        # plan's frequency is clipped to the live thermal cap — a stale
        # plan runs slower, it does not run at a frequency that no longer
        # exists
        true_p = state.apply(self.base_sysp)
        self.engine.sysp = true_p
        base = self._base_solutions[name]
        self._solutions[name] = dataclasses.replace(
            base, f=min(base.f, true_p.f_max),
            f_server=min(base.f_server, true_p.f_server_max))
        responses = super().step()

        # realized-QoS monitoring on the batch that just ran
        c = self.classes[name]
        bstats = self.batch_history[-1]
        viol = sum(1 for r in responses
                   if r.stats.total_delay_s > c.t0 * (1.0 + 1e-9))
        self._violations += viol
        if bstats.amortized_energy_j > c.e0 * (1.0 + 1e-9):
            self._energy_violations += 1
        if not getattr(base, "feasible", True):
            self._degraded_batches += 1
        if viol:
            self._miss_streak[name] = self._miss_streak.get(name, 0) + 1
        else:
            self._miss_streak[name] = 0
        return responses

    # ------------------------------------------------------------------
    def solution_for(self, qos_name: str):
        """The class's *canonical* operating point (before per-step
        frequency clipping)."""
        if self.environment is None:
            return super().solution_for(qos_name)
        return self._base_solutions[qos_name]

    def adaptive_report(self) -> AdaptiveReport:
        """Controller-level accounting for the whole run — replans,
        plan switches, degraded batches, realized QoS violations,
        weight-cache growth — complementing the serving-level
        ``report()`` (``benchmarks/adaptive_serve.py`` scores policies
        on exactly these numbers)."""
        switches = sum(1 for e in self.replan_events
                       if e.b_before != e.b_after)
        wc = self.engine._weight_cache
        return AdaptiveReport(
            policy=self.policy,
            requests_served=self._served,
            deadline_violations=self._violations,
            deadline_violation_rate=self._violations / self._served
            if self._served else 0.0,
            energy_violations=self._energy_violations,
            replans=len(self.replan_events),
            plan_switches=switches,
            degraded_batches=self._degraded_batches,
            weight_variants=len(wc) if wc is not None else 0,
            env_keys_seen=len(self._env_keys_seen),
            hysteresis_steps=self.hysteresis_steps)
