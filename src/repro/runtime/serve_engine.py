"""Co-inference serving (paper §II + DESIGN.md §7): agent stage ->
embedding transport -> server stage, with the joint (b̂, f, f̃)
configuration chosen by ``core.codesign`` per QoS class.

Two engines live here:

  * :class:`CoInferenceEngine` — one request (batch tensor) at a time; the
    paper's pipeline in its simplest form.  Used directly by the tests and
    as the execution core of the batched engine.
  * :class:`BatchedCoInferenceEngine` — a request queue that groups
    in-flight requests by QoS class, pads/packs them into one batched
    agent->server forward, and amortizes the (P1) solve across the class
    via :class:`CodesignCache`.  Per-request outputs are bitwise identical
    to the sequential path (DESIGN.md §7): the forward is row-independent,
    right-padding is invisible under causal attention, and the uplink
    quantizer computes its absmax scale per request, never across the
    batch.

Execution paths for the agent stage (both accept a leading batch
dimension end-to-end, through ``kernels/ops.py`` into ``kernels/qmm.py``):

  * ``fake``    — agent layers run with fake-quantized weights
                  (quantize-dequantize at b̂); works for every model family
                  that exposes ``run_layers`` and any bit-width 1..16.
  * ``kernel``  — weights are *actually* int8/int4-resident and every agent
                  matmul dispatches ``repro.kernels`` quantized-matmul
                  (Pallas on TPU, interpret on CPU); dense DecoderLM family.
                  This is the TPU-native realization of the paper's knob:
                  HBM traffic scales with b̂/16 (DESIGN.md §3).

Embedding transport: the boundary activation is quantized at ``b_emb``
(per-tensor absmax, computed *per request*) before "transmission"; the
engine reports exact wire bytes (realizable container sizes — nibble
packing below 4 bits, int8/int16 above), so the uplink term of the cost
model is grounded.

Mixed precision (DESIGN.md §8): ``configure`` also accepts a
``QuantPlan`` assigning per-layer bits to the agent partition, with
per-layer kernel-container selection (int4-packed / int8 / fp16
fallback); ``BatchedCoInferenceEngine(mixed_precision=True)`` solves the
layer-wise allocation of ``core.mixed_precision`` per QoS class instead
of the scalar (P1), and both the codesign and weight caches key on the
resulting plan, so serving memoizes per (class, plan).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Literal, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codesign as cd
from ..core import mixed_precision as mp
from ..core.cost_model import (SystemParams, agent_delay, agent_energy,
                               server_delay, server_energy, transport_delay,
                               transport_energy)
from ..core.quantization import (QuantConfig, QuantPlan, quantize_dequantize,
                                 wire_bytes)
from ..kernels import ops as kops
from ..kernels.bucketing import (DEFAULT_SEQ_BASE, next_geometric,
                                 seq_bucket, seq_ladder)
from ..models import layers as L
from ..obs import NULL_METRICS, NULL_TRACER, OCCUPANCY_BUCKETS, ReportBase
from . import fastpath as fp
from .qat import fake_quantize_agent


# ---------------------------------------------------------------------------
# request/response records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeStats:
    b_hat: int                  # uniform b̂, or round(mean bits) of a plan
    f: float
    f_server: float
    agent_delay_s: float
    server_delay_s: float
    transport_delay_s: float
    total_delay_s: float
    energy_j: float             # compute + uplink tx energy (eqs. 6-7 + radio)
    transport_energy_j: float   # the uplink tx share of energy_j (0 unless
    emb_bytes: int              # SystemParams.tx_power_w and the link are set)
    agent_flops: float
    server_flops: float
    # wire bytes per leading batch row (sums to emb_bytes); the batched
    # engine reads a request's own uplink cost from here
    emb_row_bytes: tuple = ()
    # per-agent-layer bits when a mixed-precision plan is active (else ())
    plan_bits: tuple = ()


@dataclasses.dataclass(frozen=True)
class QosClass:
    """One (T0, E0) service class; the engine solves (P1) per class."""
    name: str
    t0: float
    e0: float


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One queued inference request (token ids + QoS class)."""
    request_id: int
    tokens: np.ndarray          # int32 [S]
    qos: str
    arrival_s: float            # virtual arrival time (queueing model)


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request accounting inside a served batch."""
    request_id: int
    qos: str
    b_hat: int
    batch_size: int
    queue_wait_s: float         # modeled wait before its batch started
    batch_delay_s: float        # forward delay of the batch it rode in
    total_delay_s: float        # queue wait + batch delay
    energy_j: float             # amortized share of the batch energy
    emb_bytes: int              # this request's uplink bytes


@dataclasses.dataclass(frozen=True)
class ServeResponse:
    request_id: int
    logits: jax.Array           # [S, vocab] — padding stripped
    stats: RequestStats


@dataclasses.dataclass(frozen=True)
class BatchStats:
    """Batch-level aggregates (DESIGN.md §7): what one fused forward cost
    and how well the batch was packed."""
    qos: str
    batch_size: int
    b_hat: int
    agent_path: str             # kernel-int8/kernel-int4/fake (what ran)
    f: float
    f_server: float
    real_tokens: int            # sum of request lengths
    padded_tokens: int          # batch_size * padded seq len
    occupancy: float            # real / padded (1.0 = no padding waste)
    batch_delay_s: float        # agent + uplink + server for the batch
    amortized_delay_s: float    # batch_delay / batch_size
    energy_j: float
    amortized_energy_j: float
    emb_bytes: int
    queue_wait_mean_s: float
    queue_wait_max_s: float
    # per-agent-layer bits when the class serves a mixed plan (else ())
    plan_bits: tuple = ()


@dataclasses.dataclass(frozen=True)
class EngineReport(ReportBase):
    """Whole-run aggregates of a :class:`BatchedCoInferenceEngine`."""
    requests_served: int
    batches_served: int
    mean_batch_size: float
    mean_occupancy: float
    total_delay_s: float        # virtual clock at the end of the run
    total_energy_j: float
    throughput_rps: float       # requests / modeled second
    codesign_hits: int          # THIS engine's cache hits (not cache-global)
    codesign_misses: int        # (P1) solves this engine actually triggered
    # compiled-fast-path counters (DESIGN.md §10); all zero when the
    # engine serves eagerly.  Hits/misses are THIS engine's own lookups
    # (the cache may be shared); every miss is exactly one XLA compile,
    # so misses <= len(bucket ladder) x active plans on warm traffic.
    # ``compiled_variants`` counts the (possibly shared) cache's entries.
    compile_hits: int = 0
    compile_misses: int = 0
    compiled_variants: int = 0


# ---------------------------------------------------------------------------
# weight statistics
# ---------------------------------------------------------------------------

def fit_lambda(params, split: int) -> float:
    """MLE λ over the agent-partition weight magnitudes (paper eq. (3)).

    Scans the stacked-layers leaves of ``params["layers"]`` (ndim >= 3,
    floating) and fits the exponential rate over layers ``[0, split)``.
    Module-level so callers that have not built an engine yet — the
    fleet allocator sizes every agent's statistic before any engine
    exists (DESIGN.md §11) — share the engines' exact definition.
    """
    total, count = 0.0, 0
    for leaf in jax.tree_util.tree_leaves(params["layers"]):
        if hasattr(leaf, "ndim") and leaf.ndim >= 3 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            sl = leaf[: min(split, leaf.shape[0])]
            total += float(jnp.sum(jnp.abs(sl)))
            count += int(np.prod(sl.shape))
    return count / max(total, 1e-30) if count else 100.0


# ---------------------------------------------------------------------------
# codesign memoization
# ---------------------------------------------------------------------------

class CodesignCache:
    """Memoizes ``(SystemParams, QosClass) -> CodesignSolution``.

    (P1) is a host-side SCA solve; per request it would dominate smoke-size
    serving.  All decision inputs — the weight statistic ``lam``, the
    hardware constants, and the class's (T0, E0) — are hashable, so one
    dict amortizes the solve across every request of a class (and across
    engines sharing the cache).  Infeasible classes are cached as ``None``
    so repeated submits fail fast.
    """

    def __init__(self):
        # values: CodesignSolution (uniform), MixedSolution (per-layer
        # plans, "mixed"-tagged keys), or None for infeasible classes
        self._store: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(lam: float, sysp: SystemParams, qos: QosClass,
            b_max: int, b_emb: Optional[int] = None,
            env_key: Optional[tuple] = None) -> tuple:
        # keyed on the numbers, not qos.name: two classes with equal
        # (T0, E0) share one solve.  ``env_key`` is the quantized
        # environment-state key of DESIGN.md §9: the adaptive engine
        # solves against an environment-adjusted SystemParams and tags
        # the entry with the coarse state it was solved under, so every
        # revisit of a quantized environment state is a cache hit.
        return (round(float(lam), 12), sysp, float(qos.t0), float(qos.e0),
                int(b_max), b_emb, env_key)

    def solve(self, lam: float, sysp: SystemParams, qos: QosClass,
              b_max: int, b_emb: Optional[int] = None,
              env_key: Optional[tuple] = None
              ) -> Optional[cd.CodesignSolution]:
        k = self.key(lam, sysp, qos, b_max, b_emb, env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = cd.solve_sca(lam, sysp, qos.t0, qos.e0,
                                          b_max=b_max, b_emb=b_emb)
        return self._store[k]

    def solve_mixed(self, stats: "mp.LayerStats", sysp: SystemParams,
                    qos: QosClass, b_max: int,
                    b_emb: Optional[int] = None,
                    env_key: Optional[tuple] = None
                    ) -> Optional[mp.MixedSolution]:
        """Memoized per-layer bit allocation (DESIGN.md §8).

        Keyed on the per-layer statistics (λ^(l), A^(l)) instead of the
        global λ — the allocation's whole decision input — in a keyspace
        disjoint from :meth:`solve`'s, so one cache serves engines in
        both modes; the resulting plan's hash then keys the engine's
        materialized-weight cache.  ``env_key`` tags entries with the
        quantized environment state, exactly as in :meth:`solve`.
        """
        k = ("mixed", stats.key(), sysp, float(qos.t0), float(qos.e0),
             int(b_max), b_emb, env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = mp.allocate_bits(stats, sysp, qos.t0, qos.e0,
                                              b_max=b_max, b_emb=b_emb)
        return self._store[k]

    def solve_decode(self, lam: float, lam_kv: float, sysp: SystemParams,
                     qos: QosClass, b_max: int,
                     b_emb: Optional[int] = None,
                     kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                     kv_weight: float = 1.0,
                     env_key: Optional[tuple] = None
                     ) -> Optional[cd.DecodeSolution]:
        """Memoized joint (b̂, f, f̃, b_kv) decode solve (DESIGN.md §12).

        Keyed alongside :meth:`solve`'s entries — same cache, disjoint
        "kv"-tagged keyspace carrying the cache statistic λ_kv and the
        container ladder next to ``b_emb`` — so decode and prefill
        engines share one memoizer."""
        k = ("kv", round(float(lam), 12), round(float(lam_kv), 12), sysp,
             float(qos.t0), float(qos.e0), int(b_max), b_emb,
             tuple(int(b) for b in kv_ladder), float(kv_weight), env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = cd.solve_decode(
                lam, lam_kv, sysp, qos.t0, qos.e0, b_max=b_max,
                b_emb=b_emb, kv_ladder=kv_ladder, kv_weight=kv_weight)
        return self._store[k]

    def solve_decode_mixed(self, stats: "mp.LayerStats", lam_kv: float,
                           sysp: SystemParams, qos: QosClass, b_max: int,
                           b_emb: Optional[int] = None,
                           kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                           kv_weight: float = 1.0,
                           env_key: Optional[tuple] = None
                           ) -> Optional[mp.MixedDecodeSolution]:
        """Memoized per-layer allocation + b_kv (the decode counterpart
        of :meth:`solve_mixed`, keyed on the layer statistics plus the
        cache inputs)."""
        k = ("kv-mixed", stats.key(), round(float(lam_kv), 12), sysp,
             float(qos.t0), float(qos.e0), int(b_max), b_emb,
             tuple(int(b) for b in kv_ladder), float(kv_weight), env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = mp.allocate_bits_decode(
                stats, lam_kv, sysp, qos.t0, qos.e0, b_max=b_max,
                b_emb=b_emb, kv_ladder=kv_ladder, kv_weight=kv_weight)
        return self._store[k]

    def solve_speculative(self, lam: float, lam_kv: float,
                          sysp: SystemParams, qos: QosClass, b_max: int,
                          b_emb: Optional[int] = None,
                          kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                          kv_weight: float = 1.0,
                          draft_ladder: "tuple[int, ...]" = (2, 4, 8),
                          lookahead: "tuple[int, ...]" = (2, 4, 8),
                          env_key: Optional[tuple] = None
                          ) -> Optional[cd.SpeculativeSolution]:
        """Memoized joint (b̂, f, f̃, b_kv, b_draft, k) speculative solve
        (DESIGN.md §16) — :meth:`solve_decode`'s keyspace pattern with a
        "spec" tag carrying the draft ladder and lookahead menu."""
        k = ("spec", round(float(lam), 12), round(float(lam_kv), 12), sysp,
             float(qos.t0), float(qos.e0), int(b_max), b_emb,
             tuple(int(b) for b in kv_ladder), float(kv_weight),
             tuple(int(b) for b in draft_ladder),
             tuple(int(b) for b in lookahead), env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = cd.solve_speculative(
                lam, lam_kv, sysp, qos.t0, qos.e0, b_max=b_max,
                b_emb=b_emb, kv_ladder=kv_ladder, kv_weight=kv_weight,
                draft_ladder=draft_ladder, lookahead=lookahead)
        return self._store[k]

    def solve_speculative_mixed(self, stats: "mp.LayerStats", lam_kv: float,
                                sysp: SystemParams, qos: QosClass,
                                b_max: int, b_emb: Optional[int] = None,
                                kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                                kv_weight: float = 1.0,
                                draft_ladder: "tuple[int, ...]" = (2, 4, 8),
                                lookahead: "tuple[int, ...]" = (2, 4, 8),
                                env_key: Optional[tuple] = None
                                ) -> Optional[mp.MixedSpeculativeSolution]:
        """Memoized per-layer allocation + (b_kv, b_draft, k) — the
        speculative counterpart of :meth:`solve_decode_mixed`."""
        k = ("spec-mixed", stats.key(), round(float(lam_kv), 12), sysp,
             float(qos.t0), float(qos.e0), int(b_max), b_emb,
             tuple(int(b) for b in kv_ladder), float(kv_weight),
             tuple(int(b) for b in draft_ladder),
             tuple(int(b) for b in lookahead), env_key)
        if k in self._store:
            self.hits += 1
        else:
            self.misses += 1
            self._store[k] = mp.allocate_bits_speculative(
                stats, lam_kv, sysp, qos.t0, qos.e0, b_max=b_max,
                b_emb=b_emb, kv_ladder=kv_ladder, kv_weight=kv_weight,
                draft_ladder=draft_ladder, lookahead=lookahead)
        return self._store[k]

    def __len__(self) -> int:
        return len(self._store)


# ---------------------------------------------------------------------------
# sequential engine
# ---------------------------------------------------------------------------

class CoInferenceEngine:
    """One agent/server pair serving a DecoderLM-family model."""

    def __init__(self, model, params, sysp: SystemParams, *,
                 lam: Optional[float] = None,
                 scheme: str = "uniform",
                 path: Literal["fake", "kernel"] = "fake",
                 b_emb: int = 8,
                 cache_weights: bool = False,
                 compiled: bool = False,
                 compile_cache: Optional[fp.CompiledForwardCache] = None,
                 seq_bucket_base: int = DEFAULT_SEQ_BASE,
                 batch_quantum: Optional[int] = None,
                 tracer=None, metrics=None):
        if not hasattr(model, "run_layers"):
            raise TypeError(
                f"{type(model).__name__} lacks run_layers; co-inference "
                "split execution needs the DecoderLM protocol")
        if compiled and not (hasattr(model, "embed")
                             and hasattr(model, "run_layers_window")):
            raise TypeError(
                f"{type(model).__name__} lacks the embed/"
                "run_layers_window hooks; the compiled fast path "
                "(DESIGN.md §10) needs both")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.sysp = sysp
        self.scheme = scheme
        self.path = path
        self.b_emb = b_emb
        self.split = self.cfg.split_layer
        # compiled fast path (DESIGN.md §10): token batches are padded to
        # the (batch quantum, seq bucket) ladder and served through one
        # AOT-compiled end-to-end executable per (plan, bucket)
        self.compiled = bool(compiled)
        self.seq_bucket_base = int(seq_bucket_base)
        self.batch_quantum = int(batch_quantum) if batch_quantum else None
        self.compile_cache = compile_cache if compile_cache is not None \
            else (fp.CompiledForwardCache() if compiled else None)
        # observability (DESIGN.md §14): default to the no-op singletons
        # so uninstrumented serving pays nothing
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_METRICS
        # this engine's own compile-cache lookups (the cache may be shared
        # across engines — same attribution discipline as CodesignCache)
        self._own_compile_hits = 0
        self._own_compile_misses = 0
        # weight key -> (segment descs, stacked arrays) for the scan path;
        # the stacked copies coexist with the per-layer records in the
        # weight cache (both bounded by the number of active plans) so a
        # plan flip re-quantizes and re-stacks nothing
        self._stacked: Dict[tuple, tuple] = {}
        self._axes = model.logical_axes()
        self.lam = float(lam) if lam is not None else self._fit_lambda()
        self.b_hat: int = 8
        # effective bit-width for the cost model: b̂ when uniform, the
        # plan's mean agent bits when mixed (layers are FLOP-homogeneous,
        # so delay/energy depend on the plan only through its mean)
        self.b_eff: float = 8.0
        self.plan: Optional[QuantPlan] = None
        self.f: float = sysp.f_max
        self.f_server: float = sysp.f_server_max
        self._agent_params = None       # set by configure()
        self._qlinears = None
        self._layer_stats: Optional[mp.LayerStats] = None
        # stable plan key -> materialized agent weights; lets the batched
        # engine flip between QoS classes (uniform b̂ *or* mixed plans)
        # without re-quantizing every batch
        self._weight_cache: Optional[Dict[tuple, tuple]] = \
            {} if cache_weights else None
        self.configure(self.b_hat, self.f, self.f_server)

    # ------------------------------------------------------------------
    def _fit_lambda(self) -> float:
        """MLE lambda over the agent-partition weight magnitudes
        (:func:`fit_lambda` over this engine's params and split)."""
        return fit_lambda(self.params, self.split)

    def flop_split(self, tokens: int):
        """(agent_flops, server_flops) for one forward over ``tokens``."""
        per_layer = self.cfg.active_param_count() / max(self.cfg.n_layers, 1)
        n_agent = 2.0 * per_layer * self.split * tokens
        n_server = 2.0 * per_layer * (self.cfg.n_layers - self.split) * tokens
        return n_agent, n_server

    # ------------------------------------------------------------------
    # configuration (the paper's decision variables)
    # ------------------------------------------------------------------
    def configure(self, b_hat, f: Optional[float] = None,
                  f_server: Optional[float] = None) -> None:
        """Set the operating point and materialize the agent weights.

        ``b_hat`` is a uniform bit-width (int, the paper's knob) or a
        :class:`QuantPlan` assigning per-layer bits to the agent
        partition (DESIGN.md §8).  A plan whose agent layers all resolve
        to one bit-width degenerates to the uniform path — same weights,
        same cache entry, bitwise-identical serving.  Materialized
        weights are memoized on the stable plan key when
        ``cache_weights`` is on.
        """
        # kernel containers are uniform-scheme group quantizers; a plan
        # asking for another scheme runs the (scheme-honoring) fake path
        kernel_ok = self.path == "kernel" and not self.cfg.n_experts
        plan = None
        if isinstance(b_hat, QuantPlan):
            plan = b_hat
            ub = plan.uniform_layer_bits(self.split)
            # Degenerate a uniform plan to the legacy int path only when
            # that path quantizes identically: the plan's scheme and
            # granularity must match what the legacy path would use, and
            # on the kernel path the width must be a legacy kernel one
            # (b̂ ∈ {4, 8}) or > 8 (fake fallback either way).  Uniform
            # plans at other widths stay plans so e.g. (6, 6) serves
            # int8-kernel-resident exactly like the neighboring (6, 7) —
            # no container or scheme cliff inside mixed-precision serving.
            same_quant = plan.scheme == self.scheme \
                and plan.granularity == "per-channel"
            plan_kernel = kernel_ok and plan.scheme == "uniform"
            if ub is not None and same_quant and \
                    (not plan_kernel or ub in (4, 8) or ub > 8):
                b_hat, plan = ub, None
        if f is not None:
            self.f = float(f)
        if f_server is not None:
            self.f_server = float(f_server)
        self.plan = plan
        if plan is None:
            self.b_hat = int(b_hat)
            self.b_eff = float(self.b_hat)
            key = ("uniform", self.b_hat)
        else:
            self.b_eff = plan.mean_bits(self.split)
            self.b_hat = int(round(self.b_eff))
            key = plan.key()
        # the stable identity of the materialized weights at this operating
        # point — the weight cache, the restacked-segment cache, and the
        # compiled-forward cache all key on it
        self._weight_key = key
        if self._weight_cache is not None and key in self._weight_cache:
            self._agent_params, self._qlinears = self._weight_cache[key]
            return
        if plan is not None:
            if kernel_ok and plan.scheme == "uniform":
                self._qlinears = self._quantize_kernel_weights(plan)
                self._agent_params = None
            else:
                self._agent_params = fake_quantize_agent(
                    self.params, self._axes, self.cfg, plan, ste=False)
                self._qlinears = None
        elif kernel_ok and self.b_hat in (4, 8):
            self._qlinears = self._quantize_kernel_weights(
                QuantPlan.uniform(self.b_hat, scheme=self.scheme))
            self._agent_params = None
        else:
            qcfg = QuantConfig(bits=self.b_hat, scheme=self.scheme,
                               granularity="per-channel")
            self._agent_params = fake_quantize_agent(
                self.params, self._axes, self.cfg, qcfg, ste=False)
            self._qlinears = None
        if self._weight_cache is not None:
            self._weight_cache[key] = (self._agent_params, self._qlinears)

    @property
    def agent_path(self) -> str:
        """The agent execution that actually materialized at the current
        operating point: ``kernel-int8``/``kernel-int4`` (HBM-resident
        Pallas matmuls), ``kernel-mixed[b0/b1/...]`` (per-layer kernel
        residency under a plan, with > 8-bit layers falling back to
        full-precision matmuls on fake-quantized weights), or ``fake``
        (quantize-dequantize).  The uniform kernel path only exists for
        dense models at b̂ ∈ {4, 8}; other uniform bit-widths silently
        fall back, so callers claiming kernel residency should check
        this."""
        if self._qlinears is not None:
            if self.plan is not None:
                bl = "/".join(str(r["bits"]) for r in self._qlinears)
                return f"kernel-mixed[{bl}]"
            return f"kernel-int{self.b_hat}"
        return "fake"

    def auto_configure(self, qos: QosClass,
                       cache: Optional[CodesignCache] = None
                       ) -> Optional[cd.CodesignSolution]:
        """Solve (P1) for this QoS class and apply the solution.

        With ``cache`` the solve is memoized on (lam, SystemParams, QosClass)
        — see :class:`CodesignCache`.
        """
        b_max = int(self.sysp.b_full)
        if cache is not None:
            sol = cache.solve(self.lam, self.sysp, qos, b_max,
                              b_emb=self.b_emb)
        else:
            sol = cd.solve_sca(self.lam, self.sysp, qos.t0, qos.e0,
                               b_max=b_max, b_emb=self.b_emb)
        if sol is None:
            return None
        self.configure(sol.b_hat, sol.f, sol.f_server)
        return sol

    # ------------------------------------------------------------------
    # mixed-precision configuration (DESIGN.md §8)
    # ------------------------------------------------------------------
    def layer_stats(self) -> mp.LayerStats:
        """Per-agent-layer (λ^(l), A^(l)), computed once and memoized —
        the allocation's whole decision input besides the cost model."""
        if self._layer_stats is None:
            self._layer_stats = mp.decoder_layer_stats(self.params,
                                                       self.split)
        return self._layer_stats

    def plan_of(self, sol: mp.MixedSolution) -> QuantPlan:
        """The :class:`QuantPlan` realizing an allocation on this engine."""
        return mp.plan_from_bits(sol.bits, scheme=self.scheme)

    def auto_configure_mixed(self, qos: QosClass,
                             cache: Optional[CodesignCache] = None
                             ) -> Optional[mp.MixedSolution]:
        """Solve the per-layer bit allocation for this QoS class and apply
        its plan (the layer-wise counterpart of :meth:`auto_configure`).

        With ``cache`` the allocation is memoized on the layer statistics
        — see :meth:`CodesignCache.solve_mixed`.
        """
        b_max = int(self.sysp.b_full)
        if cache is not None:
            sol = cache.solve_mixed(self.layer_stats(), self.sysp, qos,
                                    b_max, b_emb=self.b_emb)
        else:
            sol = mp.allocate_bits(self.layer_stats(), self.sysp, qos.t0,
                                   qos.e0, b_max=b_max, b_emb=self.b_emb)
        if sol is None:
            return None
        self.configure(self.plan_of(sol), sol.f, sol.f_server)
        return sol

    # ------------------------------------------------------------------
    # kernel-path weight prep (dense DecoderLM)
    # ------------------------------------------------------------------
    def _quantize_kernel_weights(self, plan: QuantPlan):
        """Per-layer weight records for wq/wk/wv/wo/mlp of layers [0,split).

        Layer i materializes at ``plan.layer_bits(i)`` with the kernel
        container that width admits (kernels/ops.py): bits <= 4 →
        int4-packed, 5..8 → int8 — group size 128 along the contraction
        axis, exactly what the Pallas qmm kernel consumes.  Layers wider
        than 8 bits have no quantized kernel; they store fake-quantized
        full-precision matrices applied by plain matmuls.
        """
        lp = self.params["layers"]
        out = []
        names = ["wq", "wk", "wv", "wo"]
        mlp_names = [n for n in ("wi_gate", "wi_up", "wi", "wo")
                     if n in lp["ffn"]]
        for i in range(self.split):
            bits = plan.layer_bits(i)
            rec = {"attn": {}, "ffn": {}, "bits": bits}

            def materialize(leaf):
                w = jnp.asarray(np.asarray(leaf, np.float32))
                if bits <= 8:
                    return kops.quantize_linear(w, bits=bits,
                                                group_size=plan.group_size)
                # no kernel above 8 bits: fake-quantize with the plan's
                # own scheme/granularity (what config_for_layer resolves)
                return quantize_dequantize(w, plan.config_for_layer(i))

            for n in names:
                rec["attn"][n] = materialize(lp["attn"][n][i])
            for n in mlp_names:
                rec["ffn"][n] = materialize(lp["ffn"][n][i])
            out.append(rec)
        return out

    def _stacked_segments(self):
        """Layer-stacked scan segments for the current kernel weights,
        memoized on the weight key (DESIGN.md §10)."""
        if self._weight_key not in self._stacked:
            self._stacked[self._weight_key] = \
                fp.restack_segments(self._qlinears)
        return self._stacked[self._weight_key]

    def _agent_forward_kernel(self, x, positions):
        """Dense DecoderLM agent stack with Pallas quantized matmuls.

        ``x`` is [B, S, D] for any B — the quantized-matmul wrappers flatten
        every leading dim into the kernel's M axis (kernels/ops.py).

        The stack runs as dynamic-bound loop segments over the
        layer-stacked weight records (DESIGN.md §10), one segment per
        homogeneous kernel container — the *same* loops the compiled fast
        path traces, so eager and compiled serving execute identical XLA
        sub-computations and stay bitwise equal (a runtime-bound loop body
        is never unrolled; a Python per-layer loop would instead expose
        the block's elementwise ops to context-dependent FMA contraction).
        """
        descs, arrays = self._stacked_segments()
        side = fp.layer_side_tree(self.params["layers"], self.cfg)
        for desc, seg in zip(descs, arrays):
            x = fp.scan_segment(self.cfg, desc, seg, side, x, positions,
                                jnp.int32(desc.length))
        return x

    # ------------------------------------------------------------------
    # compiled fast path (DESIGN.md §10)
    # ------------------------------------------------------------------
    def bucket_shape(self, b: int, s: int):
        """The (batch, seq) bucket a [b, s] token batch pads up to: S on
        the geometric seq ladder, B to the batch quantum (next multiple)
        or, quantum-less, to the next power of two."""
        sp = seq_bucket(s, base=self.seq_bucket_base)
        if self.batch_quantum:
            q = self.batch_quantum
            bp = -(-b // q) * q
        else:
            bp = next_geometric(b, 1)
        return bp, sp

    def _agent_repr(self):
        """(container signature, agent argument tree, segment descs) for
        the current operating point.  Kernel-resident weights are restacked
        into scan segments (memoized per weight key); the fake path ships
        its fake-quantized parameter tree whole."""
        if self._qlinears is not None:
            descs, arrays = self._stacked_segments()
            return ("kernel",) + descs, arrays, descs
        agent = self._agent_params if self._agent_params is not None \
            else self.params
        return ("fake",), agent, None

    def _compiled_executable(self, bp: int, sp: int):
        """The AOT executable for the current plan at bucket (bp, sp),
        through the compile cache (one XLA compile per miss).  Returns
        (executable, agent argument tree, runtime bounds vector).

        The key includes the (hashable) ``ModelConfig``: ``build_forward``
        bakes config constants (rope theta, window, activation, ...) into
        the executable, so a cache shared across engines over *different*
        models must never collide on a same-shaped plan/bucket.  Weights
        and parameters are call arguments and need no key entry."""
        sig, agent, descs = self._agent_repr()
        key = (self.cfg, self._weight_key, sig, (bp, sp), self.split,
               self.b_emb)
        bounds = fp.forward_bounds(descs, self.split, self.cfg.n_layers,
                                   bp)

        def build():
            fwd = fp.build_forward(self.model, self.split, self.b_emb,
                                   descs,
                                   "kernel" if descs is not None
                                   else "fake")
            return fp.compile_forward(fwd, self.params, agent, bp, sp,
                                      len(bounds))

        cc = self.compile_cache
        h0, m0 = cc.hits, cc.misses
        if key in cc:
            exe = cc.get(key, build)
        else:
            # a miss is exactly one XLA compile: trace + time it keyed by
            # (plan, bucket) — the attribution DESIGN.md §14 asks for
            plan_tag = str(self._weight_key)
            bucket_tag = f"{bp}x{sp}"
            with self.tracer.span("xla.compile", plan=plan_tag,
                                  bucket=bucket_tag):
                t0 = time.monotonic()
                exe = cc.get(key, build)
                self.metrics.histogram(
                    "compile.seconds", plan=plan_tag,
                    bucket=bucket_tag).observe(time.monotonic() - t0)
        dh, dm = cc.hits - h0, cc.misses - m0
        self._own_compile_hits += dh
        self._own_compile_misses += dm
        if dh:
            self.metrics.counter("compile.cache_hits",
                                 engine=type(self).__name__).inc(dh)
        if dm:
            self.metrics.counter("compile.cache_misses",
                                 engine=type(self).__name__).inc(dm)
        return exe, agent, bounds

    def precompile(self, batch: int, seq: int) -> None:
        """Warm the compile cache for a [batch, seq] workload at the
        current operating point without executing anything."""
        if self.compile_cache is None:
            raise RuntimeError("precompile() needs compiled=True")
        bp, sp = self.bucket_shape(batch, seq)
        self._compiled_executable(bp, sp)

    def _serve_batch_compiled(self, tokens, lengths=None):
        """Bucket-pad, run the compiled forward, bill the padded workload.

        Per-request logits are bitwise identical to the eager path: bucket
        right-padding is invisible (row independence + causal attention +
        transport masking over the padded tail, DESIGN.md §10), and the
        compiled graph runs the same ops the eager path dispatches."""
        toks = np.asarray(tokens, np.int32)
        b0, s0 = toks.shape
        lens = np.asarray(lengths, np.int64) if lengths is not None \
            else np.full((b0,), s0, np.int64)
        bp, sp = self.bucket_shape(b0, s0)
        padded = np.zeros((bp, sp), np.int32)
        padded[:b0, :s0] = toks
        lens_p = np.zeros((bp,), np.int32)
        lens_p[:b0] = lens
        exe, agent, bounds = self._compiled_executable(bp, sp)
        out = exe(self.params, agent, jnp.asarray(padded),
                  jnp.asarray(lens_p), jnp.asarray(bounds))
        logits = out[:b0, :s0]

        # uplink wire bytes per real row — the identical accounting
        # transport() returns on the eager path
        row_bytes = self._row_wire_bytes(lens)
        emb_bytes = sum(row_bytes)

        # the batch is billed at the *padded* workload — bucket padding is
        # compute the hardware really runs; occupancy accounting shows it
        n_tok = bp * sp
        n_a, n_s = self.flop_split(n_tok)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s,
                                emb_bytes_full=float(emb_bytes)
                                * 16.0 / self.b_emb)
        t_a = float(agent_delay(self.b_eff, self.f, p))
        t_s = float(server_delay(self.f_server, p))
        t_x = float(transport_delay(self.b_emb, p))
        e_x = float(transport_energy(self.b_emb, p))
        e = float(agent_energy(self.b_eff, self.f, p)
                  + server_energy(self.f_server, p)) + e_x
        stats = ServeStats(
            b_hat=self.b_hat, f=self.f, f_server=self.f_server,
            agent_delay_s=t_a, server_delay_s=t_s, transport_delay_s=t_x,
            total_delay_s=t_a + t_s + t_x, energy_j=e,
            transport_energy_j=e_x, emb_bytes=emb_bytes,
            agent_flops=n_a, server_flops=n_s, emb_row_bytes=row_bytes,
            plan_bits=(self.plan.layer_bit_list(self.split)
                       if self.plan is not None else ()))
        return logits, stats

    # ------------------------------------------------------------------
    # the two inference stages + transport
    # ------------------------------------------------------------------
    def agent_stage(self, batch: Dict[str, Any]):
        """Embedding + layers [0, split) at bit-width b̂.

        Families exposing ``run_layers_window`` (dense DecoderLM) run the
        dynamic-bound window loop — the identical sub-computation the
        compiled fast path traces (DESIGN.md §10); others keep the
        scan-based ``run_layers``."""
        src = self._agent_params if self._agent_params is not None \
            else self.params
        x, positions = self.model._embed(src, batch)
        if self._qlinears is not None:
            x = self._agent_forward_kernel(x, positions)
        elif hasattr(self.model, "run_layers_window"):
            x, _ = self.model.run_layers_window(src, x, positions,
                                                jnp.int32(0),
                                                jnp.int32(self.split))
        else:
            x, _ = self.model.run_layers(src, x, positions, 0, self.split)
        return x, positions

    def transport(self, emb: jax.Array, lengths=None):
        """Quantize the boundary activation for the uplink; returns
        (received embedding, per-row wire bytes — one entry per request).

        The absmax scale is computed *per leading batch row* — each row is
        one request's independent transmission, so its quantization must
        not depend on what else happens to share the forward (this is what
        makes batched and sequential serving bitwise identical).

        ``lengths`` (one true sequence length per row) marks right-padding
        from the batched engine: padded positions are zeroed so they cannot
        raise a row's absmax above what the request alone would see (zeros
        never exceed a row's absmax, and the padded tail is sliced off
        after the server stage), and wire bytes count only real positions.
        """
        if lengths is not None:
            real = np.asarray(lengths, np.int64)
        else:
            real = np.full((emb.shape[0],), emb.shape[1], np.int64)
        # fastpath.transport_quantize masks padded positions (real ones
        # multiply by 1.0 — bitwise no-op) and quantizes row by row; it is
        # the exact computation the compiled forward traces (DESIGN.md §10)
        emb_q = fp.transport_quantize(emb, jnp.asarray(real, jnp.int32),
                                      self.b_emb,
                                      jnp.int32(emb.shape[0]))
        return emb_q, self._row_wire_bytes(real)

    def _row_wire_bytes(self, real_lengths) -> tuple:
        """Per-request uplink wire bytes for rows of the given true
        lengths — one helper shared by the eager :meth:`transport` and the
        compiled path's host-side accounting, so the two can never drift.

        b_emb >= 16 ships the raw activation (billed at the model's
        activation dtype, == the boundary dtype on every in-tree path);
        below that, the realizable wire size (quantization.wire_bytes):
        codes of <= 4 bits ship nibble-packed via pack_int4, wider ones
        int8/int16 — not the fractional (n*bits+7)//8 idealization — plus
        one f32 absmax scale per request."""
        d = int(self.cfg.d_model)
        if self.b_emb >= 16:
            itemsize = jnp.dtype(self.cfg.dtype).itemsize
            return tuple(int(s) * d * itemsize for s in real_lengths)
        return tuple(wire_bytes(int(s) * d, self.b_emb) + 4
                     for s in real_lengths)

    def server_stage(self, emb: jax.Array, positions):
        """Layers [split, L) at full precision + head (dynamic window
        loop where the family supports it — see :meth:`agent_stage`)."""
        if hasattr(self.model, "run_layers_window"):
            x, _ = self.model.run_layers_window(
                self.params, emb, positions, jnp.int32(self.split),
                jnp.int32(self.cfg.n_layers))
        else:
            x, _ = self.model.run_layers(self.params, emb, positions,
                                         self.split, self.cfg.n_layers)
        x = L.apply_norm(self.cfg, x, self.params["final_norm"])
        return L.unembed(self.cfg, self.params["embed"], x)

    # ------------------------------------------------------------------
    def serve_batch(self, batch: Dict[str, Any], lengths=None):
        """Full co-inference pass; returns (logits, ServeStats).

        ``lengths`` flags right-padded rows (see :meth:`transport`); the
        batched engine passes each request's true length.  With
        ``compiled=True`` token-only batches run the fast path — one
        AOT-compiled bucket-padded forward, bitwise identical per request
        (DESIGN.md §10); batches carrying extra modalities fall back to
        the eager path below."""
        if self.compiled and set(batch) == {"tokens"}:
            return self._serve_batch_compiled(batch["tokens"], lengths)
        emb, positions = self.agent_stage(batch)
        emb_rx, row_bytes = self.transport(emb, lengths)
        emb_bytes = sum(row_bytes)
        logits = self.server_stage(emb_rx, positions)

        tokens = int(np.prod(positions.shape))
        n_a, n_s = self.flop_split(tokens)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s,
                                emb_bytes_full=float(emb_bytes)
                                * 16.0 / self.b_emb)
        # b_eff = b̂ for uniform serving, mean plan bits for mixed —
        # the exact linear-in-bitwidth workload of eq. (4)
        t_a = float(agent_delay(self.b_eff, self.f, p))
        t_s = float(server_delay(self.f_server, p))
        t_x = float(transport_delay(self.b_emb, p))
        e_x = float(transport_energy(self.b_emb, p))
        e = float(agent_energy(self.b_eff, self.f, p)
                  + server_energy(self.f_server, p)) + e_x
        stats = ServeStats(
            b_hat=self.b_hat, f=self.f, f_server=self.f_server,
            agent_delay_s=t_a, server_delay_s=t_s, transport_delay_s=t_x,
            total_delay_s=t_a + t_s + t_x, energy_j=e,
            transport_energy_j=e_x, emb_bytes=emb_bytes,
            agent_flops=n_a, server_flops=n_s, emb_row_bytes=row_bytes,
            plan_bits=(self.plan.layer_bit_list(self.split)
                       if self.plan is not None else ()))
        return logits, stats


# ---------------------------------------------------------------------------
# batched engine
# ---------------------------------------------------------------------------

class BatchedCoInferenceEngine:
    """Queue -> per-QoS-class batches -> one fused forward per batch.

    Scheduling (DESIGN.md §7): strict FIFO *across* classes — each step
    serves the class of the oldest pending request, pulling up to
    ``max_batch`` of that class's oldest requests into one batch.  Classes
    are never mixed inside a batch, because a batch runs at exactly one
    (b̂, f, f̃) operating point and mixing would bill one class's requests
    at another class's (T0, E0) configuration.

    Requests are right-padded to the longest sequence in their batch
    (invisible under causal attention) and their logits are sliced back to
    the true length, so per-request outputs are bitwise identical to
    serving each request alone through :class:`CoInferenceEngine`.

    Time is virtual: a batch starts at max(clock, last member's arrival),
    runs for the cost model's batch delay, and advances the clock — queue
    waits and throughput come from the same delay model the codesign
    optimizes, not from host wall time.
    """

    def __init__(self, model, params, sysp: SystemParams, *,
                 classes: Sequence[QosClass],
                 max_batch: int = 8,
                 path: Literal["fake", "kernel"] = "fake",
                 b_emb: int = 8,
                 lam: Optional[float] = None,
                 scheme: str = "uniform",
                 codesign_cache: Optional[CodesignCache] = None,
                 pad_token: int = 0,
                 mixed_precision: bool = False,
                 compiled: bool = False,
                 compile_cache: Optional[fp.CompiledForwardCache] = None,
                 seq_bucket_base: int = DEFAULT_SEQ_BASE,
                 tracer=None, metrics=None):
        if not classes:
            raise ValueError("need at least one QosClass")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        # compiled serving buckets every batch to (max_batch, seq bucket):
        # the batch quantum is max_batch, so the compiled-variant count is
        # len(seq ladder) x active plans (DESIGN.md §10)
        self.engine = CoInferenceEngine(model, params, sysp, lam=lam,
                                        scheme=scheme, path=path,
                                        b_emb=b_emb, cache_weights=True,
                                        compiled=compiled,
                                        compile_cache=compile_cache,
                                        seq_bucket_base=seq_bucket_base,
                                        batch_quantum=max_batch,
                                        tracer=tracer, metrics=metrics)
        self.tracer = self.engine.tracer
        self.metrics = self.engine.metrics
        self.compiled = bool(compiled)
        self.sysp = sysp
        self.max_batch = int(max_batch)
        self.pad_token = int(pad_token)
        self.mixed_precision = bool(mixed_precision)
        self.classes: Dict[str, QosClass] = {c.name: c for c in classes}
        if len(self.classes) != len(classes):
            raise ValueError("duplicate QosClass names")
        self.codesign_cache = codesign_cache \
            if codesign_cache is not None else CodesignCache()
        self._queue: Deque[ServeRequest] = collections.deque()
        self._next_id = 0
        self._clock = 0.0
        self.batch_history: List[BatchStats] = []
        self._served = 0
        self._energy = 0.0
        # resolve every class eagerly: one (P1) solve — or per-layer
        # allocation in mixed-precision mode — per distinct decision input
        # for the engine's whole lifetime; hits/misses are counted per call
        # so report() attributes this engine only its own lookups even when
        # the cache is shared with other engines
        self._own_hits = 0
        self._own_misses = 0
        self._solutions: Dict[str, Any] = {}
        self._plans: Dict[str, QuantPlan] = {}
        for c in classes:
            sol = self._resolve_class(c)
            if sol is None:
                raise ValueError(
                    f"QoS class {c.name!r} is infeasible under "
                    f"(T0={c.t0}, E0={c.e0})")
            self._solutions[c.name] = sol
            if self.mixed_precision:
                self._plans[c.name] = self.engine.plan_of(sol)

    # ------------------------------------------------------------------
    # per-class operating-point resolution
    # ------------------------------------------------------------------
    def _resolve_class(self, c: QosClass):
        """The class's operating point; None = infeasible (constructor
        raises).  ``AdaptiveCoInferenceEngine`` overrides this to solve
        against the current environment state and to degrade instead of
        returning None (DESIGN.md §9)."""
        return self._counted_solution(c)

    def _counted_solution(self, c: QosClass,
                          sysp: Optional[SystemParams] = None,
                          env_key: Optional[tuple] = None):
        """:meth:`_class_solution` with this engine's own hit/miss
        attribution (the cache may be shared across engines)."""
        h0, m0 = self.codesign_cache.hits, self.codesign_cache.misses
        sol = self._class_solution(c, sysp=sysp, env_key=env_key)
        dh = self.codesign_cache.hits - h0
        dm = self.codesign_cache.misses - m0
        self._own_hits += dh
        self._own_misses += dm
        if dh:
            self.metrics.counter("codesign.cache_hits",
                                 engine=type(self).__name__,
                                 qos=c.name).inc(dh)
        if dm:
            self.metrics.counter("codesign.cache_misses",
                                 engine=type(self).__name__,
                                 qos=c.name).inc(dm)
        return sol

    def _class_solution(self, c: QosClass,
                        sysp: Optional[SystemParams] = None,
                        env_key: Optional[tuple] = None):
        """One memoized (P1) solve / layer-wise allocation for class
        ``c`` under ``sysp`` (default: the engine's static params)."""
        p = self.sysp if sysp is None else sysp
        b_max = int(p.b_full)
        if self.mixed_precision:
            return self.codesign_cache.solve_mixed(
                self.engine.layer_stats(), p, c, b_max=b_max,
                b_emb=self.engine.b_emb, env_key=env_key)
        return self.codesign_cache.solve(self.engine.lam, p, c,
                                         b_max=b_max,
                                         b_emb=self.engine.b_emb,
                                         env_key=env_key)

    # ------------------------------------------------------------------
    # queue API
    # ------------------------------------------------------------------
    def solution_for(self, qos_name: str):
        """The class's operating point: a ``CodesignSolution`` (uniform
        mode) or a ``MixedSolution`` (mixed-precision mode)."""
        return self._solutions[qos_name]

    def plan_for(self, qos_name: str) -> Optional[QuantPlan]:
        """The class's :class:`QuantPlan` (None in uniform mode)."""
        return self._plans.get(qos_name)

    def warmup(self, max_seq: int) -> int:
        """Precompile every (class plan, seq bucket) forward variant for
        requests up to ``max_seq`` tokens (DESIGN.md §10).

        After this, serving any workload whose sequences fit the ladder
        never compiles: every step is a compile-cache hit.  Returns the
        number of variants compiled (cache misses this call added);
        variants other engines or earlier calls already compiled into a
        shared cache are not recompiled.
        """
        if not self.compiled:
            raise RuntimeError("warmup() needs compiled=True")
        cc = self.engine.compile_cache
        m0 = cc.misses
        for name, c in self.classes.items():
            sol = self._solutions[name]
            target = self._plans.get(name, getattr(sol, "b_hat", None))
            self.engine.configure(target, sol.f, sol.f_server)
            for s in seq_ladder(max_seq, base=self.engine.seq_bucket_base):
                self.engine.precompile(self.max_batch, s)
        return cc.misses - m0

    def submit(self, tokens, qos: str,
               arrival_s: Optional[float] = None) -> int:
        """Enqueue one request; returns its request id."""
        if qos not in self.classes:
            raise KeyError(f"unknown QoS class {qos!r}; have "
                           f"{sorted(self.classes)}")
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty request")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(ServeRequest(
            request_id=rid, tokens=toks, qos=qos,
            arrival_s=float(arrival_s) if arrival_s is not None
            else self._clock))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def oldest_pending_arrival(self) -> Optional[float]:
        """Earliest arrival time among queued requests (None when the
        queue is empty).  Not simply the queue head: ``submit`` accepts
        arbitrary ``arrival_s``, so out-of-order submissions can put a
        later arrival in front.  The fleet engine's cross-agent FIFO
        ranks agents by this (DESIGN.md §11)."""
        if not self._queue:
            return None
        return min(r.arrival_s for r in self._queue)

    @property
    def clock_s(self) -> float:
        return self._clock

    def fast_forward(self, t_s: float) -> None:
        """Advance the virtual clock to ``t_s`` (never backwards) — the
        supervisor's hook for billing fault wait time (backoff sleeps,
        retransmits, repair windows; DESIGN.md §15) on the same clock
        the cost model bills serving on."""
        self._clock = max(self._clock, float(t_s))

    def cancel(self, request_id: int) -> bool:
        """Drop a still-queued request (the supervisor's load-shedding
        hook, DESIGN.md §15); returns True when it was queued.  Batched
        serving has no mid-batch state to unwind — a request is either
        queued or already answered."""
        n0 = len(self._queue)
        self._queue = collections.deque(
            r for r in self._queue if r.request_id != request_id)
        return len(self._queue) < n0

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def _take_batch(self) -> List[ServeRequest]:
        """Oldest request decides the class; pull up to max_batch of it.

        Only requests already *arrived* by the batch's start instant
        (max(clock, head arrival)) join — a batch never idles waiting
        for a future arrival just because the submit order knew about
        it, which would bill early requests the late one's wait.  With
        every arrival at t=0 (the common test setup) this is the old
        take-everything behavior.
        """
        head = self._queue[0]
        cls = head.qos
        t_start = max(self._clock, head.arrival_s)
        picked = []
        for r in self._queue:
            if r.qos == cls and r.arrival_s <= t_start * (1.0 + 1e-12):
                picked.append(r)
                if len(picked) == self.max_batch:
                    break
        ids = {r.request_id for r in picked}
        self._queue = collections.deque(
            r for r in self._queue if r.request_id not in ids)
        return picked

    def step(self) -> List[ServeResponse]:
        """Serve one batch; returns its responses ([] if queue empty)."""
        if not self._queue:
            return []
        # batch assembly (take + pad/pack) and the fused forward dispatch
        # are the step's two traced phases (DESIGN.md §14)
        with self.tracer.span("batch.assemble"):
            reqs = self._take_batch()
            qos = self.classes[reqs[0].qos]
            sol = self._solutions[qos.name]
            # configure() is a dict lookup after the first batch of a
            # class (weight cache keyed on the stable plan key); freqs
            # are scalars
            target = self._plans.get(qos.name, sol.b_hat)
            self.engine.configure(target, sol.f, sol.f_server)

            s_max = max(r.tokens.size for r in reqs)
            lengths = [r.tokens.size for r in reqs]
            padded = np.full((len(reqs), s_max), self.pad_token, np.int32)
            for i, r in enumerate(reqs):
                padded[i, :r.tokens.size] = r.tokens
        # hand the host array over as-is: the compiled path re-pads it to
        # the bucket before its single device upload, and the eager embed
        # converts on use — uploading here would round-trip device->host
        with self.tracer.span("batch.forward", qos=qos.name,
                              n=len(reqs), seq=s_max):
            logits, stats = self.engine.serve_batch(
                {"tokens": padded}, lengths=lengths)

        start = max(self._clock, max(r.arrival_s for r in reqs))
        end = start + stats.total_delay_s
        self._clock = end

        n = len(reqs)
        waits = [start - r.arrival_s for r in reqs]
        real = sum(r.tokens.size for r in reqs)
        if self.compiled:
            # the fast path padded to the (batch quantum, seq bucket)
            # shape — occupancy reports the bucket waste honestly
            bp, sp = self.engine.bucket_shape(n, s_max)
            n_padded = bp * sp
        else:
            n_padded = n * s_max
        bstats = BatchStats(
            qos=qos.name, batch_size=n, b_hat=stats.b_hat,
            agent_path=self.engine.agent_path, f=stats.f,
            f_server=stats.f_server, real_tokens=real,
            padded_tokens=n_padded, occupancy=real / n_padded,
            batch_delay_s=stats.total_delay_s,
            amortized_delay_s=stats.total_delay_s / n,
            energy_j=stats.energy_j,
            amortized_energy_j=stats.energy_j / n,
            emb_bytes=stats.emb_bytes,
            queue_wait_mean_s=sum(waits) / n,
            queue_wait_max_s=max(waits),
            plan_bits=stats.plan_bits)
        self.batch_history.append(bstats)
        self._served += n
        self._energy += stats.energy_j
        m = self.metrics
        if m.enabled:
            eng = type(self).__name__
            m.counter("serve.requests", engine=eng, qos=qos.name).inc(n)
            m.counter("serve.batches", engine=eng, qos=qos.name).inc()
            m.histogram("serve.batch_occupancy",
                        buckets=OCCUPANCY_BUCKETS, engine=eng,
                        qos=qos.name).observe(bstats.occupancy)
            m.histogram("serve.batch_delay_s", engine=eng,
                        qos=qos.name).observe(bstats.batch_delay_s)

        out = []
        for i, r in enumerate(reqs):
            out.append(ServeResponse(
                request_id=r.request_id,
                logits=logits[i, :r.tokens.size],
                stats=RequestStats(
                    request_id=r.request_id, qos=qos.name,
                    b_hat=stats.b_hat, batch_size=n,
                    queue_wait_s=waits[i],
                    batch_delay_s=stats.total_delay_s,
                    total_delay_s=waits[i] + stats.total_delay_s,
                    energy_j=stats.energy_j / n,
                    # transport's own per-row accounting: this request's
                    # uplink bytes, counting only its real positions
                    emb_bytes=stats.emb_row_bytes[i])))
        return out

    def drain(self) -> List[ServeResponse]:
        """Serve until the queue is empty; responses in completion order."""
        out: List[ServeResponse] = []
        while self._queue:
            out.extend(self.step())
        return out

    # ------------------------------------------------------------------
    def report(self) -> EngineReport:
        nb = len(self.batch_history)
        cc = self.engine.compile_cache
        return EngineReport(
            requests_served=self._served,
            batches_served=nb,
            mean_batch_size=self._served / nb if nb else 0.0,
            mean_occupancy=(sum(b.occupancy for b in self.batch_history)
                            / nb if nb else 0.0),
            total_delay_s=self._clock,
            total_energy_j=self._energy,
            throughput_rps=self._served / self._clock
            if self._clock > 0 else 0.0,
            codesign_hits=self._own_hits,
            codesign_misses=self._own_misses,
            compile_hits=self.engine._own_compile_hits,
            compile_misses=self.engine._own_compile_misses,
            compiled_variants=len(cc) if cc is not None else 0)
