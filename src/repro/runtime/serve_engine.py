"""Co-inference serving engine (paper §II): agent stage -> embedding
transport -> server stage, with the joint (b̂, f, f̃) configuration chosen by
``core.codesign`` per QoS class.

Execution paths for the agent stage:

  * ``fake``    — agent layers run with fake-quantized weights
                  (quantize-dequantize at b̂); works for every model family
                  that exposes ``run_layers`` and any bit-width 1..16.
  * ``kernel``  — weights are *actually* int8/int4-resident and every agent
                  matmul dispatches ``repro.kernels`` quantized-matmul
                  (Pallas on TPU, interpret on CPU); dense DecoderLM family.
                  This is the TPU-native realization of the paper's knob:
                  HBM traffic scales with b̂/16 (DESIGN.md §3).

Embedding transport: the boundary activation is quantized at ``b_emb``
(per-tensor absmax) before "transmission"; the engine reports exact wire
bytes, so the uplink term of the cost model is grounded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import codesign as cd
from ..core.cost_model import (SystemParams, agent_delay, agent_energy,
                               server_delay, server_energy, transport_delay)
from ..core.quantization import QuantConfig, quantize_dequantize
from ..kernels import ops as kops
from ..models import layers as L
from .qat import fake_quantize_agent


# ---------------------------------------------------------------------------
# request/response records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeStats:
    b_hat: int
    f: float
    f_server: float
    agent_delay_s: float
    server_delay_s: float
    transport_delay_s: float
    total_delay_s: float
    energy_j: float
    emb_bytes: int
    agent_flops: float
    server_flops: float


@dataclasses.dataclass(frozen=True)
class QosClass:
    """One (T0, E0) service class; the engine solves (P1) per class."""
    name: str
    t0: float
    e0: float


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class CoInferenceEngine:
    """One agent/server pair serving a DecoderLM-family model."""

    def __init__(self, model, params, sysp: SystemParams, *,
                 lam: Optional[float] = None,
                 scheme: str = "uniform",
                 path: Literal["fake", "kernel"] = "fake",
                 b_emb: int = 8):
        if not hasattr(model, "run_layers"):
            raise TypeError(
                f"{type(model).__name__} lacks run_layers; co-inference "
                "split execution needs the DecoderLM protocol")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.sysp = sysp
        self.scheme = scheme
        self.path = path
        self.b_emb = b_emb
        self.split = self.cfg.split_layer
        self._axes = model.logical_axes()
        self.lam = float(lam) if lam is not None else self._fit_lambda()
        self.b_hat: int = 8
        self.f: float = sysp.f_max
        self.f_server: float = sysp.f_server_max
        self._agent_params = None       # set by configure()
        self._qlinears = None
        self.configure(self.b_hat, self.f, self.f_server)

    # ------------------------------------------------------------------
    def _fit_lambda(self) -> float:
        """MLE lambda over the agent-partition weight magnitudes."""
        total, count = 0.0, 0
        for leaf in jax.tree_util.tree_leaves(self.params["layers"]):
            if hasattr(leaf, "ndim") and leaf.ndim >= 3 and \
                    jnp.issubdtype(leaf.dtype, jnp.floating):
                sl = leaf[: self._stack_split(leaf)]
                total += float(jnp.sum(jnp.abs(sl)))
                count += int(np.prod(sl.shape))
        return count / max(total, 1e-30) if count else 100.0

    def _stack_split(self, leaf) -> int:
        return min(self.split, leaf.shape[0])

    def flop_split(self, tokens: int):
        """(agent_flops, server_flops) for one forward over ``tokens``."""
        per_layer = self.cfg.active_param_count() / max(self.cfg.n_layers, 1)
        n_agent = 2.0 * per_layer * self.split * tokens
        n_server = 2.0 * per_layer * (self.cfg.n_layers - self.split) * tokens
        return n_agent, n_server

    # ------------------------------------------------------------------
    # configuration (the paper's decision variables)
    # ------------------------------------------------------------------
    def configure(self, b_hat: int, f: Optional[float] = None,
                  f_server: Optional[float] = None) -> None:
        """Set (b̂, f, f̃) and materialize the agent weights at b̂."""
        self.b_hat = int(b_hat)
        if f is not None:
            self.f = float(f)
        if f_server is not None:
            self.f_server = float(f_server)
        qcfg = QuantConfig(bits=self.b_hat, scheme=self.scheme,
                           granularity="per-channel")
        if self.path == "kernel" and self.b_hat in (4, 8) \
                and not self.cfg.n_experts:
            self._qlinears = self._quantize_kernel_weights(self.b_hat)
            self._agent_params = None
        else:
            self._agent_params = fake_quantize_agent(
                self.params, self._axes, self.cfg, qcfg, ste=False)
            self._qlinears = None

    def auto_configure(self, qos: QosClass) -> Optional[cd.CodesignSolution]:
        """Solve (P1) for this QoS class and apply the solution."""
        sol = cd.solve_sca(self.lam, self.sysp, qos.t0, qos.e0,
                           b_max=int(self.sysp.b_full))
        if sol is None:
            return None
        self.configure(sol.b_hat, sol.f, sol.f_server)
        return sol

    # ------------------------------------------------------------------
    # kernel-path weight prep (dense DecoderLM)
    # ------------------------------------------------------------------
    def _quantize_kernel_weights(self, bits: int):
        """Per-layer QuantizedLinear for wq/wk/wv/wo/mlp of layers [0,split).

        Group size 128 along the contraction axis — exactly what the Pallas
        qmm kernel consumes.
        """
        lp = self.params["layers"]
        out = []
        names = ["wq", "wk", "wv", "wo"]
        mlp_names = [n for n in ("wi_gate", "wi_up", "wi", "wo")
                     if n in lp["ffn"]]
        for i in range(self.split):
            rec = {"attn": {}, "ffn": {}}
            for n in names:
                w = np.asarray(lp["attn"][n][i], np.float32)
                rec["attn"][n] = kops.quantize_linear(
                    jnp.asarray(w), bits=bits, group_size=128)
            for n in mlp_names:
                w = np.asarray(lp["ffn"][n][i], np.float32)
                rec["ffn"][n] = kops.quantize_linear(
                    jnp.asarray(w), bits=bits, group_size=128)
            out.append(rec)
        return out

    def _agent_forward_kernel(self, x, positions):
        """Dense DecoderLM agent stack with Pallas quantized matmuls."""
        cfg = self.cfg
        lp = self.params["layers"]
        for i in range(self.split):
            ql = self._qlinears[i]
            ln1 = jax.tree_util.tree_map(lambda a: a[i], lp["ln1"])
            ln2 = jax.tree_util.tree_map(lambda a: a[i], lp["ln2"])
            h = L.apply_norm(cfg, x, ln1)
            q = ql["attn"]["wq"].apply(h)
            k = ql["attn"]["wk"].apply(h)
            v = ql["attn"]["wv"].apply(h)
            if cfg.qkv_bias:
                q = q + lp["attn"]["bq"][i].astype(x.dtype)
                k = k + lp["attn"]["bk"][i].astype(x.dtype)
                v = v + lp["attn"]["bv"][i].astype(x.dtype)
            q = q.reshape(q.shape[:-1] + (cfg.n_heads, cfg.head_dim))
            k = k.reshape(k.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
            v = v.reshape(v.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            attn = L.blockwise_attention(q, k, v, causal=True,
                                         window=cfg.sliding_window)
            x = x + ql["attn"]["wo"].apply(
                attn.reshape(x.shape[:2] + (cfg.q_dim,)))
            h2 = L.apply_norm(cfg, x, ln2)
            if cfg.act == "silu":
                y = jax.nn.silu(ql["ffn"]["wi_gate"].apply(h2)) \
                    * ql["ffn"]["wi_up"].apply(h2)
            else:
                y = jax.nn.gelu(ql["ffn"]["wi"].apply(h2))
            x = x + ql["ffn"]["wo"].apply(y)
        return x

    # ------------------------------------------------------------------
    # the two inference stages + transport
    # ------------------------------------------------------------------
    def agent_stage(self, batch: Dict[str, Any]):
        """Embedding + layers [0, split) at bit-width b̂."""
        src = self._agent_params if self._agent_params is not None \
            else self.params
        x, positions = self.model._embed(src, batch)
        if self._qlinears is not None:
            x = self._agent_forward_kernel(x, positions)
        else:
            x, _ = self.model.run_layers(src, x, positions, 0, self.split)
        return x, positions

    def transport(self, emb: jax.Array):
        """Quantize the boundary activation for the uplink; returns
        (received embedding, wire bytes)."""
        if self.b_emb >= 16:
            return emb, int(np.prod(emb.shape)) * emb.dtype.itemsize
        qcfg = QuantConfig(bits=self.b_emb, scheme="uniform",
                           granularity="per-tensor")
        emb_q = quantize_dequantize(emb, qcfg)
        bits = int(np.prod(emb.shape)) * self.b_emb
        return emb_q, (bits + 7) // 8 + 4  # + one f32 scale

    def server_stage(self, emb: jax.Array, positions):
        """Layers [split, L) at full precision + head."""
        x, _ = self.model.run_layers(self.params, emb, positions,
                                     self.split, self.cfg.n_layers)
        x = L.apply_norm(self.cfg, x, self.params["final_norm"])
        return L.unembed(self.cfg, self.params["embed"], x)

    # ------------------------------------------------------------------
    def serve_batch(self, batch: Dict[str, Any]):
        """Full co-inference pass; returns (logits, ServeStats)."""
        emb, positions = self.agent_stage(batch)
        emb_rx, emb_bytes = self.transport(emb)
        logits = self.server_stage(emb_rx, positions)

        tokens = int(np.prod(positions.shape))
        n_a, n_s = self.flop_split(tokens)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s,
                                emb_bytes_full=float(emb_bytes)
                                * 16.0 / self.b_emb)
        t_a = float(agent_delay(self.b_hat, self.f, p))
        t_s = float(server_delay(self.f_server, p))
        t_x = float(transport_delay(self.b_emb, p))
        e = float(agent_energy(self.b_hat, self.f, p)
                  + server_energy(self.f_server, p))
        stats = ServeStats(
            b_hat=self.b_hat, f=self.f, f_server=self.f_server,
            agent_delay_s=t_a, server_delay_s=t_s, transport_delay_s=t_x,
            total_delay_s=t_a + t_s + t_x, energy_j=e, emb_bytes=emb_bytes,
            agent_flops=n_a, server_flops=n_s)
        return logits, stats
