"""Distributed training loop with QAT hooks, checkpointing and compressed
cross-pod gradients.

Composition (bottom to top):

  model.loss                      — any repro.models family
  qat.fake_quantize_agent         — agent-partition fake quant (optional)
  value_and_grad + AdamW          — from-scratch optimizer
  grad_compress (int8 + EF)       — cross-pod all-reduce at 1 byte/elem
  pjit w/ logical-axis shardings  — DP/TP/EP/FSDP per parallel/sharding.py
  shard_map(axis_names={'pod'})   — manual pod axis when the mesh has one,
                                    so the pod all-reduce is explicit and
                                    quantized; 'data'/'model' stay Auto
  CheckpointManager               — async save, restore-on-start

The same ``Trainer`` serves the CPU tests (1-device mesh), the examples
(host mesh) and the dry-run (512-device production mesh; lower/compile only).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager
from ..launch.mesh import set_mesh
from ..core.quantization import QuantConfig
from ..optim import AdamW, AdamWState, compress_tree, init_error_state
from ..parallel.sharding import (batch_shardings, default_rules, replicated,
                                 tree_shardings)
from . import qat as qat_mod


@dataclasses.dataclass
class TrainConfig:
    qat_bits: int = 0                 # 0 disables QAT
    qat_scheme: str = "uniform"
    grad_compression: str = "none"    # 'none' | 'int8_ef'
    log_every: int = 10
    remat: bool = True                # models already checkpoint per-layer


class Trainer:
    """Owns jitted step + state; one instance per (model, mesh)."""

    def __init__(self, model, optimizer: AdamW, mesh: Mesh,
                 train_cfg: Optional[TrainConfig] = None,
                 rules: Optional[Dict[str, Any]] = None,
                 ckpt: Optional[CheckpointManager] = None):
        self.model = model
        self.cfg = model.cfg
        self.opt = optimizer
        self.mesh = mesh
        self.tc = train_cfg or TrainConfig()
        self.rules = rules if rules is not None else default_rules(self.cfg)
        self.ckpt = ckpt
        self._axes = model.logical_axes()
        self._step_fn = None
        self.step = 0

        qcfg = None
        if self.tc.qat_bits > 0:
            qcfg = QuantConfig(bits=self.tc.qat_bits,
                               scheme=self.tc.qat_scheme,
                               granularity="per-channel")
        self.qcfg = qcfg

    # ------------------------------------------------------------------
    # shardings
    # ------------------------------------------------------------------
    def param_shardings(self):
        structs = self.model.param_structs()
        return tree_shardings(self._axes, structs, self.rules, self.mesh)

    def opt_shardings(self, param_sh):
        # m/v mirror params; step is replicated
        return AdamWState(step=replicated(self.mesh), m=param_sh,
                          v=jax.tree_util.tree_map(lambda s: s, param_sh))

    def batch_sharding_for(self, batch_struct):
        return batch_shardings(batch_struct, self.rules, self.mesh)

    # ------------------------------------------------------------------
    # step construction
    # ------------------------------------------------------------------
    def _loss_fn(self, params, batch):
        if self.qcfg is not None:
            params = qat_mod.fake_quantize_agent(
                params, self._axes, self.cfg, self.qcfg)
        return self.model.loss(params, batch)

    def _plain_step(self, params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(self._loss_fn)(params, batch)
        if self.tc.grad_compression == "int8_ef":
            grads, err = compress_tree(grads, err, axis_name=None)
        params, opt_state, metrics = self.opt.update(grads, opt_state,
                                                     params)
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    def _podwise_step(self, params, opt_state, err, batch):
        """Manual 'pod' axis: per-pod grads -> int8 EF compress -> psum."""
        def per_pod(params, opt_state, err, batch):
            loss, grads = jax.value_and_grad(self._loss_fn)(params, batch)
            grads, err = compress_tree(grads, err, axis_name="pod")
            loss = jax.lax.pmean(loss, "pod")
            params, opt_state, metrics = self.opt.update(grads, opt_state,
                                                         params)
            metrics["loss"] = loss
            return params, opt_state, err, metrics

        # params/opt/err replicated over 'pod' (P() on the pod axis; their
        # data/model sharding is handled by the Auto axes), batch split on it
        return jax.shard_map(
            per_pod, mesh=self.mesh,
            in_specs=(P(), P(), P(), P("pod")),
            out_specs=(P(), P(), P(), P()),
            axis_names={"pod"})(params, opt_state, err, batch)

    def build_step(self, batch_struct) -> Callable:
        param_sh = self.param_shardings()
        opt_sh = self.opt_shardings(param_sh)
        batch_sh = self.batch_sharding_for(batch_struct)
        err_sh = param_sh if self.tc.grad_compression == "int8_ef" else \
            replicated(self.mesh)
        has_pod = "pod" in self.mesh.axis_names
        body = self._podwise_step if (
            has_pod and self.tc.grad_compression == "int8_ef") \
            else self._plain_step

        metrics_sh = {"loss": replicated(self.mesh),
                      "grad_norm": replicated(self.mesh),
                      "lr": replicated(self.mesh)}
        self._step_fn = jax.jit(
            body,
            in_shardings=(param_sh, opt_sh, err_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, err_sh, metrics_sh),
            donate_argnums=(0, 1, 2),
        )
        return self._step_fn

    # ------------------------------------------------------------------
    # state init / restore
    # ------------------------------------------------------------------
    def init_state(self, rng):
        with set_mesh(self.mesh):
            params = jax.jit(
                self.model.init,
                out_shardings=self.param_shardings())(rng)
        opt_state = self.opt.init(params)
        err = (init_error_state(params)
               if self.tc.grad_compression == "int8_ef"
               else jnp.zeros((), jnp.float32))
        return params, opt_state, err

    def maybe_restore(self, params, opt_state, err):
        """Resume from the newest checkpoint if one exists."""
        if self.ckpt is None:
            return params, opt_state, err, 0
        state = {"params": params, "opt": opt_state, "err": err}
        sh = {"params": self.param_shardings(),
              "opt": self.opt_shardings(self.param_shardings()),
              "err": jax.tree_util.tree_map(lambda _: replicated(self.mesh),
                                            err)}
        out = self.ckpt.restore_latest(state, sh)
        if out is None:
            return params, opt_state, err, 0
        tree, manifest = out
        self.step = int(manifest["metadata"].get("data_step",
                                                 manifest["step"]))
        return tree["params"], tree["opt"], tree["err"], self.step

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def fit(self, loader, num_steps: int, rng=None,
            state=None, on_metrics: Optional[Callable] = None):
        """Run ``num_steps`` steps; returns (state, history)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        if state is None:
            params, opt_state, err = self.init_state(rng)
            params, opt_state, err, start = self.maybe_restore(
                params, opt_state, err)
            loader.seek(start)
        else:
            params, opt_state, err = state
            start = self.step

        if self._step_fn is None:
            self.build_step(loader.peek_structure())

        history = []
        t_last = time.monotonic()
        with set_mesh(self.mesh):
            for step in range(start, start + num_steps):
                batch = next(loader)
                params, opt_state, err, metrics = self._step_fn(
                    params, opt_state, err, batch)
                self.step = step + 1
                if (step + 1) % self.tc.log_every == 0 or step == start:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step + 1
                    m["steps_per_s"] = self.tc.log_every / max(
                        time.monotonic() - t_last, 1e-9)
                    t_last = time.monotonic()
                    history.append(m)
                    if on_metrics:
                        on_metrics(m)
                if self.ckpt is not None and self.ckpt.should_save(step + 1):
                    self.ckpt.save_async(
                        step + 1,
                        {"params": params, "opt": opt_state, "err": err},
                        metadata={"data_step": step + 1})
        if self.ckpt is not None:
            self.ckpt.wait()
        return (params, opt_state, err), history
