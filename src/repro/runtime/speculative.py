"""Speculative co-inference: quantized agent drafts, server verifies
(DESIGN.md §16).

The PR-6/7 decode stack pays one full co-inference round — agent
partition forward, boundary uplink, server partition forward, cache
stream — per generated token.  This module amortizes the per-round
overheads over several tokens: the agent partition, fake-quantized at a
*draft* bit-width ``b_draft`` below the class operating point, greedily
drafts ``k`` tokens per round; the tokens and the boundary hidden state
go up once; the server-side verify pass checks all ``k`` against the
target operating point with standard longest-accepted-prefix rollback.
Acceptance rate is a function of the draft distortion ``D^U(b_draft)``,
which makes ``(b_draft, k)`` codesign variables alongside (b̂, f, f̃,
b_kv) — ``codesign.solve_speculative`` picks the joint point that
minimizes the distortion bound per *expected delivered token*.

Three commitments, on top of :class:`~.decode_engine.DecodeEngine`'s
four:

1.  **Bitwise parity, structurally.**  Rollback is realized as
    *commit-on-verify*: draft steps carry the KV cache functionally
    inside their executable and discard it, so speculative state never
    touches the canonical slot buffers.  The verify executable is a
    chain of *target* ``decode_step_q`` steps with per-row early exit —
    every token it feeds is a delivered-stream token, so every cache
    entry it commits is exactly what ``greedy_decode_reference``
    writes.  The draft influences only how many verify iterations run
    and what the round bills, never the bits (the §7/§12 house
    invariant, extended).  There is no truncation step because nothing
    speculative is ever committed.

2.  **Billed at the paper's round model.**  The virtual clock charges
    ``cost_model.speculative_round_delay``: ``k`` cheap drafts pinned
    at ``f_max``, ONE batched verify forward at the class operating
    point (decode forwards are weight-stream bound, so the ``k + 1``
    positions under one weight pass bill as a single per-token forward
    — that amortization is the speculative win), one uplink, ``k + 1``
    cache streams, and the rejected entries as rollback traffic.  The
    executed-vs-billed separation is the same one the whole repo uses
    (wall measurement lives in ``benchmarks/speculative.py``).

3.  **Supervision for free.**  Slots, groups, snapshots, cancel and
    retirement are inherited unchanged; rounds are atomic between
    ``step()`` calls and ``generated`` only ever holds verified tokens,
    so ``ServingSupervisor`` snapshots at round boundaries resume
    bitwise through the sequential reference, and rejected draft work
    is never billed twice (it was never delivered).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import mixed_precision as mp
from repro.core.cost_model import (SystemParams, speculative_round_delay,
                                   speculative_round_energy)
from repro.core.quantization import QuantConfig
from repro.kernels.bucketing import seq_ladder
from repro.obs import ReportBase

from .decode_engine import (_SPEC_MAX_K, DecodeEngine, DecodeResponse,
                            _ClassState, _compile_spec_round, _Group)
from .qat import fake_quantize_agent
from .serve_engine import QosClass

__all__ = [
    "SpecRoundStats",
    "SpeculativeDecodeEngine",
    "SPEC_DRAFT_LADDER",
    "SPEC_LOOKAHEAD_MENU",
]

# the realizable draft/lookahead menus the codesign enumerates — the
# speculative analog of the KV container ladder
SPEC_DRAFT_LADDER = (2, 4, 8)
SPEC_LOOKAHEAD_MENU = (2, 4, 8)


@dataclasses.dataclass(frozen=True)
class SpecRoundStats(ReportBase):
    """Whole-run draft/verify aggregates of a speculative engine."""
    rounds: int                 # verify rounds executed
    drafted: int                # draft tokens proposed (live rows × k)
    accepted: int               # drafts the verifier accepted
    delivered: int              # tokens delivered by verify rounds
    acceptance_rate: float      # accepted / drafted
    accepted_per_round: float   # mean accepted prefix length per row
    tokens_per_round: float     # mean delivered per row per round (τ̂)


@dataclasses.dataclass
class _SpecState:
    """One class's resolved draft schedule."""
    b_draft: int
    k: int
    plan_key: tuple             # draft weight tree key in ``_weights``


class SpeculativeDecodeEngine(DecodeEngine):
    """Draft-then-verify decode over the inherited slot machinery.

    ``auto=True`` resolves each class through
    ``codesign.solve_speculative`` (or the mixed-precision analog),
    which picks ``(b̂ or plan, f, f̃, b_kv, b_draft, k)`` jointly;
    ``auto=False`` pins ``draft_bits``/``lookahead`` directly, and
    :meth:`set_operating_point` grows ``b_draft``/``k`` keyword
    arguments for tests.  Everything else — admission policies,
    cancellation, snapshots, reporting — is inherited.
    """

    def __init__(self, model, params, sysp: SystemParams, *,
                 classes: Sequence[QosClass],
                 draft_bits: int = 4,
                 lookahead: int = 4,
                 draft_ladder: "tuple[int, ...]" = SPEC_DRAFT_LADDER,
                 lookahead_menu: "tuple[int, ...]" = SPEC_LOOKAHEAD_MENU,
                 **kwargs):
        if not (1 <= int(lookahead) <= _SPEC_MAX_K):
            raise ValueError(f"lookahead={lookahead} outside "
                             f"[1, {_SPEC_MAX_K}]")
        # set before super().__init__: the base constructor resolves
        # classes through our overridden set_operating_point/_resolve_class
        self.draft_bits = int(draft_bits)
        self.lookahead = int(lookahead)
        self.draft_ladder = tuple(int(b) for b in draft_ladder)
        self.lookahead_menu = tuple(int(v) for v in lookahead_menu)
        self._spec: Dict[str, _SpecState] = {}
        self._spec_rounds = 0
        self._spec_row_rounds = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._spec_delivered = 0
        super().__init__(model, params, sysp, classes=classes, **kwargs)

    # ------------------------------------------------------------------
    # operating points
    # ------------------------------------------------------------------
    def _resolve_class(self, c: QosClass) -> None:
        b_max = int(self.sysp.b_full)
        h0, m0 = self.codesign_cache.hits, self.codesign_cache.misses
        if self.mixed_precision:
            sol = self.codesign_cache.solve_speculative_mixed(
                self.layer_stats(), self.lam_kv, self.sysp, c, b_max,
                b_emb=self.b_emb, kv_ladder=self.kv_ladder,
                kv_weight=self.kv_weight, draft_ladder=self.draft_ladder,
                lookahead=self.lookahead_menu)
        else:
            sol = self.codesign_cache.solve_speculative(
                self.lam, self.lam_kv, self.sysp, c, b_max,
                b_emb=self.b_emb, kv_ladder=self.kv_ladder,
                kv_weight=self.kv_weight, draft_ladder=self.draft_ladder,
                lookahead=self.lookahead_menu)
        dh = self.codesign_cache.hits - h0
        dm = self.codesign_cache.misses - m0
        self._own_hits += dh
        self._own_misses += dm
        if dh:
            self.metrics.counter("codesign.cache_hits",
                                 engine="SpeculativeDecodeEngine",
                                 qos=c.name).inc(dh)
        if dm:
            self.metrics.counter("codesign.cache_misses",
                                 engine="SpeculativeDecodeEngine",
                                 qos=c.name).inc(dm)
        if sol is None:
            raise ValueError(
                f"QoS class {c.name!r} (T0={c.t0}, E0={c.e0}) is "
                "infeasible at every (b_kv, b_draft, k) in "
                f"{self.kv_ladder} x {self.draft_ladder} x "
                f"{self.lookahead_menu}")
        target = mp.plan_from_bits(sol.bits) if self.mixed_precision \
            else sol.b_hat
        self._classes[c.name] = None
        self.set_operating_point(c.name, target, sol.b_kv,
                                 f=sol.f, f_server=sol.f_server,
                                 qos=c, solution=sol,
                                 b_draft=sol.b_draft, k=sol.k)

    def set_operating_point(self, qos_name: str, target, b_kv: int, *,
                            b_draft: Optional[int] = None,
                            k: Optional[int] = None,
                            f: Optional[float] = None,
                            f_server: Optional[float] = None,
                            qos: Optional[QosClass] = None,
                            solution=None) -> None:
        """Base semantics plus the class's draft schedule (b_draft, k);
        omitted values keep the previous schedule (or the engine
        defaults on first resolution)."""
        prev = self._spec.get(qos_name)
        b_draft = int(b_draft) if b_draft is not None \
            else (prev.b_draft if prev else self.draft_bits)
        k = int(k) if k is not None \
            else (prev.k if prev else self.lookahead)
        if b_draft < 2:
            raise ValueError(f"b_draft={b_draft} below the 2-bit floor")
        if not (1 <= k <= _SPEC_MAX_K):
            raise ValueError(f"lookahead k={k} outside [1, {_SPEC_MAX_K}]")
        super().set_operating_point(qos_name, target, b_kv, f=f,
                                    f_server=f_server, qos=qos,
                                    solution=solution)
        dk = ("uniform", b_draft)
        if dk not in self._weights:
            self._weights[dk] = fake_quantize_agent(
                self.params, self._axes, self.cfg,
                QuantConfig(bits=b_draft, scheme="uniform",
                            granularity="per-channel"), ste=False)
        self._spec[qos_name] = _SpecState(b_draft=b_draft, k=k,
                                          plan_key=dk)

    def spec_params(self, qos_name: str):
        """The class's materialized draft weight tree."""
        return self._weights[self._spec[qos_name].plan_key]

    def draft_schedule(self, qos_name: str) -> "tuple[int, int]":
        sp = self._spec[qos_name]
        return sp.b_draft, sp.k

    # ------------------------------------------------------------------
    # executables
    # ------------------------------------------------------------------
    def _spec_round_exe(self, c: _ClassState, t_bucket: int):
        return self._cached(
            ("spec-round", self.cfg, self.max_batch, t_bucket, c.b_kv),
            lambda: _compile_spec_round(self.model, self.params, c.b_kv,
                                        self.max_batch, t_bucket),
            plan=f"spec-round/bkv{c.b_kv}",
            bucket=f"{t_bucket}x{self.max_batch}")

    def warmup(self, max_prompt: int, max_new: Optional[int] = None) -> int:
        """Precompile every reachable variant: the prefill (prompt,
        cache)-bucket pairs exactly as the base engine, plus ONE fused
        spec-round (draft chain + verify chain in a single dispatch)
        executable per cache bucket — lookahead ``k`` is a runtime
        argument, so the post-warmup compile count is bounded by
        pairs × n_kv + rungs × n_kv, strictly inside the
        ladder × {draft, verify} budget of 2 × rungs × n_kv round
        executables."""
        m0 = self._own_compile_misses
        mn = int(max_new) if max_new is not None else self.max_new_tokens
        for c in self._classes.values():
            t_rungs = seq_ladder(max_prompt + mn, self.seq_bucket_base)
            for t in t_rungs:
                self._spec_round_exe(c, t)
            for s in seq_ladder(max_prompt, self.seq_bucket_base):
                for t in t_rungs:
                    if t >= s:
                        self._prefill_exe(c, s, t)
        return self._own_compile_misses - m0

    # ------------------------------------------------------------------
    # the speculative round
    # ------------------------------------------------------------------
    def _decode_round(self, g: _Group, out: List[DecodeResponse],
                      max_steps: Optional[int] = None) -> None:
        c = self._classes[g.qos_name]
        sp = self._spec[g.qos_name]
        live_rows = [i for i, a in enumerate(g.slots) if a is not None]
        rem = np.zeros((self.max_batch,), np.int32)
        for i in live_rows:
            rem[i] = (g.slots[i].req.max_new_tokens
                      - len(g.slots[i].generated))
        # drafting past the largest remaining budget is pure waste (the
        # verifier stops at rem), and ``max_steps`` caps delivered
        # tokens per row: max_steps=1 degenerates to plain decode
        # (n_draft=0, verify emits exactly one target token per row)
        n_draft = min(sp.k, max(int(rem[live_rows].max()) - 1, 0))
        if max_steps is not None:
            n_draft = min(n_draft, max(int(max_steps) - 1, 0))
        live = np.zeros((self.max_batch,), np.int32)
        live[live_rows] = 1
        eos = self.eos_id if self.eos_id is not None else -1
        exe = self._spec_round_exe(c, g.t_bucket)
        with self.tracer.span("decode.spec_round", qos=g.qos_name,
                              live_rows=len(live_rows),
                              t_bucket=g.t_bucket, n_draft=n_draft):
            (blk, cnt, acc, g.k_codes, g.v_codes, g.k_scales,
             g.v_scales, g.tok, g.pos) = exe(
                self._weights[sp.plan_key], self._weights[c.plan_key],
                g.k_codes, g.v_codes, g.k_scales, g.v_scales, g.tok,
                g.pos, jnp.asarray(live),
                jnp.asarray(n_draft, jnp.int32),
                jnp.asarray(rem), jnp.asarray(eos, jnp.int32))
            blk = np.asarray(blk)
            cnt = np.asarray(cnt)
            acc = np.asarray(acc)
        # host traffic: masks + scalars in, the delivered block out
        # (drafts never leave the device — they live and die inside the
        # fused round executable)
        self._h2d += live.nbytes + rem.nbytes + 8
        self._d2h += blk.nbytes + cnt.nbytes + acc.nbytes
        n_live = len(live_rows)
        delivered = int(cnt[live_rows].sum())
        accepted = int(acc[live_rows].sum())
        tau_act = delivered / max(n_live, 1)
        t_round, e_round = self._spec_round_cost(c, sp, g.t_bucket,
                                                 n_draft, tau_act)
        self._clock += t_round
        self._energy += e_round
        self._rounds += 1
        self._spec_rounds += 1
        self._spec_row_rounds += n_live
        self._spec_drafted += n_draft * n_live
        self._spec_accepted += accepted
        self._spec_delivered += delivered
        m = self.metrics
        if m.enabled:
            m.counter("decode.spec_rounds",
                      engine="SpeculativeDecodeEngine",
                      qos=g.qos_name).inc()
            m.counter("decode.spec_drafted",
                      engine="SpeculativeDecodeEngine",
                      qos=g.qos_name).inc(n_draft * n_live)
            m.counter("decode.spec_accepted",
                      engine="SpeculativeDecodeEngine",
                      qos=g.qos_name).inc(accepted)
            m.counter("decode.h2d_bytes",
                      engine="SpeculativeDecodeEngine").inc(
                live.nbytes + rem.nbytes + 8)
            m.counter("decode.d2h_bytes",
                      engine="SpeculativeDecodeEngine").inc(
                blk.nbytes + cnt.nbytes + acc.nbytes)
            m.gauge("decode.live_rows",
                    engine="SpeculativeDecodeEngine",
                    qos=g.qos_name).set(n_live)
        # tokens land when the verify completes: the whole round's
        # output is delivered in one burst at the round boundary
        t_emit = self._clock
        finished: List[int] = []
        for i in live_rows:
            act = g.slots[i]
            for j in range(int(cnt[i])):
                tok_ij = int(blk[i, j])
                act.generated.append(tok_ij)
                act.itls.append(t_emit - act.last_emit_s)
                act.last_emit_s = t_emit
                if act.on_token is not None:
                    act.on_token(act.req.request_id, tok_ij, t_emit)
            last = act.generated[-1]
            if (self.eos_id is not None and last == self.eos_id) \
                    or len(act.generated) >= act.req.max_new_tokens:
                finished.append(i)
        for i in finished:
            out.append(self._retire(g, i))

    # ------------------------------------------------------------------
    # billing
    # ------------------------------------------------------------------
    def _spec_round_cost(self, c: _ClassState, sp: _SpecState,
                         t_bucket: int, n_draft: int, tau: float):
        """One speculative round at the PADDED workload, exactly as
        ``_round_cost`` pads the fused step: all ``max_batch`` rows and
        the full cache at ``b_kv`` are billed through
        ``cost_model.speculative_round_delay`` — ``n_draft`` drafts at
        ``f_max``, ONE batched verify weight pass over the ``n_draft +
        1`` positions, ``n_draft + 1`` cache streams, and the
        actually-rejected entries as rollback traffic."""
        n_a, n_s = self.flop_split(self.max_batch)
        kv_full = 2.0 * self.cfg.n_layers * self.max_batch * t_bucket \
            * self.cfg.n_kv_heads * self.cfg.head_dim \
            * (self.sysp.b_full / 8.0)
        p = dataclasses.replace(self.sysp, n_flop_agent=n_a,
                                n_flop_server=n_s, kv_bytes_full=kv_full)
        t = float(speculative_round_delay(
            c.b_eff, c.f, c.f_server, sp.b_draft, n_draft, tau, p,
            b_emb=self.b_emb, b_kv=c.b_kv))
        e = float(speculative_round_energy(
            c.b_eff, c.f, c.f_server, sp.b_draft, n_draft, tau, p,
            b_emb=self.b_emb, b_kv=c.b_kv))
        return t, e

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def spec_stats(self) -> SpecRoundStats:
        rr = max(self._spec_row_rounds, 1)
        drafted = max(self._spec_drafted, 1)
        return SpecRoundStats(
            rounds=self._spec_rounds,
            drafted=self._spec_drafted,
            accepted=self._spec_accepted,
            delivered=self._spec_delivered,
            acceptance_rate=self._spec_accepted / drafted,
            accepted_per_round=self._spec_accepted / rr,
            tokens_per_round=self._spec_delivered / rr)
