"""Quantization-aware training of the agent partition.

The co-inference split puts layers ``[0, split)`` on the agent; at serving
time those weights run at bit-width b̂ (core.codesign picks it).  Training
must therefore see the quantized forward — this module fake-quantizes the
agent slice of the *stacked* layer parameters each step, with
straight-through gradients (``core.quantization.qat_quantize``).

Works on any of the model families: stacked leaves are identified through
the model's ``logical_axes()`` metadata (leading axis 'layers' or 'blocks'),
vmapped per-layer (so per-channel scales are computed per layer, not across
the stack), and masked to the agent partition.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core.quantization import (QuantConfig, QuantPlan, qat_quantize,
                                 quantize_dequantize)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, str) for e in x)


def agent_mask_fn(cfg):
    """(stacked_axis_name, length) -> boolean mask of agent-owned entries.

    The returned function also exposes ``n_agent(name, length)``, the
    host-side count of agent-owned leading entries (the mask is
    ``arange(length) < n_agent``) — used where a static Python count is
    needed, e.g. to skip per-layer work on server layers under a plan.
    """
    per = getattr(cfg, "attn_period", 0) or getattr(cfg, "slstm_period", 0) \
        or 0

    def n_agent(name: str, length: int) -> int:
        if name == "layers":
            return min(int(cfg.split_layer), length)
        # 'blocks': super-block granularity (split rounded down to blocks)
        blocks = max(cfg.split_layer // max(per, 1), 0) if per else 0
        return min(int(blocks), length)

    def mask(name: str, length: int) -> jnp.ndarray:
        return jnp.arange(length) < n_agent(name, length)
    mask.n_agent = n_agent
    return mask


def fake_quantize_agent(params: Any, axes: Any, cfg, qcfg,
                        *, ste: bool = True) -> Any:
    """Return params with the agent partition fake-quantized.

    ``axes`` is the model's logical_axes() pytree.  Stacked weight leaves
    (leading 'layers'/'blocks' axis, >= 3 dims) are quantized per-layer and
    masked by the co-inference split; everything else passes through.

    ``qcfg`` is a single :class:`QuantConfig` (uniform b̂, the paper's
    knob) or a :class:`QuantPlan` whose ``layers/<i>`` entries index the
    stacked axis — layer i then quantizes at its own bit-width
    (DESIGN.md §8).  Entries past the split are masked out either way, so
    a plan only needs to cover the agent partition.
    """
    mask_of = agent_mask_fn(cfg)
    q1 = qat_quantize if ste else quantize_dequantize

    def one(ax, leaf):
        if not _is_axes(ax) or not hasattr(leaf, "ndim"):
            return leaf
        if leaf.ndim < 3 or ax[0] not in ("layers", "blocks"):
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1, leaf.shape[-1])   # [L, in*, out]
        if isinstance(qcfg, QuantPlan):
            # per-layer bits: the stacked axis can't vmap over a varying
            # Python-level level count, so stack per-layer quantizations
            # — skipping masked-out (server) layers, which jnp.where
            # would discard anyway
            na = mask_of.n_agent(ax[0], n)
            qflat = jnp.stack([q1(flat[i], qcfg.config_for_layer(i))
                               if i < na else flat[i] for i in range(n)])
        else:
            qflat = jax.vmap(lambda w: q1(w, qcfg))(flat)
        q = qflat.reshape(leaf.shape)
        m = mask_of(ax[0], n).reshape((n,) + (1,) * (leaf.ndim - 1))
        return jnp.where(m, q, leaf)

    return jax.tree_util.tree_map(one, axes, params, is_leaf=_is_axes)
