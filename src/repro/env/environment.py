"""Time-indexed environment state for adaptive serving (DESIGN.md §9).

:class:`Environment` composes the processes of ``processes.py`` — an
uplink-rate process, an f_max-cap process (thermal model or profile
replay), a battery — into one per-step trace, precomputed at
construction from a single explicit seed (one spawned child stream per
process), so the same seed always yields the identical environment.

:class:`EnvState` is the snapshot at a virtual-clock instant:

* ``apply(base)``    — the ``SystemParams`` view the cost model and the
  (P1) solver consume: f_max capped by the thermal governor, link_bps
  replaced by the current uplink rate.
* ``energy_scale``   — the battery-derived derate of per-request energy
  budgets (E0 shrinks as charge runs below the reserve), applied by
  ``runtime/adaptive.py`` at planning time.
* ``quantize()``     — a coarsened state (log-scale link buckets, linear
  f/scale buckets) whose ``key()`` is the *quantized environment-state
  key* the extended ``CodesignCache`` memoizes on: nearby states share
  one solve, and the adaptive controller's drift detector compares these
  keys instead of raw floats, so measurement jitter cannot thrash the
  plan.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional

import numpy as np

from ..core.cost_model import SystemParams

__all__ = ["EnvState", "Environment"]


@dataclasses.dataclass(frozen=True)
class EnvState:
    """Environment snapshot at virtual time ``t_s``."""

    t_s: float
    link_bps: float             # current uplink rate (0 = unmodeled)
    f_cap_hz: float             # thermal f_max cap (inf = uncapped)
    battery_soc: float          # 1.0 = full
    temp_c: float
    energy_scale: float         # battery-derived E0 derate in (0, 1]

    def apply(self, base: SystemParams) -> SystemParams:
        """The ``SystemParams`` view of this state: the paper's constants
        with the time-varying fields swapped in."""
        return dataclasses.replace(
            base,
            f_max=min(base.f_max, self.f_cap_hz),
            link_bps=self.link_bps if self.link_bps > 0.0 else base.link_bps)

    def quantize(self, *, link_steps_per_octave: float = 2.0,
                 f_step_hz: float = 1.0e8,
                 scale_step: float = 0.05) -> "EnvState":
        """Coarsen to the resolution the plan actually responds to.

        Link rate is quantized on a log2 grid (``link_steps_per_octave``
        buckets per octave — rate changes matter multiplicatively), the
        frequency cap on a linear ``f_step_hz`` grid, and the battery
        energy scale on a ``scale_step`` grid.  Timestamp and raw
        SoC/temperature are dropped (they do not enter the solve).
        """
        if self.link_bps > 0.0:
            q = round(math.log2(self.link_bps) * link_steps_per_octave)
            link = 2.0 ** (q / link_steps_per_octave)
        else:
            link = 0.0
        # floor at one bucket: a positive cap must never quantize to 0 Hz
        f_cap = self.f_cap_hz if math.isinf(self.f_cap_hz) \
            else max(round(self.f_cap_hz / f_step_hz) * f_step_hz,
                     f_step_hz)
        scale = max(scale_step,
                    round(self.energy_scale / scale_step) * scale_step)
        return EnvState(t_s=0.0, link_bps=link, f_cap_hz=f_cap,
                        battery_soc=0.0, temp_c=0.0,
                        energy_scale=min(scale, 1.0))

    def key(self) -> tuple:
        """Hashable identity of the decision-relevant fields — what the
        ``CodesignCache`` env keyspace and the drift detector compare."""
        return (round(self.link_bps, 6), round(self.f_cap_hz, 3),
                round(self.energy_scale, 6))


class Environment:
    """Deterministic composition of environment processes.

    All traces are realized at construction over ``horizon_s`` in steps
    of ``dt_s`` from child streams of ``seed``; :meth:`state_at` indexes
    them with clamp-at-the-ends semantics, so any virtual-clock time maps
    to a well-defined state.

    ``link`` / ``f_cap`` / ``battery`` are processes from
    ``processes.py`` (anything with ``realize(rng, n, dt)``); each is
    optional — an :class:`Environment` with none of them is the identity
    (``apply`` returns the base ``SystemParams`` unchanged, energy scale
    1.0), which the adaptive engine serves bitwise identically to the
    static one.

    Battery → energy budget: above ``battery_reserve_soc`` the scale is
    1.0; below it the scale falls linearly with SoC down to
    ``battery_min_scale`` at empty — the OS-governor analogue of "stretch
    the remaining charge by spending less per request".
    """

    def __init__(self, *, dt_s: float = 0.5, horizon_s: float = 60.0,
                 seed: int = 0,
                 link=None, f_cap=None, battery=None,
                 battery_reserve_soc: float = 0.25,
                 battery_min_scale: float = 0.25):
        if dt_s <= 0 or horizon_s <= 0:
            raise ValueError("dt_s and horizon_s must be positive")
        self.dt_s = float(dt_s)
        self.n_steps = max(1, int(math.ceil(horizon_s / dt_s)))
        self.horizon_s = self.n_steps * self.dt_s
        self.seed = int(seed)
        self.battery_reserve_soc = float(battery_reserve_soc)
        self.battery_min_scale = float(battery_min_scale)
        r_link, r_fcap, r_batt = (np.random.default_rng(s) for s in
                                  np.random.SeedSequence(seed).spawn(3))
        n, dt = self.n_steps, self.dt_s
        self.link_trace = link.realize(r_link, n, dt) if link is not None \
            else np.zeros(n)
        if f_cap is not None:
            self.f_cap_trace = np.asarray(f_cap.realize(r_fcap, n, dt),
                                          np.float64)
            self.temp_trace = f_cap.temperature(n, dt) \
                if hasattr(f_cap, "temperature") else np.zeros(n)
        else:
            self.f_cap_trace = np.full(n, math.inf)
            self.temp_trace = np.zeros(n)
        self.soc_trace = battery.realize(r_batt, n, dt) \
            if battery is not None else np.ones(n)

    # ------------------------------------------------------------------
    def _energy_scale(self, soc: float) -> float:
        if soc >= self.battery_reserve_soc:
            return 1.0
        frac = soc / max(self.battery_reserve_soc, 1e-12)
        return self.battery_min_scale \
            + frac * (1.0 - self.battery_min_scale)

    def index_at(self, t_s: float) -> int:
        return min(max(int(t_s / self.dt_s), 0), self.n_steps - 1)

    def state_at(self, t_s: float) -> EnvState:
        k = self.index_at(t_s)
        soc = float(self.soc_trace[k])
        return EnvState(t_s=float(t_s),
                        link_bps=float(self.link_trace[k]),
                        f_cap_hz=float(self.f_cap_trace[k]),
                        battery_soc=soc,
                        temp_c=float(self.temp_trace[k]),
                        energy_scale=self._energy_scale(soc))

    def states(self) -> Iterator[EnvState]:
        for k in range(self.n_steps):
            yield self.state_at(k * self.dt_s)

    def is_constant(self) -> bool:
        """True when every step carries the same decision-relevant state
        (the bitwise-identity precondition of the adaptive engine)."""
        keys = {s.key() for s in self.states()}
        return len(keys) <= 1
