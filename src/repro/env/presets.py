"""Canned environment scenarios (DESIGN.md §9) for the launch driver,
examples, and benchmarks — one function per `--env-trace` choice.

Each preset returns a fully-seeded :class:`~repro.env.Environment`; the
numbers are edge-plausible defaults (home-Wi-Fi uplink rates, Jetson-ish
thermal envelope, the Table I low/medium/high frequency profiles), not
paper constants — override per call site where a benchmark needs a
specific regime.
"""

from __future__ import annotations

from typing import Optional, Sequence

from .environment import Environment
from .faults import (AgentDropout, ChaosTrace, LinkOutage,
                     PacketCorruption, ServerPreemption)
from .processes import (Battery, MarkovLink, RayleighLink, ThermalThrottle,
                        TraceReplay)

__all__ = ["PROFILE_FMAX", "wifi_markov", "rayleigh_fading",
           "profile_replay", "battery_drain", "edge_day", "constant",
           "chaos_outage", "chaos_corruption", "chaos_preemption",
           "chaos_storm", "chaos_clean"]

# Table I coarse frequency profiles (benchmarks/testbed_profiles.py);
# duplicated here so src/ never imports from benchmarks/
PROFILE_FMAX = {"low": 0.6e9, "medium": 1.2e9, "high": 2.0e9}

# good / fair / bad home-uplink states in bytes/s (~20 / 4 / 0.8 Mbit/s)
_WIFI_RATES = (2.5e6, 5.0e5, 1.0e5)
_WIFI_TRANSITION = ((0.90, 0.08, 0.02),
                    (0.10, 0.80, 0.10),
                    (0.05, 0.20, 0.75))


def wifi_markov(*, seed: int = 0, horizon_s: float = 60.0,
                dt_s: float = 0.5,
                rates_bps: Sequence[float] = _WIFI_RATES,
                transition=_WIFI_TRANSITION) -> Environment:
    """Markov-chain Wi-Fi uplink; computation constants untouched.

    Defaults model a home link hopping between good/fair/bad states
    (~20/4/0.8 Mbit/s) with sticky transitions; the adaptive engine
    sees it as a time-varying ``SystemParams.link_bps``."""
    return Environment(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                       link=MarkovLink(rates_bps=rates_bps,
                                       transition=transition))


def rayleigh_fading(*, seed: int = 0, horizon_s: float = 60.0,
                    dt_s: float = 0.5, bandwidth_hz: float = 5.0e6,
                    mean_snr: float = 8.0,
                    coherence_s: float = 2.0) -> Environment:
    """Rayleigh block-fading uplink rate trace.

    Continuous-valued rates (Shannon over an Exp(1) power gain per
    ``coherence_s`` block) — the stress case for the adaptive engine's
    state *quantizer*: raw rates almost never repeat, so only the
    log-bucketed keys keep the codesign cache and drift detector
    effective (DESIGN.md §9)."""
    return Environment(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                       link=RayleighLink(bandwidth_hz=bandwidth_hz,
                                         mean_snr=mean_snr,
                                         coherence_s=coherence_s))


def profile_replay(schedule: Sequence[str] = ("high", "low", "medium"),
                   *, seed: int = 0, dwell_s: float = 20.0,
                   dt_s: float = 0.5,
                   profiles: Optional[dict] = None) -> Environment:
    """Replay a coarse-frequency-profile schedule as the f_max cap —
    the Table I testbed profiles as a time-varying governor.

    ``schedule`` names entries of ``profiles`` (default
    :data:`PROFILE_FMAX`), each held for ``dwell_s``; the horizon is
    exactly one pass over the schedule (the last profile then holds,
    per ``TraceReplay`` clamping)."""
    fmap = PROFILE_FMAX if profiles is None else profiles
    caps = [fmap[name] for name in schedule]
    return Environment(seed=seed, horizon_s=dwell_s * len(schedule),
                       dt_s=dt_s,
                       f_cap=TraceReplay(values=caps, dwell_s=dwell_s))


def battery_drain(*, seed: int = 0, horizon_s: float = 60.0,
                  dt_s: float = 0.5, capacity_j: float = 900.0,
                  drain_w: float = 12.0, soc0: float = 0.6) -> Environment:
    """Battery running down over the horizon; E0 derates below reserve.

    Defaults start at 60% charge with a drain that crosses the
    environment's reserve SoC mid-horizon, so per-request energy
    budgets visibly tighten (``EnvState.energy_scale``) during a run."""
    return Environment(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                       battery=Battery(capacity_j=capacity_j,
                                       drain_w=drain_w, soc0=soc0))


def edge_day(*, seed: int = 0, horizon_s: float = 90.0,
             dt_s: float = 0.5) -> Environment:
    """The kitchen-sink scenario: Markov Wi-Fi + thermal throttling under
    sustained load + battery drain — all three knobs moving at once.

    The thermal time constant is horizon/4 so the throttle actually
    bites within the run, and the battery crosses its reserve — the
    default demo trace of ``launch/serve.py --env-trace edge-day``."""
    return Environment(
        seed=seed, horizon_s=horizon_s, dt_s=dt_s,
        link=MarkovLink(rates_bps=_WIFI_RATES, transition=_WIFI_TRANSITION),
        f_cap=ThermalThrottle(tau_s=horizon_s / 4.0),
        battery=Battery(capacity_j=40.0 * horizon_s, drain_w=15.0,
                        soc0=0.5))


# ----------------------------------------------------------------------
# chaos presets (DESIGN.md §15) — seeded fault schedules for the
# supervisor, one per headline failure mode plus the kitchen sink
# ----------------------------------------------------------------------
def chaos_outage(*, seed: int = 0, horizon_s: float = 60.0,
                 dt_s: float = 0.5) -> ChaosTrace:
    """Flaky uplink: sticky Markov outages, ~14% of steps dark.

    The headline goodput scenario of ``benchmarks/chaos.py``: a bare
    engine loses every request in flight during a dark window, the
    supervisor backs off and retries through it."""
    return ChaosTrace(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                      link_outage=LinkOutage(p_fail=0.05, p_recover=0.30))


def chaos_corruption(*, seed: int = 0, horizon_s: float = 60.0,
                     dt_s: float = 0.5) -> ChaosTrace:
    """Noisy uplink: payload bit-flips on ~5% of transmissions — the
    checksum detect-and-retransmit scenario."""
    return ChaosTrace(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                      corruption=PacketCorruption(rate=0.05))


def chaos_preemption(*, seed: int = 0, horizon_s: float = 60.0,
                     dt_s: float = 0.5) -> ChaosTrace:
    """Preemptible edge server: crash/restart windows (MTBF 20 s,
    MTTR 4 s) — the decode snapshot/restore recovery scenario."""
    return ChaosTrace(seed=seed, horizon_s=horizon_s, dt_s=dt_s,
                      preemption=ServerPreemption(mtbf_s=20.0, mttr_s=4.0))


def chaos_storm(*, seed: int = 0, horizon_s: float = 90.0,
                dt_s: float = 0.5, n_agents: int = 1) -> ChaosTrace:
    """Everything at once: outages + corruption + preemption (+ fleet
    dropout when ``n_agents > 1``) — the zero-lost/zero-duplicated
    token stress test."""
    return ChaosTrace(
        seed=seed, horizon_s=horizon_s, dt_s=dt_s, n_agents=n_agents,
        link_outage=LinkOutage(p_fail=0.04, p_recover=0.35),
        corruption=PacketCorruption(rate=0.03),
        preemption=ServerPreemption(mtbf_s=30.0, mttr_s=5.0),
        dropout=AgentDropout(p_drop=0.02, p_rejoin=0.25)
        if n_agents > 1 else None)


def chaos_clean(*, seed: int = 0, horizon_s: float = 60.0,
                dt_s: float = 0.5) -> ChaosTrace:
    """The identity fault schedule: nothing ever fails, so the
    supervisor passes every step straight through and is bitwise
    identical to the bare engine (the §15 identity contract)."""
    return ChaosTrace(seed=seed, horizon_s=horizon_s, dt_s=dt_s)


def constant(*, horizon_s: float = 60.0, dt_s: float = 0.5,
             seed: int = 0) -> Environment:
    """The identity environment: no process attached, every state equal —
    the adaptive engine on it is bitwise identical to the static one
    (the §9 identity contract; ``seed`` is accepted for interface
    symmetry but nothing in the trace is random)."""
    return Environment(seed=seed, horizon_s=horizon_s, dt_s=dt_s)
