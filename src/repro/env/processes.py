"""Composable dynamic-environment processes (DESIGN.md §9).

Each process realizes one per-step scalar trace over a fixed horizon —
uplink rate, device frequency cap, or battery state of charge — via

    realize(rng, n_steps, dt_s) -> np.ndarray [n_steps] float64

``rng`` is a ``numpy.random.Generator`` the caller seeds explicitly
(``environment.Environment`` spawns one child stream per process from a
single seed), so the same seed always yields the identical trace: the
whole subsystem is a deterministic function of (seed, horizon, dt).
Processes that are deterministic by construction (trace replay, battery
drain, the thermal RC model) simply ignore ``rng``.

The processes:

* :class:`MarkovLink`       — discrete-state Wi-Fi link (good/fair/bad …)
                              with a row-stochastic transition matrix,
                              one transition per step.
* :class:`RayleighLink`     — Rayleigh block fading: per coherence block
                              the power gain g ~ Exp(1), and the uplink
                              rate follows Shannon, B·log2(1 + SNR·g)/8
                              bytes/s.
* :class:`TraceReplay`      — step-function replay of an explicit value
                              schedule (e.g. the Table I low/medium/high
                              frequency profiles of
                              ``benchmarks/testbed_profiles.py``).
* :class:`Battery`          — state-of-charge drain under a baseline
                              platform power draw, clipped at empty.
* :class:`ThermalThrottle`  — first-order RC die-temperature model whose
                              temperature maps to an f_max cap (linear
                              derate between t_throttle and t_max).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

__all__ = ["MarkovLink", "RayleighLink", "TraceReplay", "Battery",
           "ThermalThrottle"]


@dataclasses.dataclass(frozen=True)
class MarkovLink:
    """Markov-chain Wi-Fi uplink: one named rate per state, one
    transition draw per step.

    ``rates_bps`` are uplink rates in *bytes*/s (the unit of
    ``SystemParams.link_bps``); ``transition[i][j]`` is the per-step
    probability of moving from state i to state j.
    """

    rates_bps: Sequence[float]
    transition: Sequence[Sequence[float]]
    init_state: int = 0

    def __post_init__(self):
        p = np.asarray(self.transition, np.float64)
        n = len(self.rates_bps)
        if p.shape != (n, n):
            raise ValueError(f"transition must be {n}x{n}, got {p.shape}")
        if not np.allclose(p.sum(axis=1), 1.0, atol=1e-9):
            raise ValueError("transition rows must sum to 1")
        if (p < 0).any():
            raise ValueError("transition probabilities must be >= 0")
        if not 0 <= self.init_state < n:
            raise ValueError(f"init_state {self.init_state} out of range")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        """Per-step uplink rates (bytes/s): start in ``init_state``,
        draw one Markov transition per step from ``rng``.  ``dt_s`` is
        unused — the chain is specified per step, so dwell times scale
        with the environment's resolution by construction."""
        rates = np.asarray(self.rates_bps, np.float64)
        p = np.asarray(self.transition, np.float64)
        out = np.empty(n_steps, np.float64)
        s = self.init_state
        for k in range(n_steps):
            out[k] = rates[s]
            s = int(rng.choice(len(rates), p=p[s]))
        return out


@dataclasses.dataclass(frozen=True)
class RayleighLink:
    """Rayleigh block-fading uplink rate trace.

    Per coherence block the channel power gain is g ~ Exponential(1)
    (Rayleigh amplitude), and the achievable rate is Shannon's
    ``bandwidth_hz * log2(1 + mean_snr * g) / 8`` bytes/s, floored at
    ``rate_floor_bps`` (a deeply faded link still carries the control
    channel rather than dropping to exactly zero).
    """

    bandwidth_hz: float
    mean_snr: float            # linear (not dB)
    coherence_s: float         # fading block length
    rate_floor_bps: float = 1e3

    def __post_init__(self):
        if self.bandwidth_hz <= 0 or self.mean_snr <= 0 \
                or self.coherence_s <= 0:
            raise ValueError("bandwidth_hz, mean_snr and coherence_s must "
                             "be positive")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        """Per-step Shannon rates (bytes/s): one i.i.d. Exp(1) power
        gain per coherence block, each step indexing into the block
        covering its timestamp (so the trace is piecewise constant on
        ``coherence_s`` and independent of ``dt_s`` resolution)."""
        n_blocks = max(1, int(math.ceil(n_steps * dt_s / self.coherence_s)))
        gains = rng.exponential(1.0, size=n_blocks)
        rates = self.bandwidth_hz * np.log2(1.0 + self.mean_snr * gains) / 8.0
        rates = np.maximum(rates, self.rate_floor_bps)
        idx = np.minimum((np.arange(n_steps) * dt_s
                          / self.coherence_s).astype(np.int64), n_blocks - 1)
        return rates[idx]


@dataclasses.dataclass(frozen=True)
class TraceReplay:
    """Deterministic step-function replay of an explicit schedule.

    ``values[i]`` holds for ``dwell_s`` seconds; the last value holds
    forever (clamped, so any horizon is covered).  This is how measured
    testbed profiles — e.g. the Table I low/medium/high frequency map of
    ``benchmarks/testbed_profiles.py`` — replay as an f_max-cap process.
    """

    values: Sequence[float]
    dwell_s: float

    def __post_init__(self):
        if not len(self.values):
            raise ValueError("need at least one value to replay")
        if self.dwell_s <= 0:
            raise ValueError("dwell_s must be positive")

    def realize(self, rng: Optional[np.random.Generator], n_steps: int,
                dt_s: float) -> np.ndarray:
        """Per-step values: step k reads ``values[k·dt/dwell]``, clamped
        to the last entry; ``rng`` is accepted but unused (the replay is
        deterministic by construction)."""
        vals = np.asarray(self.values, np.float64)
        idx = np.minimum((np.arange(n_steps) * dt_s
                          / self.dwell_s).astype(np.int64), len(vals) - 1)
        return vals[idx]


@dataclasses.dataclass(frozen=True)
class Battery:
    """State-of-charge drain under a baseline platform draw.

    soc(t) = clip(soc0 − drain_w·t / capacity_j, 0, 1) — deterministic,
    so the oracle/static/adaptive policies of the benchmark see the same
    battery no matter what they serve.  The serving-side consequence
    (tightening per-request energy budgets as charge runs down) is the
    environment's ``energy_scale`` (environment.py), not the process's.
    """

    capacity_j: float
    drain_w: float
    soc0: float = 1.0

    def __post_init__(self):
        if self.capacity_j <= 0:
            raise ValueError("capacity_j must be positive")
        if self.drain_w < 0:
            raise ValueError("drain_w must be >= 0")
        if not 0.0 < self.soc0 <= 1.0:
            raise ValueError("soc0 must be in (0, 1]")

    def realize(self, rng: Optional[np.random.Generator], n_steps: int,
                dt_s: float) -> np.ndarray:
        """Per-step state of charge in [0, 1]: linear drain from
        ``soc0`` at ``drain_w`` watts against ``capacity_j``, clipped at
        empty; ``rng`` is accepted but unused (deterministic)."""
        t = np.arange(n_steps) * dt_s
        return np.clip(self.soc0 - self.drain_w * t / self.capacity_j,
                       0.0, 1.0)


@dataclasses.dataclass(frozen=True)
class ThermalThrottle:
    """First-order RC thermal model driving an f_max cap.

    Die temperature relaxes toward ``ambient + duty·(peak − ambient)``
    with time constant ``tau_s`` (duty is a constant load fraction or a
    per-step schedule).  The cap is ``f_full_hz`` below ``t_throttle_c``,
    ``f_floor_hz`` above ``t_max_c``, and linearly derated between —
    the Jetson-style governor of the paper's testbed.
    """

    f_full_hz: float = 2.0e9
    f_floor_hz: float = 0.6e9
    t_ambient_c: float = 25.0
    t_peak_c: float = 95.0
    t_throttle_c: float = 70.0
    t_max_c: float = 90.0
    tau_s: float = 30.0
    duty: object = 1.0          # scalar in [0,1] or per-step sequence

    def __post_init__(self):
        if self.f_floor_hz > self.f_full_hz:
            raise ValueError("f_floor_hz must be <= f_full_hz")
        if self.t_max_c <= self.t_throttle_c:
            raise ValueError("t_max_c must be > t_throttle_c")
        if self.tau_s <= 0:
            raise ValueError("tau_s must be positive")

    def _duty_trace(self, n_steps: int) -> np.ndarray:
        """Per-step load fraction in [0, 1]: a scalar ``duty`` is
        broadcast, a sequence is clamp-extended with its last value
        (the same convention as :class:`TraceReplay`)."""
        if np.isscalar(self.duty):
            d = np.full(n_steps, float(self.duty))
        else:
            d = np.asarray(self.duty, np.float64)
            if d.shape[0] < n_steps:   # clamp-extend like TraceReplay
                d = np.concatenate([d, np.full(n_steps - d.shape[0], d[-1])])
            d = d[:n_steps]
        return np.clip(d, 0.0, 1.0)

    def temperature(self, n_steps: int, dt_s: float) -> np.ndarray:
        """Die-temperature trace (°C): first-order relaxation toward
        the duty-scaled target with step factor 1 − exp(−dt/τ), started
        from ambient.  Exposed separately so ``Environment`` can record
        the temperature alongside the frequency cap it induces."""
        duty = self._duty_trace(n_steps)
        temp = np.empty(n_steps, np.float64)
        t = self.t_ambient_c
        alpha = 1.0 - math.exp(-dt_s / self.tau_s)
        for k in range(n_steps):
            target = self.t_ambient_c + duty[k] \
                * (self.t_peak_c - self.t_ambient_c)
            t = t + alpha * (target - t)
            temp[k] = t
        return temp

    def cap_for(self, temp_c: np.ndarray) -> np.ndarray:
        """The governor map: f_full below ``t_throttle_c``, f_floor
        above ``t_max_c``, linear derate in between."""
        frac = np.clip((np.asarray(temp_c, np.float64) - self.t_throttle_c)
                       / (self.t_max_c - self.t_throttle_c), 0.0, 1.0)
        return self.f_full_hz - frac * (self.f_full_hz - self.f_floor_hz)

    def realize(self, rng: Optional[np.random.Generator], n_steps: int,
                dt_s: float) -> np.ndarray:
        """Per-step f_max caps (Hz): the governor map applied to the RC
        temperature trace; ``rng`` is accepted but unused."""
        return self.cap_for(self.temperature(n_steps, dt_s))
