"""Dynamic-environment simulation (DESIGN.md §9): composable link /
thermal / battery processes realized into deterministic, time-indexed
``SystemParams`` views for adaptive co-inference serving."""

from .environment import Environment, EnvState  # noqa: F401
from .faults import (AgentDropout, ChaosTrace, FaultState,  # noqa: F401
                     LinkOutage, PacketCorruption, ServerPreemption,
                     chaos_from_spec)
from .processes import (Battery, MarkovLink, RayleighLink,  # noqa: F401
                        ThermalThrottle, TraceReplay)
from . import presets  # noqa: F401
