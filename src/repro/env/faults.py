"""Fault-injecting environment processes (DESIGN.md §15).

The chaos counterpart of `processes.py`: seeded, deterministic fault
processes realized once, up front, into time-indexed traces — the same
contract as the §9 dynamic environment, so a fault schedule is a pure
function of ``(seed, dt_s, horizon_s, processes)`` and every run over
it replays bit-identically.  Four faults cover the deployment failure
modes of the co-inference split:

* :class:`LinkOutage` — the uplink goes binary up/down as a two-state
  Markov chain (layered on top of, not replacing, the §9 link-rate
  processes: an outage means *no* transport, not a slow one);
* :class:`PacketCorruption` — an uplink payload arrives bit-flipped
  with a configurable per-step probability (detected by the
  supervisor's payload checksum, DESIGN.md §15);
* :class:`ServerPreemption` — the edge server disappears for
  repair-time windows (crash/restart events for decode recovery);
* :class:`AgentDropout` — a fleet member leaves and rejoins, driving
  re-water-filling of the server shares (DESIGN.md §11, §15).

:class:`ChaosTrace` composes them into one indexed schedule
(:class:`FaultState` per step) the :class:`~repro.runtime.supervisor.
ServingSupervisor` samples at scheduling boundaries, and
:func:`chaos_from_spec` parses the JSON spec format of
``launch/serve.py --chaos-trace``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["LinkOutage", "PacketCorruption", "ServerPreemption",
           "AgentDropout", "FaultState", "ChaosTrace", "chaos_from_spec"]


# ----------------------------------------------------------------------
# fault processes — the `realize(rng, n_steps, dt_s) -> np.ndarray`
# protocol of processes.py, traces valued in {0.0, 1.0}
# ----------------------------------------------------------------------
def _markov_binary(rng: np.random.Generator, n_steps: int, *,
                   p_down: float, p_up: float, init_up: bool) -> np.ndarray:
    """Two-state up/down chain, one rng draw per step (so the schedule
    is a pure function of the seed regardless of parameter values)."""
    out = np.empty(n_steps, dtype=np.float64)
    up = bool(init_up)
    for i in range(n_steps):
        u = rng.random()
        if up:
            if u < p_down:
                up = False
        else:
            if u < p_up:
                up = True
        out[i] = 1.0 if up else 0.0
    return out


@dataclasses.dataclass(frozen=True)
class LinkOutage:
    """Binary uplink availability: a sticky Markov up/down chain.

    ``p_fail``/``p_recover`` are per-step transition probabilities; the
    stationary up-fraction is ``p_recover / (p_fail + p_recover)``
    (checked by the property tests).  Trace value 1.0 = link up.
    """

    p_fail: float = 0.05
    p_recover: float = 0.30
    init_up: bool = True

    def __post_init__(self):
        for name in ("p_fail", "p_recover"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        return _markov_binary(rng, n_steps, p_down=self.p_fail,
                              p_up=self.p_recover, init_up=self.init_up)


@dataclasses.dataclass(frozen=True)
class PacketCorruption:
    """Uplink payload bit-flips: each step's transmission is corrupted
    independently with probability ``rate``.  Trace value 1.0 = the
    payload sent during this step arrives corrupted (the supervisor's
    checksum detects it and retransmits; a bare engine serves garbage).
    """

    rate: float = 0.02

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        return (rng.random(n_steps) < self.rate).astype(np.float64)


@dataclasses.dataclass(frozen=True)
class ServerPreemption:
    """Edge-server crash/restart windows: up/down Markov chain whose
    per-step rates derive from a mean time between failures and a mean
    time to repair, so the same physical story holds across ``dt_s``.
    Trace value 1.0 = server up."""

    mtbf_s: float = 30.0
    mttr_s: float = 5.0
    init_up: bool = True

    def __post_init__(self):
        for name in ("mtbf_s", "mttr_s"):
            v = getattr(self, name)
            if v <= 0.0:
                raise ValueError(f"{name} must be positive, got {v}")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        p_down = min(1.0, float(dt_s) / self.mtbf_s)
        p_up = min(1.0, float(dt_s) / self.mttr_s)
        return _markov_binary(rng, n_steps, p_down=p_down, p_up=p_up,
                              init_up=self.init_up)


@dataclasses.dataclass(frozen=True)
class AgentDropout:
    """Fleet-membership churn: one independent present/absent Markov
    chain per agent (``ChaosTrace`` realizes one child stream per
    agent).  Trace value 1.0 = agent present."""

    p_drop: float = 0.02
    p_rejoin: float = 0.20

    def __post_init__(self):
        for name in ("p_drop", "p_rejoin"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")

    def realize(self, rng: np.random.Generator, n_steps: int,
                dt_s: float) -> np.ndarray:
        return _markov_binary(rng, n_steps, p_down=self.p_drop,
                              p_up=self.p_rejoin, init_up=True)


# ----------------------------------------------------------------------
# composed schedule
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultState:
    """The fault vector at one instant (the §15 analogue of §9's
    ``EnvState``): what is up, what is corrupting, who is present."""

    t_s: float
    link_up: bool = True
    corrupt: bool = False
    server_up: bool = True
    agents_up: Tuple[bool, ...] = ()

    @property
    def server_reachable(self) -> bool:
        """True when the co-inference uplink can complete: both the
        link and the server must be up."""
        return self.link_up and self.server_up


class ChaosTrace:
    """A seeded, fully-realized fault schedule over a finite horizon.

    Mirrors :class:`~repro.env.environment.Environment`: child rng
    streams are spawned from one ``SeedSequence`` (one per process plus
    one per fleet agent), every process is realized once at
    construction, and lookups are pure indexing — so two traces built
    from the same arguments are identical arrays and a supervisor run
    over them is deterministic.  Beyond the trace the last state holds
    (clamp-extend, like ``TraceReplay``).
    """

    def __init__(self, *, dt_s: float = 0.5, horizon_s: float = 60.0,
                 seed: int = 0,
                 link_outage: Optional[LinkOutage] = None,
                 corruption: Optional[PacketCorruption] = None,
                 preemption: Optional[ServerPreemption] = None,
                 dropout: Optional[AgentDropout] = None,
                 n_agents: int = 1):
        if dt_s <= 0.0:
            raise ValueError(f"dt_s must be positive, got {dt_s}")
        if horizon_s <= 0.0:
            raise ValueError(f"horizon_s must be positive, got {horizon_s}")
        if n_agents < 1:
            raise ValueError(f"n_agents must be >= 1, got {n_agents}")
        self.dt_s = float(dt_s)
        self.horizon_s = float(horizon_s)
        self.seed = int(seed)
        self.n_agents = int(n_agents)
        self.link_outage = link_outage
        self.corruption = corruption
        self.preemption = preemption
        self.dropout = dropout
        n = max(1, int(round(self.horizon_s / self.dt_s)))
        self.n_steps = n

        # one child stream per process slot + one per agent, spawned in
        # a fixed order so adding a process never reshuffles the others
        streams = [np.random.default_rng(s) for s in
                   np.random.SeedSequence(self.seed).spawn(3 + self.n_agents)]
        r_link, r_corrupt, r_server = streams[:3]
        ones = np.ones(n, dtype=np.float64)
        self.link_up = (link_outage.realize(r_link, n, self.dt_s)
                        if link_outage is not None else ones) > 0.5
        self.corrupt = (corruption.realize(r_corrupt, n, self.dt_s)
                        if corruption is not None
                        else np.zeros(n, dtype=np.float64)) > 0.5
        self.server_up = (preemption.realize(r_server, n, self.dt_s)
                          if preemption is not None else ones) > 0.5
        self.agents_up = np.stack(
            [(dropout.realize(streams[3 + i], n, self.dt_s)
              if dropout is not None else ones) > 0.5
             for i in range(self.n_agents)])

    # -- lookup -------------------------------------------------------
    @property
    def end_s(self) -> float:
        """One step past the last trace index; a ``_next_true``-family
        answer equal to this means 'never within the trace'."""
        return self.n_steps * self.dt_s

    def index_at(self, t_s: float) -> int:
        return int(np.clip(int(t_s / self.dt_s), 0, self.n_steps - 1))

    def fault_at(self, t_s: float) -> FaultState:
        i = self.index_at(t_s)
        return FaultState(
            t_s=i * self.dt_s,
            link_up=bool(self.link_up[i]),
            corrupt=bool(self.corrupt[i]),
            server_up=bool(self.server_up[i]),
            agents_up=tuple(bool(v) for v in self.agents_up[:, i]))

    def states(self) -> Iterator[FaultState]:
        for i in range(self.n_steps):
            yield self.fault_at(i * self.dt_s)

    # -- schedule queries (supervisor recovery planning) --------------
    def _next_true(self, flags: np.ndarray, t_s: float) -> float:
        """First trace time >= ``t_s`` at which ``flags`` holds; past
        the horizon the trace clamp-extends, so if the tail is down the
        answer is one step past the end (the clamped state there is the
        last step's — callers treat it as 'never recovered in trace')."""
        i = self.index_at(t_s)
        j = int(np.argmax(flags[i:])) + i if flags[i:].any() \
            else self.n_steps
        return j * self.dt_s

    def next_server_up(self, t_s: float) -> float:
        return self._next_true(self.server_up & self.link_up, t_s)

    def next_link_up(self, t_s: float) -> float:
        return self._next_true(self.link_up, t_s)

    def next_agent_up(self, agent_idx: int, t_s: float) -> float:
        return self._next_true(self.agents_up[int(agent_idx)], t_s)

    # -- aggregates ---------------------------------------------------
    def is_clean(self) -> bool:
        """True when no fault ever fires — the supervisor's pass-through
        (bitwise-identity) trigger."""
        return bool(self.link_up.all() and self.server_up.all()
                    and (~self.corrupt).all() and self.agents_up.all())

    def outage_fraction(self) -> float:
        """Fraction of steps during which the server is unreachable."""
        return float(np.mean(~(self.link_up & self.server_up)))

    def corruption_fraction(self) -> float:
        return float(np.mean(self.corrupt))


# ----------------------------------------------------------------------
# JSON spec (launch/serve.py --chaos-trace)
# ----------------------------------------------------------------------
_TOP_KEYS = {"dt_s", "horizon_s", "seed", "link_outage", "corruption",
             "preemption", "dropout"}
_SECTION_FIELDS = {
    "link_outage": {"p_fail", "p_recover", "init_up"},
    "corruption": {"rate"},
    "preemption": {"mtbf_s", "mttr_s", "init_up"},
    "dropout": {"p_drop", "p_rejoin", "n_agents"},
}


def _section(spec: dict, name: str) -> Optional[dict]:
    sub = spec.get(name)
    if sub is None:
        return None
    if not isinstance(sub, dict):
        raise ValueError(f"chaos spec: {name!r} must be an object, "
                         f"got {type(sub).__name__}")
    unknown = set(sub) - _SECTION_FIELDS[name]
    if unknown:
        raise ValueError(f"chaos spec: unknown key(s) in {name!r}: "
                         f"{sorted(unknown)}")
    return sub


def chaos_from_spec(spec: dict, *, seed: Optional[int] = None) -> ChaosTrace:
    """Build a :class:`ChaosTrace` from the ``--chaos-trace`` JSON spec.

    Raises :class:`ValueError` with a one-line message on any malformed
    spec (unknown keys, wrong types, out-of-range rates) — the CLI maps
    it to exit code 2, mirroring the fleet-spec handling.  ``seed``
    overrides the spec's own seed when given.
    """
    if not isinstance(spec, dict):
        raise ValueError("chaos spec: top level must be a JSON object, "
                         f"got {type(spec).__name__}")
    unknown = set(spec) - _TOP_KEYS
    if unknown:
        raise ValueError(f"chaos spec: unknown top-level key(s): "
                         f"{sorted(unknown)}")
    for key in ("dt_s", "horizon_s", "seed"):
        if key in spec and not isinstance(spec[key], (int, float)):
            raise ValueError(f"chaos spec: {key!r} must be a number, "
                             f"got {type(spec[key]).__name__}")
    n_agents = 1
    link = corr = preempt = drop = None
    try:
        sub = _section(spec, "link_outage")
        if sub is not None:
            link = LinkOutage(**{k: sub[k] for k in sub})
        sub = _section(spec, "corruption")
        if sub is not None:
            corr = PacketCorruption(**{k: sub[k] for k in sub})
        sub = _section(spec, "preemption")
        if sub is not None:
            preempt = ServerPreemption(**{k: sub[k] for k in sub})
        sub = _section(spec, "dropout")
        if sub is not None:
            n_agents = int(sub.get("n_agents", 1))
            drop = AgentDropout(**{k: sub[k] for k in sub
                                   if k != "n_agents"})
    except TypeError as e:  # wrong field type reaching a dataclass
        raise ValueError(f"chaos spec: {e}") from e
    return ChaosTrace(
        dt_s=float(spec.get("dt_s", 0.5)),
        horizon_s=float(spec.get("horizon_s", 60.0)),
        seed=int(seed if seed is not None else spec.get("seed", 0)),
        link_outage=link, corruption=corr, preemption=preempt,
        dropout=drop, n_agents=n_agents)
