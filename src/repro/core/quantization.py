"""Model quantizers (paper §II-A, §VI-A).

The paper's scheme: keep the sign bit, quantize the magnitude with
``b_hat - 1`` bits.  Two codebooks are evaluated:

  * uniform      — fixed step over [0, absmax]          (paper ref [31])
  * pot-log      — power-of-two logarithmic levels       (paper ref [32])

Everything operates on arrays or whole parameter pytrees.  Two execution
styles:

  * ``quantize_dequantize``  — "fake quant" used for distortion analysis and
    QAT (straight-through estimator gradients);
  * ``quantize`` / ``dequantize`` — real integer codes + scales, the storage
    format consumed by ``repro.kernels.qmm`` (int8/int4-resident matmul).

Granularity: per-tensor, per-channel (last axis), or per-group along the
contraction axis — per-group is what the Pallas kernel consumes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Literal

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "quantize_dequantize",
    "quantize",
    "dequantize",
    "quantize_tree",
    "fake_quantize_tree",
    "qat_quantize",
    "uniform_step_size",
    "max_quant_error",
    "pack_int4",
    "unpack_int4",
]

Scheme = Literal["uniform", "pot-log"]
Granularity = Literal["per-tensor", "per-channel", "per-group"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How to quantize one tensor (or a whole tree)."""

    bits: int = 8                       # total bits incl. sign (paper's b_hat)
    scheme: Scheme = "uniform"
    granularity: Granularity = "per-channel"
    group_size: int = 128               # for per-group
    # Which pytree leaves to quantize: predicate on (path, leaf).  2D+ weights
    # only by default — biases/norm gains stay full precision (paper keeps
    # them; their byte count is negligible).
    min_ndim: int = 2

    def __post_init__(self):
        if self.bits < 1:
            raise ValueError(f"bits must be >= 1, got {self.bits}")
        if self.scheme not in ("uniform", "pot-log"):
            raise ValueError(f"unknown scheme {self.scheme!r}")

    @property
    def magnitude_levels(self) -> int:
        """Number of magnitude codepoints: 2^(bits-1) (sign kept separately)."""
        return 2 ** (self.bits - 1)


# ---------------------------------------------------------------------------
# Scale computation
# ---------------------------------------------------------------------------

def _absmax(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Reduction producing the scale denominator, shaped for broadcasting."""
    if cfg.granularity == "per-tensor":
        return jnp.max(jnp.abs(x))
    if cfg.granularity == "per-channel":
        # reduce all axes but the last (output-feature axis for [in, out] mats)
        axes = tuple(range(x.ndim - 1))
        return jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    if cfg.granularity == "per-group":
        # group along the first (contraction) axis
        g = cfg.group_size
        if x.shape[0] % g != 0:
            # fall back to per-channel when the axis doesn't tile
            axes = tuple(range(x.ndim - 1))
            return jnp.max(jnp.abs(x), axis=axes, keepdims=True)
        xg = x.reshape((x.shape[0] // g, g) + x.shape[1:])
        return jnp.repeat(jnp.max(jnp.abs(xg), axis=1), g, axis=0)
    raise ValueError(cfg.granularity)


def uniform_step_size(absmax: jax.Array, bits: int) -> jax.Array:
    """Uniform-quantizer step Delta = absmax / (2^(bits-1) - 1).

    bits includes the sign bit; magnitudes get 2^(bits-1)-1 nonzero levels.
    Guard bits==1: a 1-bit code is sign-only, magnitude collapses to a single
    reconstruction level (we use absmax/2, the conditional mean surrogate).
    """
    levels = max(2 ** (bits - 1) - 1, 1)
    return absmax / levels


def max_quant_error(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """tau bound of Assumption 3: worst-case per-element |w - w_hat|.

    Uniform: Delta/2.  PoT-log with geometric rounding in the exponent: a
    magnitude just above the k/(k+1) boundary amax·2^{-(k+0.5)} rounds UP to
    amax·2^{-k}, so the worst relative error is (1 - 2^{-1/2}) ~ 0.2929 of
    the top level — i.e. tau = (1 - 1/sqrt(2)) · absmax (k = 0 dominates),
    plus the underflow-to-zero floor for 1-level codebooks.
    """
    amax = _absmax(x, cfg)
    if cfg.scheme == "uniform":
        return jnp.max(uniform_step_size(amax, cfg.bits)) / 2.0
    n = cfg.magnitude_levels
    if n <= 1:
        return jnp.max(amax)  # sign-only: recon amax/2, worst err ~ amax
    round_up = (1.0 - 2.0 ** -0.5) * jnp.max(amax)
    floor = jnp.max(amax) * 2.0 ** (-(n - 1))  # underflow-to-zero half-gap
    return jnp.maximum(round_up, floor)


# ---------------------------------------------------------------------------
# Core quantizers (array level)
# ---------------------------------------------------------------------------

def _uniform_qdq(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    amax = _absmax(x, cfg)
    step = uniform_step_size(amax, cfg.bits)
    step = jnp.where(step <= 0, 1.0, step)
    mag = jnp.abs(x)
    if cfg.bits == 1:
        # sign-only code: reconstruct magnitude at its conditional mean proxy
        recon = amax / 2.0
        return jnp.sign(x) * jnp.broadcast_to(recon, x.shape)
    levels = 2 ** (cfg.bits - 1) - 1
    q = jnp.clip(jnp.round(mag / step), 0, levels)
    return jnp.sign(x) * q * step


def _potlog_qdq(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Power-of-two logarithmic codebook: {0} U {amax 2^{-k}, k=0..n-2}."""
    amax = _absmax(x, cfg)
    amax = jnp.where(amax <= 0, 1.0, amax)
    n = cfg.magnitude_levels
    if n <= 1:
        recon = amax / 2.0
        return jnp.sign(x) * jnp.broadcast_to(recon, x.shape)
    mag = jnp.abs(x)
    # exponent k = round(log2(amax / mag)), clipped to codebook range
    safe = jnp.maximum(mag, jnp.finfo(x.dtype).tiny)
    k = jnp.round(jnp.log2(amax / safe))
    k = jnp.clip(k, 0, n - 2)
    recon = amax * jnp.exp2(-k)
    # underflow to zero: anything below half the smallest level
    smallest = amax * (2.0 ** (-(n - 2)))
    recon = jnp.where(mag < smallest / 2.0, 0.0, recon)
    return jnp.sign(x) * recon


def quantize_dequantize(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Fake-quantization (quantize then immediately dequantize)."""
    if cfg.scheme == "uniform":
        return _uniform_qdq(x, cfg)
    return _potlog_qdq(x, cfg)


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Integer codes + scale, the storage format for quantized weights.

    ``codes`` is int8 regardless of bits<=8 (int4 values live in [-7, 7];
    use :func:`pack_int4` for the 2-per-byte wire format).
    """

    codes: jax.Array          # int8, same shape as original
    scale: jax.Array          # broadcastable to codes.shape
    bits: int
    scheme: Scheme

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    @property
    def dtype(self):
        return self.codes.dtype

    def astype(self, dtype) -> jax.Array:
        """Transparent dequant-on-read: model code does
        ``p["w"].astype(x.dtype)`` before every matmul, so swapping a float
        leaf for a QuantizedTensor makes the weights int8-resident in HBM
        with the dequant fused into the consumer by XLA (the pure-JAX
        analogue of kernels/qmm.py; used by the serving dry-run)."""
        return dequantize(self, dtype)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype)

    def nbytes_effective(self) -> int:
        """Storage bytes at the nominal bit-width (what goes over the wire)."""
        import numpy as _np
        n = int(_np.prod(self.codes.shape))
        scale_bytes = int(_np.prod(self.scale.shape)) * 4
        return (n * self.bits + 7) // 8 + scale_bytes


jax.tree_util.register_pytree_node(
    QuantizedTensor,
    lambda qt: ((qt.codes, qt.scale), (qt.bits, qt.scheme)),
    lambda aux, ch: QuantizedTensor(ch[0], ch[1], aux[0], aux[1]),
)


def quantize(x: jax.Array, cfg: QuantConfig) -> QuantizedTensor:
    """Real quantization to integer codes (uniform scheme)."""
    if cfg.scheme != "uniform":
        raise NotImplementedError(
            "integer-code storage implemented for the uniform scheme; "
            "pot-log uses quantize_dequantize (codes are exponents).")
    amax = _absmax(x, cfg)
    step = uniform_step_size(amax, cfg.bits)
    step = jnp.where(step <= 0, 1.0, step)
    levels = max(2 ** (cfg.bits - 1) - 1, 1)
    q = jnp.clip(jnp.round(x / step), -levels, levels).astype(jnp.int8)
    return QuantizedTensor(codes=q, scale=step.astype(jnp.float32),
                           bits=cfg.bits, scheme=cfg.scheme)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return (qt.codes.astype(dtype) * qt.scale.astype(dtype)).astype(dtype)


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8-held int4 codes (two per byte) along the last axis."""
    if codes.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even to pack int4")
    lo = codes[..., 0::2] & 0x0F
    hi = (codes[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extends 4-bit two's complement)."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    def sext(v):
        return jnp.where(v >= 8, v - 16, v)
    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Pytree level
# ---------------------------------------------------------------------------

def _should_quantize(path, leaf, cfg: QuantConfig) -> bool:
    del path
    return hasattr(leaf, "ndim") and leaf.ndim >= cfg.min_ndim and \
        jnp.issubdtype(leaf.dtype, jnp.floating)


def fake_quantize_tree(params: Any, cfg: QuantConfig) -> Any:
    """Apply quantize-dequantize to every eligible leaf of a param pytree."""
    def f(path, leaf):
        if _should_quantize(path, leaf, cfg):
            return quantize_dequantize(leaf, cfg)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)


def quantize_tree(params: Any, cfg: QuantConfig) -> Any:
    """Integer-quantize every eligible leaf; others pass through unchanged."""
    def f(path, leaf):
        if _should_quantize(path, leaf, cfg):
            return quantize(leaf, cfg)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)


def quantize_tree_stacked(params: Any, cfg: QuantConfig,
                          min_stacked_ndim: int = 3) -> Any:
    """Like :func:`quantize_tree` but scale computation is vmapped over the
    leading (stacked-layers) axis, so per-channel scales are per *layer* —
    the form the scan-over-layers models consume when serving with
    int8-resident weights.  Only >=3-D leaves (stacked weight matrices) are
    quantized; stacked 1-D-per-layer vectors (norm gains, biases) stay in
    float, matching the paper's sign/magnitude treatment of weights only."""
    def f(path, leaf):
        if not _should_quantize(path, leaf, cfg):
            return leaf
        if leaf.ndim >= min_stacked_ndim:
            return jax.vmap(lambda w: quantize(w, cfg))(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# QAT (straight-through estimator)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def qat_quantize(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Fake-quant with identity (straight-through) gradients.

    Used by the training loop to make the agent partition quantization-aware:
    forward sees quantized weights, backward passes gradients through.
    """
    return quantize_dequantize(x, cfg)


def _qat_fwd(x, cfg):
    return quantize_dequantize(x, cfg), None


def _qat_bwd(cfg, res, g):
    del cfg, res
    return (g,)


qat_quantize.defvjp(_qat_fwd, _qat_bwd)
