"""Model quantizers (paper §II-A, §VI-A).

The paper's scheme: keep the sign bit, quantize the magnitude with
``b_hat - 1`` bits.  Two codebooks are evaluated:

  * uniform      — fixed step over [0, absmax]          (paper ref [31])
  * pot-log      — power-of-two logarithmic levels       (paper ref [32])

Everything operates on arrays or whole parameter pytrees.  Two execution
styles:

  * ``quantize_dequantize``  — "fake quant" used for distortion analysis and
    QAT (straight-through estimator gradients);
  * ``quantize`` / ``dequantize`` — real integer codes + scales, the storage
    format consumed by ``repro.kernels.qmm`` (int8/int4-resident matmul).

Granularity: per-tensor, per-channel (last axis), or per-group along the
contraction axis — per-group is what the Pallas kernel consumes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Literal

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "QuantPlan",
    "as_plan",
    "tree_path_str",
    "quantize_dequantize",
    "quantize",
    "dequantize",
    "quantize_tree",
    "fake_quantize_tree",
    "quantize_tree_stacked",
    "qat_quantize",
    "uniform_step_size",
    "max_quant_error",
    "pack_int4",
    "unpack_int4",
    "wire_bytes",
]

Scheme = Literal["uniform", "pot-log"]
Granularity = Literal["per-tensor", "per-channel", "per-group"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How to quantize one tensor (or a whole tree)."""

    bits: int = 8                       # total bits incl. sign (paper's b_hat)
    scheme: Scheme = "uniform"
    granularity: Granularity = "per-channel"
    group_size: int = 128               # for per-group
    # Which pytree leaves to quantize: predicate on (path, leaf).  2D+ weights
    # only by default — biases/norm gains stay full precision (paper keeps
    # them; their byte count is negligible).
    min_ndim: int = 2

    def __post_init__(self):
        if self.bits < 1:
            raise ValueError(f"bits must be >= 1, got {self.bits}")
        if self.scheme not in ("uniform", "pot-log"):
            raise ValueError(f"unknown scheme {self.scheme!r}")

    @property
    def magnitude_levels(self) -> int:
        """Number of magnitude codepoints: 2^(bits-1) (sign kept separately)."""
        return 2 ** (self.bits - 1)


# ---------------------------------------------------------------------------
# Mixed-precision plans (DESIGN.md §8)
# ---------------------------------------------------------------------------

def _key_part(k) -> str:
    """One pytree key entry -> path component (DictKey/SequenceKey/attr)."""
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def tree_path_str(key_path) -> str:
    """Canonical '/'-joined path of a tree_map_with_path key path.

    ``{"layers": {"attn": {"wq": ...}}}`` -> ``"layers/attn/wq"``.  This is
    the string :class:`QuantPlan` entries match against.
    """
    return "/".join(_key_part(k) for k in key_path)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Per-layer (per-subtree) bit-allocation plan.

    ``entries`` is an ordered map of path prefixes to bit-widths, e.g.
    ``(("layers/0", 4), ("layers/1", 8))``.  A leaf resolves to the bits of
    its *longest* matching prefix ('/'-boundary aware), falling back to
    ``default_bits``.  ``scheme``/``granularity``/``group_size``/``min_ndim``
    play the same role as on :class:`QuantConfig` and are shared by every
    resolved per-leaf config.

    A plan with no entries is the degenerate uniform case: every leaf
    resolves to ``default_bits``, making the plan-aware tree quantizers
    bitwise identical to the single-:class:`QuantConfig` API.
    """

    entries: tuple = ()                 # ((path_prefix, bits), ...)
    default_bits: int = 16
    scheme: Scheme = "uniform"
    granularity: Granularity = "per-channel"
    group_size: int = 128
    min_ndim: int = 2

    def __post_init__(self):
        ent = tuple((str(p), int(b)) for p, b in self.entries)
        object.__setattr__(self, "entries", ent)
        for p, b in ent:
            if b < 1:
                raise ValueError(f"bits must be >= 1 for {p!r}, got {b}")
        if self.default_bits < 1:
            raise ValueError(f"default_bits must be >= 1, "
                             f"got {self.default_bits}")

    # -- construction -------------------------------------------------
    @staticmethod
    def uniform(bits: int, **kw) -> "QuantPlan":
        """The degenerate single-bit-width plan (no entries)."""
        return QuantPlan(entries=(), default_bits=bits, **kw)

    @staticmethod
    def from_layer_bits(bits, prefix: str = "layers", **kw) -> "QuantPlan":
        """Plan keyed ``<prefix>/<i> -> bits[i]`` (the allocator's output)."""
        ent = tuple((f"{prefix}/{i}", int(b)) for i, b in enumerate(bits))
        return QuantPlan(entries=ent, **kw)

    # -- resolution ---------------------------------------------------
    def resolve_bits(self, path: str) -> int:
        """Bits of the longest entry prefix matching ``path``."""
        best, best_len = self.default_bits, -1
        for prefix, bits in self.entries:
            if (path == prefix or path.startswith(prefix + "/")) \
                    and len(prefix) > best_len:
                best, best_len = bits, len(prefix)
        return best

    def config_for(self, path: str) -> QuantConfig:
        return QuantConfig(bits=self.resolve_bits(path), scheme=self.scheme,
                           granularity=self.granularity,
                           group_size=self.group_size,
                           min_ndim=self.min_ndim)

    def layer_bits(self, i: int, prefix: str = "layers") -> int:
        return self.resolve_bits(f"{prefix}/{i}")

    def config_for_layer(self, i: int, prefix: str = "layers") -> QuantConfig:
        return self.config_for(f"{prefix}/{i}")

    def layer_bit_list(self, n_layers: int,
                       prefix: str = "layers") -> tuple:
        return tuple(self.layer_bits(i, prefix) for i in range(n_layers))

    # -- aggregate views ----------------------------------------------
    def uniform_layer_bits(self, n_layers: int,
                           prefix: str = "layers"):
        """The single bit-width all of layers [0, n) resolve to, or None."""
        bs = set(self.layer_bit_list(n_layers, prefix))
        return bs.pop() if len(bs) == 1 else None

    def mean_bits(self, n_layers: int, prefix: str = "layers") -> float:
        bl = self.layer_bit_list(n_layers, prefix)
        return sum(bl) / max(len(bl), 1)

    # -- caching ------------------------------------------------------
    def key(self) -> tuple:
        """Hashable, order-stable cache key (weight caches key on this)."""
        return ("plan", self.entries, self.default_bits, self.scheme,
                self.granularity, self.group_size, self.min_ndim)

    def plan_hash(self) -> str:
        """Short stable hex digest of :meth:`key` (logs / JSON reports)."""
        import hashlib
        return hashlib.sha1(repr(self.key()).encode()).hexdigest()[:12]


def as_plan(cfg) -> QuantPlan:
    """Lift a single :class:`QuantConfig` to the degenerate uniform plan."""
    if isinstance(cfg, QuantPlan):
        return cfg
    return QuantPlan.uniform(cfg.bits, scheme=cfg.scheme,
                             granularity=cfg.granularity,
                             group_size=cfg.group_size, min_ndim=cfg.min_ndim)


# ---------------------------------------------------------------------------
# Scale computation
# ---------------------------------------------------------------------------

def _absmax(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Reduction producing the scale denominator, shaped for broadcasting."""
    if cfg.granularity == "per-tensor":
        return jnp.max(jnp.abs(x))
    if cfg.granularity == "per-channel":
        # reduce all axes but the last (output-feature axis for [in, out] mats)
        axes = tuple(range(x.ndim - 1))
        return jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    if cfg.granularity == "per-group":
        # group along the first (contraction) axis
        g = cfg.group_size
        if x.shape[0] % g != 0:
            # fall back to per-channel when the axis doesn't tile
            axes = tuple(range(x.ndim - 1))
            return jnp.max(jnp.abs(x), axis=axes, keepdims=True)
        xg = x.reshape((x.shape[0] // g, g) + x.shape[1:])
        return jnp.repeat(jnp.max(jnp.abs(xg), axis=1), g, axis=0)
    raise ValueError(cfg.granularity)


def uniform_step_size(absmax: jax.Array, bits: int) -> jax.Array:
    """Uniform-quantizer step Delta = absmax / (2^(bits-1) - 1).

    bits includes the sign bit; magnitudes get 2^(bits-1)-1 nonzero levels.
    Guard bits==1: a 1-bit code is sign-only, magnitude collapses to a single
    reconstruction level (we use absmax/2, the conditional mean surrogate).
    """
    levels = max(2 ** (bits - 1) - 1, 1)
    return absmax / levels


def max_quant_error(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """tau bound of Assumption 3: worst-case per-element |w - w_hat|.

    Uniform: Delta/2.  PoT-log with geometric rounding in the exponent: a
    magnitude just above the k/(k+1) boundary amax·2^{-(k+0.5)} rounds UP to
    amax·2^{-k}, so the worst relative error is (1 - 2^{-1/2}) ~ 0.2929 of
    the top level — i.e. tau = (1 - 1/sqrt(2)) · absmax (k = 0 dominates),
    plus the underflow-to-zero floor for 1-level codebooks.
    """
    amax = _absmax(x, cfg)
    if cfg.scheme == "uniform":
        return jnp.max(uniform_step_size(amax, cfg.bits)) / 2.0
    n = cfg.magnitude_levels
    if n <= 1:
        return jnp.max(amax)  # sign-only: recon amax/2, worst err ~ amax
    round_up = (1.0 - 2.0 ** -0.5) * jnp.max(amax)
    floor = jnp.max(amax) * 2.0 ** (-(n - 1))  # underflow-to-zero half-gap
    return jnp.maximum(round_up, floor)


# ---------------------------------------------------------------------------
# Core quantizers (array level)
# ---------------------------------------------------------------------------

def _uniform_qdq(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    amax = _absmax(x, cfg)
    step = uniform_step_size(amax, cfg.bits)
    step = jnp.where(step <= 0, 1.0, step)
    mag = jnp.abs(x)
    if cfg.bits == 1:
        # sign-only code: reconstruct magnitude at its conditional mean proxy
        recon = amax / 2.0
        return jnp.sign(x) * jnp.broadcast_to(recon, x.shape)
    levels = 2 ** (cfg.bits - 1) - 1
    q = jnp.clip(jnp.round(mag / step), 0, levels)
    return jnp.sign(x) * q * step


def _potlog_qdq(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Power-of-two logarithmic codebook: {0} U {amax 2^{-k}, k=0..n-2}."""
    amax = _absmax(x, cfg)
    amax = jnp.where(amax <= 0, 1.0, amax)
    n = cfg.magnitude_levels
    if n <= 1:
        recon = amax / 2.0
        return jnp.sign(x) * jnp.broadcast_to(recon, x.shape)
    mag = jnp.abs(x)
    # exponent k = round(log2(amax / mag)), clipped to codebook range
    safe = jnp.maximum(mag, jnp.finfo(x.dtype).tiny)
    k = jnp.round(jnp.log2(amax / safe))
    k = jnp.clip(k, 0, n - 2)
    recon = amax * jnp.exp2(-k)
    # underflow to zero: anything below half the smallest level
    smallest = amax * (2.0 ** (-(n - 2)))
    recon = jnp.where(mag < smallest / 2.0, 0.0, recon)
    return jnp.sign(x) * recon


def quantize_dequantize(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Fake-quantization (quantize then immediately dequantize)."""
    if cfg.scheme == "uniform":
        return _uniform_qdq(x, cfg)
    return _potlog_qdq(x, cfg)


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Integer codes + scale, the storage format for quantized weights.

    ``codes`` is int8 for bits <= 8 (int4 values live in [-7, 7]; use
    :func:`pack_int4` for the 2-per-byte wire format) and int16 for
    9..16 bits — the containers :func:`wire_bytes` bills for.
    """

    codes: jax.Array          # int8 (<= 8 bits) / int16, original shape
    scale: jax.Array          # broadcastable to codes.shape
    bits: int
    scheme: Scheme

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    @property
    def dtype(self):
        return self.codes.dtype

    def astype(self, dtype) -> jax.Array:
        """Transparent dequant-on-read: model code does
        ``p["w"].astype(x.dtype)`` before every matmul, so swapping a float
        leaf for a QuantizedTensor makes the weights int8-resident in HBM
        with the dequant fused into the consumer by XLA (the pure-JAX
        analogue of kernels/qmm.py; used by the serving dry-run)."""
        return dequantize(self, dtype)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype)

    def nbytes_effective(self) -> int:
        """Realizable wire/storage bytes for the codes + f32 scales.

        Uses the byte layouts that actually exist (:func:`wire_bytes`):
        bits <= 4 ships two codes per byte via :func:`pack_int4`; there is
        no sub-byte packing beyond that, so 5..8 bits cost one byte per
        code and 9..16 two — not the ``(n*bits+7)//8`` idealization."""
        import numpy as _np
        n = int(_np.prod(self.codes.shape))
        scale_bytes = int(_np.prod(self.scale.shape)) * 4
        return wire_bytes(n, self.bits) + scale_bytes


jax.tree_util.register_pytree_node(
    QuantizedTensor,
    lambda qt: ((qt.codes, qt.scale), (qt.bits, qt.scheme)),
    lambda aux, ch: QuantizedTensor(ch[0], ch[1], aux[0], aux[1]),
)


def quantize(x: jax.Array, cfg: QuantConfig) -> QuantizedTensor:
    """Real quantization to integer codes (uniform scheme)."""
    if cfg.scheme != "uniform":
        raise NotImplementedError(
            "integer-code storage implemented for the uniform scheme; "
            "pot-log uses quantize_dequantize (codes are exponents).")
    if cfg.bits > 16:
        raise ValueError(f"no integer container for bits={cfg.bits} (>16)")
    amax = _absmax(x, cfg)
    step = uniform_step_size(amax, cfg.bits)
    step = jnp.where(step <= 0, 1.0, step)
    levels = max(2 ** (cfg.bits - 1) - 1, 1)
    # container must hold ±levels: int8 through 8 bits, int16 above —
    # an int8 cast at 9..16 bits would silently wrap the codes
    dtype = jnp.int8 if cfg.bits <= 8 else jnp.int16
    q = jnp.clip(jnp.round(x / step), -levels, levels).astype(dtype)
    return QuantizedTensor(codes=q, scale=step.astype(jnp.float32),
                           bits=cfg.bits, scheme=cfg.scheme)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return (qt.codes.astype(dtype) * qt.scale.astype(dtype)).astype(dtype)


def wire_bytes(n_codes: int, bits: int) -> int:
    """Bytes to ship ``n_codes`` integer codes at ``bits`` (scales excluded).

    The only sub-byte container in this codebase is :func:`pack_int4`
    (two codes per byte), which holds any code of <= 4 bits; wider codes
    are int8- or int16-resident.  So the realizable sizes are
    ceil(n/2) for bits <= 4, n for 5..8, and 2n above.
    """
    if bits <= 4:
        return (n_codes + 1) // 2
    if bits <= 8:
        return n_codes
    return 2 * n_codes


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8-held int4 codes (two per byte) along the last axis."""
    if codes.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even to pack int4")
    lo = codes[..., 0::2] & 0x0F
    hi = (codes[..., 1::2] & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extends 4-bit two's complement)."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    def sext(v):
        return jnp.where(v >= 8, v - 16, v)
    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Pytree level
# ---------------------------------------------------------------------------

def _should_quantize(path, leaf, cfg: QuantConfig) -> bool:
    del path
    return hasattr(leaf, "ndim") and leaf.ndim >= cfg.min_ndim and \
        jnp.issubdtype(leaf.dtype, jnp.floating)


def fake_quantize_tree(params: Any, cfg) -> Any:
    """Apply quantize-dequantize to every eligible leaf of a param pytree.

    ``cfg`` is a :class:`QuantConfig` (uniform bits) or a
    :class:`QuantPlan` (per-leaf bits via longest-prefix path match).

    Plan prefixes match the *dict path* of each leaf.  Scan-over-layers
    models stack all layers into one leaf (path ``layers/attn/wq``, no
    layer id), so an allocator plan keyed ``layers/<i>`` will not match
    here — use :func:`quantize_tree_stacked` or
    ``runtime.qat.fake_quantize_agent``, which index the leading axis."""
    plan = as_plan(cfg)

    def f(path, leaf):
        lc = plan.config_for(tree_path_str(path))
        if _should_quantize(path, leaf, lc):
            return quantize_dequantize(leaf, lc)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)


def quantize_tree(params: Any, cfg) -> Any:
    """Integer-quantize every eligible leaf; others pass through unchanged.

    Accepts a :class:`QuantConfig` or a :class:`QuantPlan`; a uniform
    plan is bitwise identical to the single-config call.  Plan prefixes
    match dict paths — for stacked-layers models (one leaf per weight,
    layers on the leading axis) see the caveat on
    :func:`fake_quantize_tree`."""
    plan = as_plan(cfg)

    def f(path, leaf):
        lc = plan.config_for(tree_path_str(path))
        if _should_quantize(path, leaf, lc):
            return quantize(leaf, lc)
        return leaf
    return jax.tree_util.tree_map_with_path(f, params)


def quantize_tree_stacked(params: Any, cfg,
                          min_stacked_ndim: int = 3) -> Any:
    """Like :func:`quantize_tree` but scale computation is vmapped over the
    leading (stacked-layers) axis, so per-channel scales are per *layer* —
    the form the scan-over-layers models consume when serving with
    int8-resident weights.  Only >=3-D leaves (stacked weight matrices) are
    quantized; stacked 1-D-per-layer vectors (norm gains, biases) stay in
    float, matching the paper's sign/magnitude treatment of weights only.

    With a :class:`QuantPlan`, layer i of each stacked leaf quantizes at
    ``plan.layer_bits(i)`` (plan keys are ``layers/<i>``, indexing the
    leading axis — not the leaf's dict path, which carries no layer id).
    Dequantization is ``codes * scale`` and thus bits-independent, so
    heterogeneous per-layer levels stack into one
    :class:`QuantizedTensor`; its ``bits`` field records the max (the
    value byte-accounting must assume)."""
    plan = as_plan(cfg)
    base = plan.config_for("")   # shared scheme/granularity/min_ndim

    def f(path, leaf):
        if not _should_quantize(path, leaf, base):
            return leaf
        if leaf.ndim < min_stacked_ndim:
            return leaf
        n = leaf.shape[0]
        bits = plan.layer_bit_list(n)
        if len(set(bits)) == 1:
            lc = dataclasses.replace(base, bits=bits[0])
            return jax.vmap(lambda w: quantize(w, lc))(leaf)
        qts = [quantize(leaf[i], dataclasses.replace(base, bits=bits[i]))
               for i in range(n)]
        # one container for the whole stack: wide enough for the widest
        # layer (int8 unless some layer needs int16)
        cdtype = jnp.int8 if max(bits) <= 8 else jnp.int16
        return QuantizedTensor(
            codes=jnp.stack([q.codes.astype(cdtype) for q in qts]),
            scale=jnp.stack([q.scale for q in qts]),
            bits=max(bits), scheme=base.scheme)
    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# QAT (straight-through estimator)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def qat_quantize(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Fake-quant with identity (straight-through) gradients.

    Used by the training loop to make the agent partition quantization-aware:
    forward sees quantized weights, backward passes gradients through.
    """
    return quantize_dequantize(x, cfg)


def _qat_fwd(x, cfg):
    return quantize_dequantize(x, cfg), None


def _qat_bwd(cfg, res, g):
    del cfg, res
    return (g,)


qat_quantize.defvjp(_qat_fwd, _qat_bwd)
