"""Benchmark schemes of paper §VI-C.

  1) PPO-based design  — DRL over (b_hat, f, f~) with penalty-driven
     constraint handling (paper ref [12]).  Implemented as PPO-clip on a
     tabular softmax policy over a discretized action grid; honest but
     deliberately the paper's "needs proper initialization / exploration"
     baseline.
  2) Fixed-frequency   — f = f_max, f~ = f~_max; only b_hat is optimized.
  3) Feasible random   — sample bit-widths uniformly (400 trials), keep the
     feasible ones (frequencies optimized per trial), report them all.

Every scheme returns :class:`repro.core.codesign.CodesignSolution` so the
benchmark harness can compare objectives / realized delay / energy directly.
"""

from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from .codesign import (CodesignSolution, _pack, distortion_gap,
                       feasible_bitwidth, min_energy_under_deadline)
from .cost_model import SystemParams, total_delay, total_energy

__all__ = ["solve_fixed_frequency", "solve_feasible_random", "solve_ppo"]


def solve_fixed_frequency(lam: float, p: SystemParams, t0: float, e0: float,
                          b_max: int = 16) -> Optional[CodesignSolution]:
    """Max frequencies, bit-width is the only knob."""
    f, fs = p.f_max, p.f_server_max
    for b_hat in range(b_max, 0, -1):
        t = float(total_delay(b_hat, f, fs, p))
        e = float(total_energy(b_hat, f, fs, p))
        if t <= t0 * (1 + 1e-9) and e <= e0 * (1 + 1e-9):
            return _pack(b_hat, f, fs, lam, p)
    return None


def solve_feasible_random(lam: float, p: SystemParams, t0: float, e0: float,
                          b_max: int = 16, trials: int = 400,
                          seed: int = 0) -> List[CodesignSolution]:
    """Paper's 400-trial random scheme; returns all feasible trials."""
    rng = np.random.default_rng(seed)
    out: List[CodesignSolution] = []
    for _ in range(trials):
        b_hat = int(rng.integers(1, b_max + 1))
        ok, f, fs, _ = feasible_bitwidth(b_hat, p, t0, e0)
        if ok:
            out.append(_pack(b_hat, f, fs, lam, p))
    return out


def solve_ppo(lam: float, p: SystemParams, t0: float, e0: float,
              b_max: int = 16, n_f: int = 8, n_fs: int = 8,
              iters: int = 300, batch: int = 64, lr: float = 0.15,
              clip: float = 0.2, penalty: float = 50.0,
              seed: int = 0) -> Optional[CodesignSolution]:
    """PPO-clip over the discretized joint action space.

    Action = (b_hat, f_idx, f~_idx) on a grid; reward = -gap(b_hat) minus a
    penalty proportional to relative constraint violation (the
    "penalty-driven constraint handling" the paper credits for the PPO
    baseline's suboptimality).  Tabular softmax policy, advantage = reward -
    running mean, PPO clipped surrogate ascent.
    """
    rng = np.random.default_rng(seed)
    f_grid = np.linspace(p.f_max / n_f, p.f_max, n_f)
    fs_grid = np.linspace(p.f_server_max / n_fs, p.f_server_max, n_fs)
    n_actions = b_max * n_f * n_fs
    logits = np.zeros(n_actions)

    def decode(a: int):
        b_hat = a // (n_f * n_fs) + 1
        rem = a % (n_f * n_fs)
        return b_hat, f_grid[rem // n_fs], fs_grid[rem % n_fs]

    def reward(a: int) -> float:
        b_hat, f, fs = decode(a)
        t = float(total_delay(b_hat, f, fs, p))
        e = float(total_energy(b_hat, f, fs, p))
        viol = max(0.0, t / t0 - 1.0) + max(0.0, e / e0 - 1.0)
        return -distortion_gap(b_hat, lam) * lam - penalty * viol

    baseline_r = 0.0
    for it in range(iters):
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        acts = rng.choice(n_actions, size=batch, p=probs)
        rs = np.array([reward(a) for a in acts])
        if it == 0:
            baseline_r = rs.mean()
        adv = rs - baseline_r
        baseline_r = 0.9 * baseline_r + 0.1 * rs.mean()
        old_probs = probs[acts]
        # one PPO-clip ascent step on the tabular logits
        new_probs_all = np.exp(logits - logits.max())
        new_probs_all /= new_probs_all.sum()
        ratio = new_probs_all[acts] / np.maximum(old_probs, 1e-12)
        clipped = np.clip(ratio, 1 - clip, 1 + clip)
        use = np.where((adv >= 0) & (ratio > 1 + clip) |
                       (adv < 0) & (ratio < 1 - clip), 0.0, 1.0)
        grad = np.zeros_like(logits)
        for a, ad, u in zip(acts, adv, use):
            if u == 0.0:
                continue
            # d log pi(a) / d logits = e_a - probs
            grad += ad * (np.eye(1, n_actions, a)[0] - new_probs_all)
        logits += lr * grad / batch

    # greedy action from the trained policy; report only if feasible
    order = np.argsort(-logits)
    for a in order:
        b_hat, f, fs = decode(int(a))
        t = float(total_delay(b_hat, f, fs, p))
        e = float(total_energy(b_hat, f, fs, p))
        if t <= t0 * (1 + 1e-9) and e <= e0 * (1 + 1e-9):
            return _pack(b_hat, f, fs, lam, p)
    return None
