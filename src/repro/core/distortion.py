"""Quantization-induced output distortion (paper §III).

Implements:

  * Proposition 3.1 — the layered chain upper bound for FC DNNs:
        ||f(x,W) - f(x,W_hat)||_1 <= sum_l A^(l) ||W^(l) - W_hat^(l)||_1
    with A^(l) = prod_{j<l} ||W^(j)||_1 * prod_{k>l} (||W^(k)||_1 + tau^(k)).
    The matrix norm here is the induced L1 norm (max column abs sum), which is
    the sub-multiplicative norm compatible with the proof's
    ||W x||_1 <= ||W||_1 ||x||_1 step.

  * the surrogate parameter-distortion metric d(W, W_hat) = ||W - W_hat||_1
    (eq. 15), elementwise L1 over the whole pytree;

  * the first-order Taylor surrogate for general models (eq. 16-17) and an
    empirical gradient-norm constant H estimator;

  * measured output distortion: run the model at full precision and
    quantized, take ||.||_1 of the difference (what Fig. 3 plots).
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "induced_l1_norm",
    "elementwise_l1",
    "param_distortion",
    "chain_bound_coefficients",
    "fc_chain_bound",
    "measured_output_distortion",
    "taylor_surrogate_bound",
    "estimate_grad_norm_H",
]


def induced_l1_norm(w: jax.Array) -> jax.Array:
    """Induced (operator) L1 norm of a matrix: max over columns of column
    abs-sums.  For y = W x with ||x||_1 bounded, ||W x||_1 <= ||W||_1 ||x||_1.

    Convention: W has shape [out, in]; columns index the input dimension.
    """
    if w.ndim != 2:
        w = w.reshape(w.shape[0], -1)
    return jnp.max(jnp.sum(jnp.abs(w), axis=0))


def elementwise_l1(a: jax.Array, b: jax.Array) -> jax.Array:
    """sum |a - b| — the entrywise L1 used for the surrogate metric."""
    return jnp.sum(jnp.abs(a - b))


def param_distortion(params: Any, params_hat: Any) -> jax.Array:
    """d(W, W_hat) = ||W - W_hat||_1 over a whole pytree (paper eq. 15)."""
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(elementwise_l1, params, params_hat))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Proposition 3.1 for FC DNNs
# ---------------------------------------------------------------------------

def chain_bound_coefficients(
    weights: Sequence[jax.Array],
    taus: Sequence[jax.Array],
) -> list[jax.Array]:
    """A^(l) coefficients of Prop 3.1 (eq. 14), 1-indexed layers -> list.

    ``weights`` are the *unquantized* per-layer matrices W^(1..L) ([out, in]),
    ``taus`` the per-layer quantization error bounds of Assumption 3
    (||W^(l) - W_hat^(l)||_1 <= tau^(l), induced-L1).
    """
    L = len(weights)
    norms = [induced_l1_norm(w) for w in weights]
    coeffs = []
    for l in range(L):  # 0-based
        pre = jnp.prod(jnp.stack([norms[j] for j in range(l)])) if l > 0 \
            else jnp.float32(1.0)
        post = jnp.prod(jnp.stack(
            [norms[k] + taus[k] for k in range(l + 1, L)])) if l < L - 1 \
            else jnp.float32(1.0)
        coeffs.append(pre * post)
    return coeffs


def fc_chain_bound(
    weights: Sequence[jax.Array],
    weights_hat: Sequence[jax.Array],
) -> jax.Array:
    """Right-hand side of Prop 3.1 for a concrete quantization.

    tau^(l) is instantiated as the realized induced-L1 error of layer l
    (which trivially satisfies Assumption 3 with equality).
    """
    taus = [induced_l1_norm(w - wh) for w, wh in zip(weights, weights_hat)]
    coeffs = chain_bound_coefficients(weights, taus)
    terms = [c * t for c, t in zip(coeffs, taus)]
    return jnp.sum(jnp.stack(terms))


def measured_output_distortion(
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    params_hat: Any,
    x: jax.Array,
) -> jax.Array:
    """||f(x,W) - f(x,W_hat)||_1 averaged over the batch (Fig. 3 y-axis)."""
    y = apply_fn(params, x)
    y_hat = apply_fn(params_hat, x)
    d = jnp.abs(y - y_hat)
    return jnp.sum(d) / (d.shape[0] if d.ndim > 1 else 1)


# ---------------------------------------------------------------------------
# General-model Taylor surrogate (Remark 3.2)
# ---------------------------------------------------------------------------

def estimate_grad_norm_H(
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    xs: jax.Array,
) -> jax.Array:
    """Empirical H >= ||grad_W f(x, W)||_1 (max-abs row-sum proxy over batch).

    The paper estimates the model-dependent constant "in a data-driven manner
    as an empirical upper-bound constant"; we do the same: H is the max over
    inputs of the L1 norm of the scalar-output gradient (model outputs are
    reduced by sum so grad is well-defined for vector outputs; this yields the
    worst-case direction constant used in eq. 17).
    """
    def scalar_out(p, x):
        return jnp.sum(apply_fn(p, x[None, ...]))

    def one(x):
        g = jax.grad(scalar_out)(params, x)
        leaves = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(lambda a: jnp.sum(jnp.abs(a)), g))
        return jnp.sum(jnp.stack(leaves))

    return jnp.max(jax.vmap(one)(xs))


def taylor_surrogate_bound(H: jax.Array, params: Any, params_hat: Any) -> jax.Array:
    """Eq. (17): ||f(x,W_hat) - f(x,W)||_1 <~ H ||W - W_hat||_1."""
    return H * param_distortion(params, params_hat)
