"""Multi-agent fleet co-design: shared edge-server allocation
(DESIGN.md §11).

The paper's joint (b̂, f, f̃) design is derived for one agent–server
pair.  The fleet problem serves N heterogeneous agents — each with its
own weight statistic λ_i, hardware constants, and per-request budgets
(T0_i, E0_i) — from **one** edge server whose compute is a contended
resource.  The server is *frequency-partitioned*: agent i's slice
behaves like a private server with maximum frequency α_i·f̃_max, with
the shares summing to at most one,

    (P-fleet)   min_{b, α}  Σ_i w_i · [D^U_i(b_i − 1) − D^L_i(b_i − 1)]
                s.t.        T_i(b_i, f_i, α_i) ≤ T0_i      ∀i
                            E_i(b_i, f_i, α_i) ≤ E0_i      ∀i
                            Σ_i α_i ≤ 1,   α_i > 0
                            b_i ∈ {1..B_max,i},  0 ≤ f_i ≤ f_max,i.

Given a share vector α the problem separates into N independent
single-pair (P1)s — agent i solves the paper's problem against
``shared_params(sysp_i, α_i)``, its own ``SystemParams`` with
``f_server_max`` (and optionally ``link_bps``, for a TDMA uplink slice)
scaled by α_i.  Each per-agent objective is decreasing in b_i and each
agent's largest feasible bit-width is nondecreasing in α_i, so the
coupling collapses to *share thresholds*: ``min_share_for(agent, b)``
is the smallest α that makes bit-width b feasible (feasibility is
monotone in α, so plain bisection), and the fleet problem becomes a
multiple-choice knapsack over the per-agent bit curves.

:func:`solve_fleet` solves it water-filling-style: start every agent at
b_i = 1 with its minimal feasible share (if even that overflows the
server, the fleet is infeasible), then repeatedly spend leftover share
on the single-bit upgrade with the best marginal bound decrease per
unit share, Δobj/Δα.  D^U is convex decreasing in b and the threshold
curve is increasing in b, so marginal ratios shrink along each agent's
curve and the greedy fills the most valuable agents first — the
discrete analogue of water-filling over N distortion curves.  Leftover
share (agents pinned at B_max or by energy) is spread equally: extra
server frequency never hurts feasibility and buys delay/energy slack.
:func:`solve_equal_split` is the α_i = 1/N baseline the fleet benchmark
compares against.

Per-agent solves go through ``codesign.solve_sca`` — the *same* solver
the serving engines memoize through their shared ``CodesignCache``, so
the allocator's per-agent solutions are exactly what the engines
re-derive (a cache hit when the cache is shared).  Mixed-precision
fleets reuse these shares: the share split is decided on the uniform-b̂
surrogate, and each engine realizes a per-layer ``QuantPlan`` under its
assigned slice (DESIGN.md §8/§11).

Host-side float64 numpy, like ``codesign.py``: this runs once per
fleet, not in the serving hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from . import codesign as cd
from .cost_model import SystemParams

__all__ = [
    "FleetAgent",
    "FleetSolution",
    "shared_params",
    "min_share_for",
    "solve_fleet",
    "solve_equal_split",
]

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Agent record and the shared-server parameter view
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetAgent:
    """One agent–server pair inside a fleet, as the allocator sees it.

    ``sysp`` carries the agent's own hardware constants with
    ``f_server_max`` set to the **full** (unshared) server frequency;
    the allocator decides what fraction of it the agent receives.
    ``weight`` scales the agent's term of the fleet objective (traffic
    share or priority).  ``b_emb`` makes the per-agent solves link-aware
    exactly as in ``codesign.solve_sca`` (None = computation-only).
    """

    name: str
    lam: float                  # exponential-MLE weight statistic (eq. 3)
    sysp: SystemParams
    t0: float
    e0: float
    weight: float = 1.0
    b_emb: Optional[int] = None

    def __post_init__(self):
        if self.lam <= 0.0:
            raise ValueError(f"agent {self.name!r}: lam must be positive")
        if self.t0 <= 0.0 or self.e0 <= 0.0:
            raise ValueError(f"agent {self.name!r}: (T0, E0) must be "
                             "positive")
        if self.weight <= 0.0:
            raise ValueError(f"agent {self.name!r}: weight must be positive")


def shared_params(p: SystemParams, share: float, *,
                  share_link: bool = False) -> SystemParams:
    """Agent ``p`` granted fraction ``share`` of the edge server.

    The server slice is frequency-partitioned: the agent's effective
    server ceiling is ``share * f_server_max`` (eq. (5) then charges
    the slice's delay; eq. (7) bills energy at the *absolute* realized
    frequency, so a smaller slice can only spend less server energy).
    With ``share_link`` the uplink is a TDMA resource divided the same
    way (``link_bps`` scaled by ``share``); by default only the server
    is contended, matching the (P-fleet) formulation.

    ``share == 1`` returns params equal to ``p`` (same dataclass
    fields), which is what makes a single-agent fleet bitwise identical
    to the single-pair engines — cache keys included.
    """
    if not 0.0 < share <= 1.0 + 1e-12:
        raise ValueError(f"share must be in (0, 1], got {share}")
    share = min(share, 1.0)
    fields = {"f_server_max": p.f_server_max * share}
    if share_link and p.link_bps > 0.0:
        fields["link_bps"] = p.link_bps * share
    return dataclasses.replace(p, **fields)


# ---------------------------------------------------------------------------
# Share thresholds
# ---------------------------------------------------------------------------

def min_share_for(agent: FleetAgent, b_hat: int, *,
                  share_link: bool = False, iters: int = 50,
                  ) -> Optional[float]:
    """Smallest server share under which ``b_hat`` meets (T0_i, E0_i).

    Feasibility is monotone nondecreasing in the share (a bigger slice
    only loosens the server-frequency box of the min-energy-under-
    deadline subproblem), so the threshold is found by bisection over
    (0, 1].  Returns None when ``b_hat`` is infeasible even with the
    whole server.  The returned share is the bisection's *feasible*
    upper bracket, so building an engine at exactly this share succeeds.
    """

    def ok(share: float) -> bool:
        p = shared_params(agent.sysp, share, share_link=share_link)
        return cd.feasible_bitwidth(b_hat, p, agent.t0, agent.e0,
                                    b_emb=agent.b_emb)[0]

    if not ok(1.0):
        return None
    lo, hi = 0.0, 1.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if mid <= 0.0:
            break
        if ok(mid):
            hi = mid
        else:
            lo = mid
    return hi


# ---------------------------------------------------------------------------
# Solution record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetSolution:
    """A share vector plus the per-agent (P1) solutions it induces.

    ``solutions[i]`` is agent i's ``CodesignSolution`` under
    ``shared_params(sysp_i, shares[i])``; ``aggregate_bound`` is the
    (P-fleet) objective Σ_i w_i · objective_i at those solutions.
    ``solves`` counts single-pair solver invocations (threshold
    bisections count feasibility probes, not solves).
    """

    solver: str                 # "water-filling" | "equal-split"
    shares: tuple               # per-agent server fraction, sums to <= 1
    solutions: tuple            # per-agent CodesignSolution
    aggregate_bound: float      # Σ w_i (D^U - D^L) at the solutions
    upgrades: int = 0           # greedy single-bit upgrades applied
    solves: int = 0
    # one record per greedy upgrade, in application order:
    # (agent name, new bit-width, share spent, marginal gain/share ratio)
    # — the decision log the fleet engine's tracer replays (DESIGN.md §14)
    upgrade_log: tuple = ()


def _finalize(agents: Sequence[FleetAgent], shares: Sequence[float],
              solver: str, *, share_link: bool, upgrades: int = 0,
              upgrade_log: tuple = ()) -> Optional[FleetSolution]:
    """Solve every agent at its final share and assemble the record."""
    sols = []
    for a, s in zip(agents, shares):
        p = shared_params(a.sysp, s, share_link=share_link)
        sol = cd.solve_sca(a.lam, p, a.t0, a.e0,
                           b_max=int(p.b_full), b_emb=a.b_emb)
        if sol is None:
            return None
        sols.append(sol)
    agg = sum(a.weight * s.objective for a, s in zip(agents, sols))
    return FleetSolution(solver=solver, shares=tuple(float(s)
                                                     for s in shares),
                         solutions=tuple(sols), aggregate_bound=float(agg),
                         upgrades=upgrades, solves=len(sols),
                         upgrade_log=upgrade_log)


def _validate(agents: Sequence[FleetAgent]) -> None:
    if not agents:
        raise ValueError("need at least one FleetAgent")
    names = [a.name for a in agents]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate agent names: {sorted(names)}")


# ---------------------------------------------------------------------------
# Solvers
# ---------------------------------------------------------------------------

def solve_equal_split(agents: Sequence[FleetAgent], *,
                      share_link: bool = False) -> Optional[FleetSolution]:
    """The α_i = 1/N baseline: every agent gets the same server slice.

    Returns None when any agent is infeasible under its equal slice —
    the baseline has no degrade path; the joint allocator may still
    find a feasible (unequal) split for the same fleet.
    """
    _validate(agents)
    share = 1.0 / len(agents)
    return _finalize(agents, [share] * len(agents), "equal-split",
                     share_link=share_link)


def solve_fleet(agents: Sequence[FleetAgent], *,
                share_link: bool = False) -> Optional[FleetSolution]:
    """Water-filling-style joint allocation for (P-fleet).

    1. Threshold pass: ``s_i(b)`` = minimal share making bit-width b
       feasible for agent i (None past the agent's energy/deadline
       wall).  If Σ_i s_i(1) > 1 the fleet is infeasible → None.
    2. Greedy fill: every agent starts at (b_i = 1, s_i(1)); while
       leftover share remains, apply the single-bit upgrade
       b_i → b_i + 1 maximizing  w_i·[gap_i(b_i) − gap_i(b_i+1)] /
       [s_i(b_i+1) − current share]  among those that fit.  Marginal
       ratios decrease along each agent's curve (convex D^U, increasing
       thresholds), so this is the discrete water level rising across
       the fleet's distortion curves.
    3. Leftover share is spread equally (a single-agent fleet therefore
       ends at share exactly 1.0), and every agent is re-solved at its
       final share through ``codesign.solve_sca``.
    """
    _validate(agents)
    n = len(agents)
    if n == 1:
        # trivial fleet: the whole server; identical to the pair solve
        sol = _finalize(agents, [1.0], "water-filling",
                        share_link=share_link)
        return sol

    b_caps = [int(a.sysp.b_full) for a in agents]
    # s[i][b] = minimal share for bit-width b (lazily beyond b=1)
    thresholds: list = [{} for _ in range(n)]
    bits = [1] * n
    shares = [0.0] * n
    for i, a in enumerate(agents):
        s1 = min_share_for(a, 1, share_link=share_link)
        if s1 is None:
            return None      # agent i infeasible even owning the server
        thresholds[i][1] = s1
        shares[i] = s1
    leftover = 1.0 - sum(shares)
    if leftover < -1e-9:
        return None          # minimal slices already overflow the server

    def threshold(i: int, b: int) -> Optional[float]:
        if b not in thresholds[i]:
            thresholds[i][b] = min_share_for(agents[i], b,
                                             share_link=share_link)
        return thresholds[i][b]

    upgrades = 0
    upgrade_log: list = []
    while leftover > _EPS:
        best, best_ratio, best_cost = -1, -1.0, 0.0
        for i, a in enumerate(agents):
            b = bits[i]
            if b >= b_caps[i]:
                continue
            s_next = threshold(i, b + 1)
            if s_next is None:
                continue
            cost = max(s_next - shares[i], 0.0)
            if cost > leftover + 1e-12:
                continue
            gain = a.weight * (cd.distortion_gap(float(b), a.lam)
                               - cd.distortion_gap(float(b + 1), a.lam))
            ratio = gain / max(cost, _EPS)
            if ratio > best_ratio or (ratio == best_ratio
                                      and cost < best_cost):
                best, best_ratio, best_cost = i, ratio, cost
        if best < 0:
            break
        bits[best] += 1
        shares[best] += best_cost
        leftover -= best_cost
        upgrades += 1
        upgrade_log.append((agents[best].name, bits[best],
                            float(best_cost), float(best_ratio)))

    if leftover > _EPS:
        extra = leftover / n
        shares = [s + extra for s in shares]
    return _finalize(agents, shares, "water-filling",
                     share_link=share_link, upgrades=upgrades,
                     upgrade_log=tuple(upgrade_log))
