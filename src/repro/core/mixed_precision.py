"""Layer-wise mixed-precision bit allocation (DESIGN.md §8).

The codesign of ``codesign.py`` fits one global λ and assigns one uniform
b̂ to the whole agent partition.  The paper's own machinery is finer
grained: the distortion-rate bounds of Props. 4.1/4.2 are functions of a
*per-layer* rate parameter λ^(l), and the chain bound of Prop. 3.1 weighs
layer l's parameter distortion by a sensitivity coefficient A^(l)
(`distortion.chain_bound_coefficients`).  This module exploits both:

  * :func:`decoder_layer_stats` — per-agent-layer λ^(l) via
    ``rate_distortion.exponential_mle`` and A^(l) via the chain bound,
    computed on the stacked-layers parameter tree of the DecoderLM
    families;
  * :func:`allocate_bits` — minimize  Σ_l A^(l) · D^U(b_l - 1; λ_l)
    over b_l ∈ {1..B_max} subject to the same (T0, E0) feasibility as
    problem (P1), via greedy marginal-gain descent (exact for this
    separable convex objective under the total-bit budget implied by the
    oracle frequency subproblem ``codesign.min_energy_under_deadline``);
  * :func:`plan_from_bits` — wrap an allocation into a
    :class:`~repro.core.quantization.QuantPlan` the serving engine and
    the tree quantizers consume.

Feasibility reduction: the DecoderLM agent layers are FLOP-homogeneous,
so the cost model's workload fraction under a per-layer plan is
mean(b_l)/b — delay and energy depend on the allocation only through its
*mean* bit-width.  The (T0, E0) region therefore maps to a scalar budget
B* = max feasible mean bits (monotone in the workload fraction, found by
bisection), and the discrete problem becomes "spend ⌊B*·L⌋ bits over L
layers" — which greedy descent on the convex per-layer distortion curves
solves exactly.  A uniform allocation is the degenerate output when the
budget divides evenly and the layer statistics are homogeneous.

Host-side float64 numpy, like ``codesign.py``: this runs once per
(model, QoS class), not in the serving hot path.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .codesign import (_d_upper, acceptance_rate, distortion_gap,
                       expected_tokens_per_round, min_energy_under_deadline,
                       net_budgets)
from .cost_model import (SystemParams, draft_delay, draft_energy, kv_delay,
                         kv_energy, rollback_delay, rollback_energy,
                         speculative_round_delay, speculative_round_energy,
                         total_delay, total_energy, transport_delay,
                         transport_energy)
from .distortion import chain_bound_coefficients, induced_l1_norm
from .quantization import QuantConfig, QuantPlan, quantize_dequantize
from .rate_distortion import exponential_mle

__all__ = [
    "LayerStats",
    "MixedSolution",
    "agent_layer_matrices",
    "layer_lambdas",
    "layer_sensitivities",
    "decoder_layer_stats",
    "max_mean_bits",
    "best_uniform_bits",
    "allocation_objective",
    "uniform_objective",
    "allocate_bits",
    "MixedDecodeSolution",
    "allocate_bits_decode",
    "MixedSpeculativeSolution",
    "allocate_bits_speculative",
    "plan_from_bits",
]


# ---------------------------------------------------------------------------
# Per-layer statistics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerStats:
    """Per-agent-layer rate-distortion statistics.

    ``lam[l]``  — Exponential MLE rate of layer l's weight magnitudes
    (paper eq. (3), fitted per layer instead of globally).
    ``sens[l]`` — chain-bound sensitivity A^(l) of Prop. 3.1, normalized
    so min(sens) == 1 (only ratios matter for the allocation; the common
    server-side suffix factor cancels — see :func:`layer_sensitivities`).
    """

    lam: tuple
    sens: tuple

    def __post_init__(self):
        object.__setattr__(self, "lam", tuple(float(x) for x in self.lam))
        object.__setattr__(self, "sens", tuple(float(x) for x in self.sens))
        if len(self.lam) != len(self.sens):
            raise ValueError("lam and sens must have equal length")
        if not self.lam:
            raise ValueError("need at least one layer")

    @property
    def n_layers(self) -> int:
        return len(self.lam)

    def key(self) -> tuple:
        """Hashable cache key (rounded so float jitter can't split it)."""
        return (tuple(round(x, 10) for x in self.lam),
                tuple(round(x, 10) for x in self.sens))


def agent_layer_matrices(params, split: int) -> list:
    """Per-layer 2-D weight matrices of the agent partition.

    The DecoderLM families stack per-layer weights on a leading axis
    (leaves of ndim >= 3 under ``params["layers"]``).  For each layer
    l < split this returns every such leaf's slice, reshaped to
    ``[out, in*]`` — the induced-L1 convention of ``distortion.py``
    (columns index the input dimension).
    """
    out = [[] for _ in range(split)]
    for leaf in jax.tree_util.tree_leaves(params["layers"]):
        if not (hasattr(leaf, "ndim") and leaf.ndim >= 3
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            continue
        for l in range(min(split, leaf.shape[0])):
            w = leaf[l]
            out[l].append(w.reshape(-1, w.shape[-1]).T)
    if any(not mats for mats in out):
        raise ValueError(f"no stacked weight leaves for some of the "
                         f"{split} agent layers")
    return out


def layer_lambdas(layer_mats: Sequence[Sequence[jax.Array]]) -> np.ndarray:
    """λ^(l): the Exponential MLE over all of layer l's weight magnitudes
    (``exponential_mle``, i.e. 1 / mean|θ^(l)| — paper eq. (3) per layer)."""
    return np.asarray(
        [float(exponential_mle(jnp.concatenate(
            [m.ravel() for m in mats]))) for mats in layer_mats],
        np.float64)


def layer_sensitivities(layer_mats: Sequence[Sequence[jax.Array]],
                        ref_bits: int = 8) -> np.ndarray:
    """Chain-bound coefficients A^(l) of Prop. 3.1 over the agent layers.

    Each transformer layer is represented by its norm-dominant matrix
    (the slice with the largest induced-L1 norm — for a column-wise
    concatenation of a layer's matmuls the induced norm *is* that max),
    and τ^(l) of Assumption 3 is instantiated as the realized induced-L1
    quantization error of that representative at ``ref_bits``.  The
    server layers (full precision) multiply every agent A^(l) by the same
    ∏(‖W‖₁) suffix, so they drop out of the allocation and are omitted.
    """
    reps = []
    for mats in layer_mats:
        norms = [float(induced_l1_norm(m)) for m in mats]
        reps.append(mats[int(np.argmax(norms))])
    cfg = QuantConfig(bits=ref_bits, scheme="uniform",
                      granularity="per-channel")
    taus = [induced_l1_norm(w - quantize_dequantize(w, cfg)) for w in reps]
    coeffs = np.asarray([float(c) for c in
                         chain_bound_coefficients(reps, taus)], np.float64)
    return coeffs / max(float(coeffs.min()), 1e-300)


def decoder_layer_stats(params, split: int, ref_bits: int = 8) -> LayerStats:
    """λ^(l) and A^(l) for the agent partition of a stacked-layers model."""
    mats = agent_layer_matrices(params, split)
    return LayerStats(lam=tuple(layer_lambdas(mats)),
                      sens=tuple(layer_sensitivities(mats, ref_bits)))


# ---------------------------------------------------------------------------
# Feasibility: the (T0, E0) region as a mean-bit budget
# ---------------------------------------------------------------------------

def _mean_bits_feasible(mean_b: float, p: SystemParams, t0: float,
                        e0: float) -> bool:
    e_min, _, _ = min_energy_under_deadline(mean_b / p.b_full, p, t0)
    return e_min <= e0 * (1.0 + 1e-9)


def max_mean_bits(p: SystemParams, t0: float, e0: float,
                  b_max: int = 16,
                  b_emb: Optional[float] = None) -> Optional[float]:
    """Largest mean agent bit-width meeting (T0, E0), or None if even
    mean 1 is infeasible.  Monotone in the workload fraction (delay is
    linear in b̄, min-energy increasing), so plain bisection.  ``b_emb``
    deducts the uplink's delay/energy share from the budgets first
    (``codesign.net_budgets``)."""
    t0, e0 = net_budgets(p, t0, e0, b_emb)
    if t0 <= 0.0 or e0 <= 0.0:
        return None
    if not _mean_bits_feasible(1.0, p, t0, e0):
        return None
    if _mean_bits_feasible(float(b_max), p, t0, e0):
        return float(b_max)
    lo, hi = 1.0, float(b_max)
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if _mean_bits_feasible(mid, p, t0, e0):
            lo = mid
        else:
            hi = mid
    return lo


def best_uniform_bits(p: SystemParams, t0: float, e0: float,
                      b_max: int = 16,
                      b_emb: Optional[float] = None) -> Optional[int]:
    """Largest feasible *uniform* b̂ — what ``solve_oracle`` assigns."""
    b_star = max_mean_bits(p, t0, e0, b_max, b_emb=b_emb)
    return None if b_star is None else int(math.floor(b_star + 1e-9))


# ---------------------------------------------------------------------------
# The allocator
# ---------------------------------------------------------------------------

def allocation_objective(stats: LayerStats, bits: Sequence[int]) -> float:
    """Σ_l A^(l) · D^U(b_l - 1; λ_l) — the plan's distortion bound."""
    return float(sum(a * _d_upper(b - 1.0, lam)
                     for a, lam, b in zip(stats.sens, stats.lam, bits)))


def uniform_objective(stats: LayerStats, b_hat: int) -> float:
    """The same bound under a uniform b̂ (comparison baseline)."""
    return allocation_objective(stats, [b_hat] * stats.n_layers)


@dataclasses.dataclass(frozen=True)
class MixedSolution:
    """One per-layer bit allocation + its frequency assignment."""

    bits: tuple                 # per agent layer, len == stats.n_layers
    f: float                    # device frequency realizing feasibility
    f_server: float
    objective: float            # Σ A^(l) D^U(b_l - 1; λ_l)
    uniform_b: int              # best uniform b̂ under the same (T0, E0)
    uniform_objective: float    # the bound that uniform b̂ achieves
    mean_bits: float
    delay: float                # realized T at mean_bits
    energy: float               # realized E at mean_bits
    feasible: bool = True

    @property
    def b_hat(self) -> int:
        """Integer summary bit-width (display / legacy stats fields)."""
        return int(round(self.mean_bits))


def allocate_bits(stats: LayerStats, p: SystemParams, t0: float, e0: float,
                  b_max: int = 16,
                  b_emb: Optional[float] = None) -> Optional[MixedSolution]:
    """Greedy/water-filling bit allocation under the (P1) constraints.

    Start every layer at 1 bit (the cheapest plan; if that is infeasible
    so is (P1) and we return None, matching ``solve_sca``), then spend
    the remaining budget one bit at a time on the layer with the largest
    marginal bound decrease A^(l)·[D^U(b_l-1) - D^U(b_l)].  D^U is
    convex decreasing in b, so marginal gains shrink along each layer's
    curve and the greedy optimum is exact for the separable objective.
    ``b_emb`` makes the feasibility frontier link-aware, exactly as in
    ``codesign.solve_sca``.
    """
    b_star = max_mean_bits(p, t0, e0, b_max, b_emb=b_emb)
    if b_star is None:
        return None
    n = stats.n_layers
    budget = int(math.floor(b_star * n + 1e-9))   # total bits to spend
    bits = [1] * n
    budget -= n

    def gain(l: int, b: int) -> float:
        return stats.sens[l] * (_d_upper(b - 1.0, stats.lam[l])
                                - _d_upper(float(b), stats.lam[l]))

    # max-heap of (−gain, layer) for the next bit on each layer
    heap = [(-gain(l, 1), l) for l in range(n)]
    heapq.heapify(heap)
    while budget > 0 and heap:
        neg, l = heapq.heappop(heap)
        if bits[l] >= b_max:
            continue
        bits[l] += 1
        budget -= 1
        if bits[l] < b_max:
            heapq.heappush(heap, (-gain(l, bits[l]), l))

    mean_b = sum(bits) / n
    t0_net, _ = net_budgets(p, t0, e0, b_emb)
    e, f, fs = min_energy_under_deadline(mean_b / p.b_full, p, t0_net)
    u_b = int(math.floor(b_star + 1e-9))
    return MixedSolution(
        bits=tuple(bits), f=f, f_server=fs,
        objective=allocation_objective(stats, bits),
        uniform_b=u_b, uniform_objective=uniform_objective(stats, u_b),
        mean_bits=mean_b,
        delay=float(total_delay(mean_b, f, fs, p, b_emb=b_emb)),
        energy=float(total_energy(mean_b, f, fs, p, b_emb=b_emb)))


@dataclasses.dataclass(frozen=True)
class MixedDecodeSolution:
    """Per-layer weight allocation + stored KV-cache bit-width.

    The decode analog of :class:`MixedSolution`, mirroring
    ``codesign.DecodeSolution``: ``inner`` solves the per-layer problem
    against the budgets left after the cache read at ``b_kv``, and
    ``objective`` is the joint bound (DESIGN.md §12)."""

    b_kv: int
    inner: MixedSolution
    objective: float            # inner.objective + kv_weight · gap(b_kv)
    kv_gap: float
    delay: float                # realized T including the cache read
    energy: float

    @property
    def bits(self) -> tuple:
        return self.inner.bits

    @property
    def f(self) -> float:
        return self.inner.f

    @property
    def f_server(self) -> float:
        return self.inner.f_server

    @property
    def mean_bits(self) -> float:
        return self.inner.mean_bits


def allocate_bits_decode(stats: LayerStats, lam_kv: float, p: SystemParams,
                         t0: float, e0: float, b_max: int = 16,
                         b_emb: Optional[float] = None,
                         kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                         kv_weight: float = 1.0
                         ) -> Optional[MixedDecodeSolution]:
    """Joint per-layer weight bits + KV-cache bit-width allocation.

    Exact enumeration over the realizable cache container ladder (the
    same reduction as ``codesign.solve_decode``): each rung's cache
    delay/energy share shrinks the (T0, E0) frontier the greedy
    allocator runs against, and the joint objective adds the cache's
    distortion gap at λ_kv on top of the per-layer bound.  None when
    every rung is infeasible.
    """
    best: Optional[MixedDecodeSolution] = None
    for b_kv in kv_ladder:
        t0_net, e0_net = net_budgets(p, t0, e0, None, b_kv=b_kv)
        if t0_net <= 0.0 or e0_net <= 0.0:
            continue
        inner = allocate_bits(stats, p, t0_net, e0_net, b_max, b_emb=b_emb)
        if inner is None:
            continue
        kv_gap = distortion_gap(b_kv, lam_kv)
        cand = MixedDecodeSolution(
            b_kv=int(b_kv), inner=inner,
            objective=inner.objective + kv_weight * kv_gap,
            kv_gap=kv_gap,
            delay=inner.delay + float(kv_delay(b_kv, p)),
            energy=inner.energy + float(kv_energy(b_kv, p)))
        if best is None or cand.objective < best.objective:
            best = cand
    return best


@dataclasses.dataclass(frozen=True)
class MixedSpeculativeSolution:
    """Per-layer allocation + cache width + draft schedule (b_draft, k).

    The speculative analog of :class:`MixedDecodeSolution`, mirroring
    ``codesign.SpeculativeSolution``: ``inner`` is the decode-level
    allocation solved against per-*delivered-token* budgets, and
    ``objective`` divides the joint bound by the expected tokens per
    round τ (DESIGN.md §16)."""

    b_draft: int
    k: int
    alpha: float                # modeled acceptance rate
    tokens_per_round: float     # τ = E[delivered tokens / round]
    inner: MixedDecodeSolution
    objective: float            # (inner bound + kv gap) / τ
    delay: float                # per-token expected delay (round / τ)
    energy: float

    @property
    def bits(self) -> tuple:
        return self.inner.bits

    @property
    def b_kv(self) -> int:
        return self.inner.b_kv

    @property
    def f(self) -> float:
        return self.inner.f

    @property
    def f_server(self) -> float:
        return self.inner.f_server

    @property
    def mean_bits(self) -> float:
        return self.inner.mean_bits


def allocate_bits_speculative(stats: LayerStats, lam_kv: float,
                              p: SystemParams, t0: float, e0: float,
                              b_max: int = 16,
                              b_emb: Optional[float] = None,
                              kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                              kv_weight: float = 1.0,
                              draft_ladder: "tuple[int, ...]" = (2, 4, 8),
                              lookahead: "tuple[int, ...]" = (2, 4, 8),
                              ) -> Optional[MixedSpeculativeSolution]:
    """Joint per-layer bits + cache width + draft schedule allocation.

    The same (b_kv × b_draft × k) enumeration as
    ``codesign.solve_speculative``, with the per-layer greedy allocator
    as the inner solver: each rung's per-round overhead (draft chain at
    f_max, k+1 cache streams, expected rollback, one uplink) is spread
    over the τ expected delivered tokens and netted off (T0, E0); the
    forward workload is scaled by 1/τ — the batched verify is one
    weight pass per round (``cost_model.verify_delay``) — so the
    allocator prices the verify forward per delivered token.  None when
    every rung is infeasible.
    """
    lam_mean = sum(stats.lam) / max(stats.n_layers, 1)
    best: Optional[MixedSpeculativeSolution] = None
    for b_kv in kv_ladder:
        for b_draft in draft_ladder:
            alpha = acceptance_rate(b_draft, lam_mean)
            for k in lookahead:
                tau = expected_tokens_per_round(alpha, k)
                t_oh = (draft_delay(b_draft, k, p)
                        + (k + 1) * kv_delay(b_kv, p)
                        + rollback_delay(b_kv, max(k + 1 - tau, 0.0), p))
                e_oh = (draft_energy(b_draft, k, p)
                        + (k + 1) * kv_energy(b_kv, p)
                        + rollback_energy(b_kv, max(k + 1 - tau, 0.0), p))
                if b_emb is not None:
                    t_oh += float(transport_delay(b_emb, p))
                    e_oh += float(transport_energy(b_emb, p))
                t_net = t0 - t_oh / tau
                e_net = e0 - e_oh / tau
                if t_net <= 0.0 or e_net <= 0.0:
                    continue
                scale = 1.0 / tau
                p_v = dataclasses.replace(
                    p, n_flop_agent=p.n_flop_agent * scale,
                    n_flop_server=p.n_flop_server * scale)
                inner = allocate_bits(stats, p_v, t_net, e_net, b_max)
                if inner is None:
                    continue
                kv_gap = distortion_gap(b_kv, lam_kv)
                joint = inner.objective + kv_weight * kv_gap
                delay = speculative_round_delay(
                    inner.mean_bits, inner.f, inner.f_server, b_draft, k,
                    tau, p, b_emb=b_emb, b_kv=b_kv) / tau
                energy = speculative_round_energy(
                    inner.mean_bits, inner.f, inner.f_server, b_draft, k,
                    tau, p, b_emb=b_emb, b_kv=b_kv) / tau
                dec = MixedDecodeSolution(
                    b_kv=int(b_kv), inner=inner, objective=joint,
                    kv_gap=kv_gap, delay=float(delay), energy=float(energy))
                cand = MixedSpeculativeSolution(
                    b_draft=int(b_draft), k=int(k), alpha=alpha,
                    tokens_per_round=tau, inner=dec,
                    objective=joint / tau,
                    delay=float(delay), energy=float(energy))
                if best is None or cand.objective < best.objective:
                    best = cand
    return best


def plan_from_bits(bits: Sequence[int], *, scheme: str = "uniform",
                   granularity: str = "per-channel",
                   group_size: int = 128,
                   default_bits: int = 16) -> QuantPlan:
    """Wrap an allocation into the plan the quantizers/engine consume.

    Layers beyond the allocation (the server partition) resolve to
    ``default_bits`` = 16, i.e. stay full precision."""
    return QuantPlan.from_layer_bits(
        bits, scheme=scheme, granularity=granularity,
        group_size=group_size, default_bits=default_bits)
