"""Joint quantization bit-width x computation frequency co-design (paper §V).

Problem (P1):

    min_{b_hat, f, f~}   D^U(b_hat - 1) - D^L(b_hat - 1)
    s.t.                 T(b_hat, f, f~) <= T0
                         E(b_hat, f, f~) <= E0
                         b_hat in {1..B_max},  0 <= f <= f_max,  0 <= f~ <= f~_max

Two solvers live here:

  * :func:`solve_sca` — the paper's Algorithm 1, faithfully: continuous
    relaxation (P2), auxiliary variable b' ~ 1/b (P3), iterative convex
    surrogates (P4.k), rounding.  The convex subproblem (P4.k) is solved
    *exactly* by exploiting its structure (the objective depends only on b~;
    v := b' enters only the constraints) — see `_solve_p4k`.  No external
    convex solver is needed (the environment has no CVX), and tests verify
    the SCA output against the oracle below.

  * :func:`solve_oracle` — exhaustive search over the discrete bit-width with
    a closed-form optimal frequency split per bit-width (KKT of the
    min-energy-under-deadline subproblem).  This is the beyond-paper
    reference optimum for (P1) used to check SCA solution quality.

Baselines of §VI-C (fixed-frequency, feasible-random, PPO-like) are in
``repro.core.baselines``.

All math is float64 numpy on the host — this is a serving-configuration
routine, not a training-step hot path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .cost_model import (SystemParams, draft_delay, draft_energy, kv_delay,
                         kv_energy, rollback_delay, rollback_energy,
                         speculative_round_delay, speculative_round_energy,
                         transport_delay, transport_energy)

__all__ = [
    "CodesignSolution",
    "DecodeSolution",
    "SpeculativeSolution",
    "distortion_gap",
    "net_budgets",
    "min_energy_under_deadline",
    "feasible_bitwidth",
    "solve_oracle",
    "solve_sca",
    "solve_decode",
    "solve_speculative",
    "acceptance_from_distortion",
    "acceptance_rate",
    "expected_tokens_per_round",
    "device_only_params",
    "solve_device_only",
    "SPEC_GAMMA",
]

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Objective (float64 host mirror of rate_distortion bounds)
# ---------------------------------------------------------------------------

def _d_upper(rate: float, lam: float) -> float:
    denom = max(2.0 ** rate - 1.0, _EPS)
    return (math.sqrt(1.0 + 4.0 / denom) - 1.0) / (2.0 * lam)


def _d_lower(rate: float, lam: float) -> float:
    return 1.0 / (lam * 2.0 ** (rate + 1.0))


def distortion_gap(b_hat: float, lam: float) -> float:
    """(P1)/(P2) objective D^U(b-1) - D^L(b-1); sign bit costs one bit."""
    r = b_hat - 1.0
    return _d_upper(r, lam) - _d_lower(r, lam)


def _gap_grad(b: float, lam: float) -> float:
    """d/db [ D^U(b-1) - D^L(b-1) ] (analytic; used by the 1-D Newton)."""
    r = b - 1.0
    s = 2.0 ** r
    denom = max(s - 1.0, _EPS)
    g = 1.0 + 4.0 / denom
    dg = -4.0 * math.log(2.0) * s / (denom * denom)
    d_upper = dg / (4.0 * lam * math.sqrt(g))
    d_lower = -math.log(2.0) / (lam * 2.0 ** (r + 1.0))
    return d_upper - d_lower


# ---------------------------------------------------------------------------
# Link-aware budget reduction
# ---------------------------------------------------------------------------

def net_budgets(p: SystemParams, t0: float, e0: float,
                b_emb: Optional[float],
                b_kv: Optional[float] = None) -> "tuple[float, float]":
    """(T0, E0) left for computation after the uplink takes its share.

    The embedding transport at ``b_emb`` is independent of the decision
    variables (b̂, f, f̃), so a link-aware solve is the computation-only
    solve against the *reduced* budgets T0 − t_x and E0 − e_x (tx power ×
    uplink time).  With ``b_emb=None`` or link modeling disabled the
    budgets pass through untouched — the faithful model of eqs. (4)–(9).

    ``b_kv`` deducts the KV-cache read share the same way (decode
    serving, DESIGN.md §12): the cache traffic at the stored bit-width
    is also independent of (b̂, f, f̃), so it simply shrinks the budgets.
    """
    if b_emb is not None:
        t0 = t0 - float(transport_delay(b_emb, p))
        e0 = e0 - float(transport_energy(b_emb, p))
    if b_kv is not None:
        t0 = t0 - float(kv_delay(b_kv, p))
        e0 = e0 - float(kv_energy(b_kv, p))
    return t0, e0


# ---------------------------------------------------------------------------
# Frequency subproblem: minimal energy subject to the deadline
# ---------------------------------------------------------------------------

def _workload_constants(p: SystemParams):
    """Ka, Ks (seconds at f=f_max) and Ea, Es (joules at f=f_max).

    t_a = Ka * w / u,  e_a = Ea * w * u^2    with u = f/f_max, w = b_hat/b
    t_s = Ks / u~,     e_s = Es * u~^2       with u~ = f~/f~_max
    """
    ka = p.n_flop_agent / (p.c_agent * p.f_max)
    ks = p.n_flop_server / (p.c_server * p.f_server_max)
    ea = p.eta_agent * p.n_flop_agent * p.psi_agent * p.f_max ** 2 / p.c_agent
    es = p.eta_server * p.n_flop_server * p.psi_server * p.f_server_max ** 2 \
        / p.c_server
    return ka, ks, ea, es


def min_energy_under_deadline(workload_frac: float, p: SystemParams,
                              t0: float):
    """min_{f, f~} E  s.t.  T <= t0, f <= f_max, f~ <= f~_max.

    ``workload_frac`` = b_hat / b (the paper's linear-in-bitwidth scaling).
    Writing tau_a = t_a, tau_s = t_s:  e_a = A/tau_a^2 with A = Ea w^3 Ka^2
    (eliminating u), e_s = B/tau_s^2 with B = Es Ks^2.  Energy decreases in
    each tau, so tau_a + tau_s = t0 at the optimum; the KKT point is
    tau_a : tau_s = A^{1/3} : B^{1/3}, clipped to the frequency boxes.

    Returns (e_min, f_opt, f_server_opt) or (inf, nan, nan) if the deadline
    is unmeetable even at max frequencies.
    """
    w = workload_frac
    ka, ks, ea, es = _workload_constants(p)
    tau_a_lo = ka * w          # at u = 1
    tau_s_lo = ks              # at u~ = 1
    if tau_a_lo + tau_s_lo > t0 * (1.0 + 1e-12):
        return math.inf, math.nan, math.nan
    a = ea * (w ** 3) * ka * ka
    b = es * ks * ks
    if a <= 0.0:  # degenerate: no agent workload
        tau_s = min(max(t0, tau_s_lo), t0)
        tau_a = t0 - tau_s
        e = b / max(tau_s, _EPS) ** 2
        return e, 0.0, p.f_server_max * ks / max(tau_s, _EPS)
    if b <= 0.0:  # degenerate: no server workload (device-only split)
        tau_a = t0  # whole deadline on the agent minimizes its energy
        e = a / max(tau_a, _EPS) ** 2
        f_opt = p.f_max * ka * w / max(tau_a, _EPS)
        # f~ = f~_max is inert here (zero server FLOPs) but keeps the
        # cost model's server-delay expression well-defined
        return e, min(f_opt, p.f_max), p.f_server_max
    r = (a / b) ** (1.0 / 3.0)
    tau_a = t0 * r / (1.0 + r)
    # clip into the box implied by max frequencies
    tau_a = min(max(tau_a, tau_a_lo), t0 - tau_s_lo)
    tau_s = t0 - tau_a
    e = a / tau_a ** 2 + b / tau_s ** 2
    f_opt = p.f_max * ka * w / tau_a
    fs_opt = p.f_server_max * ks / tau_s
    return e, min(f_opt, p.f_max), min(fs_opt, p.f_server_max)


def feasible_bitwidth(b_hat: float, p: SystemParams, t0: float,
                      e0: float, b_emb: Optional[float] = None
                      ) -> "tuple[bool, float, float, float]":
    """Can bit-width ``b_hat`` meet (T0, E0) at *some* frequency pair?

    Pure feasibility: the objective (and thus the weight statistic λ)
    plays no role here, only the cost model — frequencies are chosen by
    the min-energy-under-deadline subproblem and checked against E0.
    With ``b_emb`` the uplink's delay/energy share is deducted from the
    budgets first (:func:`net_budgets`).

    Returns ``(ok, f, f_server, e_min)``; on infeasibility ``f`` and
    ``f_server`` are NaN and ``e_min`` is the (unmeetable) energy floor,
    which may be ``inf`` when even the deadline alone cannot be met.
    """
    t0, e0 = net_budgets(p, t0, e0, b_emb)
    if t0 <= 0.0 or e0 <= 0.0:
        return False, math.nan, math.nan, math.inf
    w = b_hat / p.b_full
    e_min, f, fs = min_energy_under_deadline(w, p, t0)
    # isfinite guard: an unmeetable deadline reports e_min = inf, which
    # must stay infeasible even under a relaxed (infinite) energy budget
    if math.isfinite(e_min) and e_min <= e0 * (1.0 + 1e-9):
        return True, f, fs, e_min
    return False, math.nan, math.nan, e_min


# ---------------------------------------------------------------------------
# Solution record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CodesignSolution:
    b_hat: int                  # chosen bit-width
    f: float                    # device frequency (Hz)
    f_server: float             # server frequency (Hz)
    objective: float            # D^U - D^L gap at b_hat
    d_upper: float              # conservative distortion estimate
    d_lower: float              # optimistic floor
    delay: float                # realized T at the solution
    energy: float               # realized E at the solution
    feasible: bool
    iterations: int = 0         # SCA outer iterations (0 for oracle)
    b_relaxed: float = float("nan")  # pre-rounding b~* (SCA only)


def _pack(b_hat: int, f: float, fs: float, lam: float, p: SystemParams,
          iterations: int = 0, b_relaxed: float = float("nan"),
          feasible: bool = True,
          b_emb: Optional[float] = None) -> CodesignSolution:
    from .cost_model import total_delay, total_energy
    t = float(total_delay(b_hat, f, fs, p, b_emb=b_emb))
    e = float(total_energy(b_hat, f, fs, p, b_emb=b_emb))
    r = b_hat - 1.0
    return CodesignSolution(
        b_hat=b_hat, f=f, f_server=fs,
        objective=distortion_gap(b_hat, lam),
        d_upper=_d_upper(r, lam), d_lower=_d_lower(r, lam),
        delay=t, energy=e, feasible=feasible, iterations=iterations,
        b_relaxed=b_relaxed)


# ---------------------------------------------------------------------------
# Oracle: exhaustive over the discrete bit-width set
# ---------------------------------------------------------------------------

def solve_oracle(lam: float, p: SystemParams, t0: float, e0: float,
                 b_max: int = 16, b_emb: Optional[float] = None
                 ) -> Optional[CodesignSolution]:
    """Exact solution of (P1) by enumerating b_hat (the objective is
    monotonically decreasing in b_hat for b_hat >= 1, verified in tests), so
    the optimum is the largest feasible bit-width with its min-energy
    frequency assignment.  ``b_emb`` makes the solve link-aware: the
    uplink's delay/energy share comes off (T0, E0) first."""
    for b_hat in range(b_max, 0, -1):
        ok, f, fs, _ = feasible_bitwidth(b_hat, p, t0, e0, b_emb=b_emb)
        if ok:
            return _pack(b_hat, f, fs, lam, p, b_emb=b_emb)
    return None


# ---------------------------------------------------------------------------
# Degraded device-only fallback (DESIGN.md §15)
# ---------------------------------------------------------------------------

def device_only_params(p: SystemParams) -> SystemParams:
    """The system with the split pinned fully on-agent: every server
    FLOP moves to the device, and the uplink disappears (no boundary
    embedding is ever transmitted, so transport delay/energy and the
    link rate are all zeroed).  This is (P1) restricted to the corner
    the paper's split makes first-class: when the server is
    unreachable the agent still holds the full model at some b̂."""
    return dataclasses.replace(
        p, n_flop_agent=p.n_flop_agent + p.n_flop_server,
        n_flop_server=0.0, link_bps=0.0, emb_bytes_full=0.0,
        tx_power_w=0.0)


def solve_device_only(lam: float, p: SystemParams, t0: float, e0: float,
                      b_max: int = 16) -> CodesignSolution:
    """(P1) over :func:`device_only_params`: the largest bit-width the
    agent can run the *whole* model at within (T0, E0), with the
    min-energy frequency assignment — the supervisor's degraded
    operating point when the server is unreachable (DESIGN.md §15).

    Never returns ``None``: if no bit-width meets both budgets the
    energy budget is relaxed (deadline kept), and failing that the
    solve pins b̂=1 at full frequency with ``feasible=False`` — a
    degraded agent keeps acting, it does not halt."""
    pl = device_only_params(p)
    for b_hat in range(b_max, 0, -1):
        ok, f, fs, _ = feasible_bitwidth(b_hat, pl, t0, e0)
        if ok:
            return _pack(b_hat, f, fs, lam, pl)
    for b_hat in range(b_max, 0, -1):
        ok, f, fs, _ = feasible_bitwidth(b_hat, pl, t0, math.inf)
        if ok:
            return _pack(b_hat, f, fs, lam, pl, feasible=False)
    return _pack(1, pl.f_max, pl.f_server_max, lam, pl, feasible=False)


# ---------------------------------------------------------------------------
# Algorithm 1: SCA on (P2)/(P3)/(P4.k)
# ---------------------------------------------------------------------------

def _solve_p4k(b_k: float, v_k: float, lam: float, p: SystemParams,
               t0: float, e0: float, b_max: int):
    """Exactly solve the convex subproblem (P4.k).

    Structure: the objective depends only on b~; v (:= b') appears only in
    the constraints.  The linearized (35) gives b~ <= cap(v) with
    cap(v) = 1/v_k - (v - v_k)/v_k^2 decreasing in v, so the optimal v is the
    smallest v feasible for (32a)/(32b) — found by bisection — and then b~ is
    a 1-D convex minimization of the surrogate objective over
    [1+eps, min(B_max, cap(v*))].

    Surrogate objective (34): D^U(b~-1) - [1/(lam 2^{b_k}) -
    ln2/(lam 2^{b_k}) (b~ - b_k)].
    """
    ka, ks, ea, es = _workload_constants(p)

    def v_feasible(v: float) -> bool:
        # (32a)/(32b) treat the agent workload as N/(v b): equivalent to a
        # relative workload w = 1/(v * b_full) * b_full = 1/v of full... in
        # normalized terms t_a = (ka / (v * p.b_full)) * p.b_full / u.
        w = 1.0 / (v * p.b_full)  # b~_effective / b  implied by v
        e_min, _, _ = min_energy_under_deadline(w, p, t0)
        return e_min <= e0 * (1.0 + 1e-9)

    v_hi = 1.0  # v = 1 -> effective bit-width 1: the cheapest workload
    if not v_feasible(v_hi):
        return None  # (P3) infeasible even at the lightest workload
    v_lo = 1.0 / b_max
    if v_feasible(v_lo):
        v_star = v_lo
    else:
        lo, hi = v_lo, v_hi
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if v_feasible(mid):
                hi = mid
            else:
                lo = mid
        v_star = hi

    cap = 1.0 / v_k - (v_star - v_k) / (v_k * v_k)
    b_hi = min(float(b_max), cap)
    b_lo = 1.0 + 1e-6
    if b_hi < b_lo:
        b_hi = b_lo

    # 1-D convex minimization of the surrogate via golden-section
    lin_slope = math.log(2.0) / (lam * 2.0 ** b_k)

    def surrogate(b: float) -> float:
        return _d_upper(b - 1.0, lam) \
            - (1.0 / (lam * 2.0 ** b_k) - lin_slope * (b - b_k))

    phi = (math.sqrt(5.0) - 1.0) / 2.0
    lo, hi = b_lo, b_hi
    c = hi - phi * (hi - lo)
    d = lo + phi * (hi - lo)
    fc, fd = surrogate(c), surrogate(d)
    for _ in range(200):
        if hi - lo < 1e-10:
            break
        if fc < fd:
            hi, d, fd = d, c, fc
            c = hi - phi * (hi - lo)
            fc = surrogate(c)
        else:
            lo, c, fc = c, d, fd
            d = lo + phi * (hi - lo)
            fd = surrogate(d)
    b_star = 0.5 * (lo + hi)

    # frequencies realizing feasibility at the chosen v*
    w = 1.0 / (v_star * p.b_full)
    _, f, fs = min_energy_under_deadline(w, p, t0)
    return b_star, v_star, f, fs


def solve_sca(lam: float, p: SystemParams, t0: float, e0: float,
              b_max: int = 16, tol: float = 1e-6, max_iters: int = 64,
              b_emb: Optional[float] = None) -> Optional[CodesignSolution]:
    """Algorithm 1 (paper).  Returns None when (P1) is infeasible.

    ``b_emb`` makes the solve link-aware (:func:`net_budgets`): the
    surrogates run against the computation budgets left after the uplink.
    """
    t0_net, e0_net = net_budgets(p, t0, e0, b_emb)
    if t0_net <= 0.0 or e0_net <= 0.0:
        return None
    t0, e0 = t0_net, e0_net
    # Step 1-2: relax and initialize a feasible local point.
    ok1, _, _, _ = feasible_bitwidth(1.0, p, t0, e0)
    if not ok1:
        return None
    b_k, v_k = 1.0 + 1e-3, 1.0 / (1.0 + 1e-3)
    prev_obj = math.inf
    f = fs = float("nan")
    iters = 0
    for k in range(1, max_iters + 1):
        iters = k
        out = _solve_p4k(b_k, v_k, lam, p, t0, e0, b_max)
        if out is None:
            return None
        b_star, v_star, f, fs = out
        obj = distortion_gap(b_star, lam)
        b_k, v_k = b_star, v_star
        # relative decrease: the objective scales like 1/lam, so an absolute
        # threshold would stop after one step for peaky weight distributions
        if prev_obj - obj < tol * max(abs(prev_obj), _EPS):
            break
        prev_obj = obj

    # Step 9: round to the nearest feasible bit-width; fall back downward.
    b_round = int(round(b_k))
    b_round = max(1, min(b_max, b_round))
    for b_hat in range(b_round, 0, -1):
        ok, f_r, fs_r, _ = feasible_bitwidth(b_hat, p, t0, e0)
        if ok:
            return _pack(b_hat, f_r, fs_r, lam, p, iterations=iters,
                         b_relaxed=b_k, b_emb=b_emb)
    return None


# ---------------------------------------------------------------------------
# Decode extension: the KV-cache bit-width as a third allocated variable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeSolution:
    """(P1) extended with the stored KV-cache bit-width (DESIGN.md §12).

    ``inner`` is the weight/frequency solution obtained against the
    budgets left after the cache takes its share at ``b_kv``;
    ``objective`` is the joint distortion gap
    ``inner.objective + kv_weight · gap(b_kv; λ_kv)``.
    """

    b_kv: int                   # stored KV-cache bit-width
    inner: CodesignSolution     # (b̂, f, f̃) solve under the net budgets
    objective: float            # joint weight + cache distortion gap
    kv_gap: float               # cache share of the objective (unweighted)
    delay: float                # realized T including the cache read
    energy: float               # realized E including cache access energy

    @property
    def b_hat(self) -> int:
        return self.inner.b_hat

    @property
    def f(self) -> float:
        return self.inner.f

    @property
    def f_server(self) -> float:
        return self.inner.f_server

    @property
    def feasible(self) -> bool:
        return self.inner.feasible


def solve_decode(lam: float, lam_kv: float, p: SystemParams, t0: float,
                 e0: float, b_max: int = 16,
                 b_emb: Optional[float] = None,
                 kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                 kv_weight: float = 1.0) -> Optional[DecodeSolution]:
    """Joint (b̂, f, f̃, b_kv) solve for decode serving.

    The cache bit-width ranges over the realizable container ladder
    (int4-packed / int8 / full) rather than a continuum, so the extension
    is an exact enumeration: for each rung, deduct the cache's
    delay/energy share from (T0, E0) (:func:`net_budgets`), run the
    paper's Algorithm 1 on what is left, and score the joint distortion
    upper-bound gap — the weight gap at λ plus ``kv_weight`` times the
    cache gap at λ_kv (the exponential-MLE statistic of the cached K/V
    activations).  Returns the rung minimizing the joint gap, or None
    when every rung is infeasible.
    """
    best: Optional[DecodeSolution] = None
    for b_kv in kv_ladder:
        t0_net, e0_net = net_budgets(p, t0, e0, None, b_kv=b_kv)
        if t0_net <= 0.0 or e0_net <= 0.0:
            continue
        inner = solve_sca(lam, p, t0_net, e0_net, b_max, b_emb=b_emb)
        if inner is None:
            continue
        kv_gap = distortion_gap(b_kv, lam_kv)
        cand = DecodeSolution(
            b_kv=int(b_kv), inner=inner,
            objective=inner.objective + kv_weight * kv_gap,
            kv_gap=kv_gap,
            delay=inner.delay + float(kv_delay(b_kv, p)),
            energy=inner.energy + float(kv_energy(b_kv, p)))
        if best is None or cand.objective < best.objective:
            best = cand
    return best


# ---------------------------------------------------------------------------
# Speculative extension: (b_draft, k) as joint variables (DESIGN.md §16)
# ---------------------------------------------------------------------------

# acceptance sharpness: how fast the modeled per-token acceptance decays
# with the draft's normalized distortion bound.  Calibrated so the ladder
# rungs spread (b_draft = 2/4/8 -> alpha ~ 0.29/0.78/0.98); the engine
# reports the *measured* acceptance next to this estimate.
SPEC_GAMMA = 2.0


def acceptance_from_distortion(d_rel: float,
                               gamma: float = SPEC_GAMMA) -> float:
    """Modeled per-token draft acceptance from the draft's *normalized*
    distortion upper bound ``d_rel = λ · D^U(b_draft - 1; λ)``.

    ``exp(-γ d)``: exactly 1 at zero distortion, in [0, 1] everywhere,
    and monotone non-increasing in the distortion — the three properties
    ``tests/test_properties.py`` pins down.  An estimator, not a law:
    the engine measures the realized acceptance per round."""
    return math.exp(-gamma * max(float(d_rel), 0.0))


def acceptance_rate(b_draft: float, lam: float,
                    gamma: float = SPEC_GAMMA) -> float:
    """Acceptance estimate for a draft quantized at ``b_draft`` bits.

    The normalization λ·D^U makes the statistic dimensionless — D^U
    scales like 1/λ, so λ cancels and acceptance depends only on the
    draft bit-width (draft fidelity relative to the weight scale)."""
    return acceptance_from_distortion(
        lam * _d_upper(b_draft - 1.0, lam), gamma)


def expected_tokens_per_round(alpha: float, k: int) -> float:
    """E[delivered tokens per speculative round] with lookahead ``k``
    under i.i.d. per-token acceptance ``alpha``: the accepted prefix
    plus the free correction/bonus token, ``sum_{i=0..k} alpha^i``.
    Ranges over [1, k+1], monotone in both arguments."""
    a = min(max(float(alpha), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


@dataclasses.dataclass(frozen=True)
class SpeculativeSolution:
    """(P1) extended with the draft bit-width and lookahead (§16).

    ``inner`` is the decode-style (b̂, f, f̃, b_kv) solution obtained
    against the budgets left after the per-round draft/uplink/cache/
    rollback overheads take their per-delivered-token share, with the
    batched verify forward's 1/τ workload scaling folded into the FLOP
    counts; ``objective`` is the joint distortion gap per *expected
    delivered token* — the quantity the ladder descent minimizes.
    """

    b_draft: int                # draft bit-width (agent partition)
    k: int                      # lookahead: drafted tokens per round
    alpha: float                # modeled per-token acceptance
    tokens_per_round: float     # tau = E[delivered per round] in [1, k+1]
    inner: DecodeSolution       # (b̂, f, f̃, b_kv) under the net budgets
    objective: float            # joint gap / tau
    delay: float                # expected per-delivered-token delay
    energy: float               # expected per-delivered-token energy

    @property
    def b_hat(self) -> int:
        return self.inner.b_hat

    @property
    def b_kv(self) -> int:
        return self.inner.b_kv

    @property
    def f(self) -> float:
        return self.inner.f

    @property
    def f_server(self) -> float:
        return self.inner.f_server

    @property
    def kv_gap(self) -> float:
        return self.inner.kv_gap

    @property
    def feasible(self) -> bool:
        return self.inner.feasible


def solve_speculative(lam: float, lam_kv: float, p: SystemParams,
                      t0: float, e0: float, b_max: int = 16,
                      b_emb: Optional[float] = None,
                      kv_ladder: "tuple[int, ...]" = (4, 8, 16),
                      kv_weight: float = 1.0,
                      draft_ladder: "tuple[int, ...]" = (2, 4, 8),
                      lookahead: "tuple[int, ...]" = (2, 4, 8),
                      gamma: float = SPEC_GAMMA
                      ) -> Optional[SpeculativeSolution]:
    """Joint (b̂, f, f̃, b_kv, b_draft, k) solve for speculative decode.

    Extends :func:`solve_decode`'s exact ladder enumeration with the
    draft rungs: for each (b_kv, b_draft, k), the modeled acceptance
    α(D^U(b_draft)) gives the expected delivered tokens per round
    τ = Σ αⁱ; the decision-independent per-round overheads (``k`` draft
    forwards at ``f_max``, ONE uplink, ``k+1`` cache reads, expected
    rollback truncation) come off (T0, E0) at their per-delivered-token
    share, and Algorithm 1 runs on the remainder with the batched
    verify forward's 1/τ per-token workload scaling folded into the
    FLOP counts.  The score is the joint distortion gap per expected
    delivered token — cheap drafts lower the overhead but also α, which
    inflates every per-token share; the enumeration resolves exactly
    that tension.  (T0, E0) are per-delivered-token budgets, same units
    as :func:`solve_decode`'s.  Returns None when every rung is
    infeasible."""
    best: Optional[SpeculativeSolution] = None
    for b_kv in kv_ladder:
        for b_draft in draft_ladder:
            alpha = acceptance_rate(b_draft, lam, gamma)
            for k in lookahead:
                tau = expected_tokens_per_round(alpha, k)
                t_oh = (draft_delay(b_draft, k, p)
                        + (k + 1) * kv_delay(b_kv, p)
                        + rollback_delay(b_kv, max(k + 1 - tau, 0.0), p))
                e_oh = (draft_energy(b_draft, k, p)
                        + (k + 1) * kv_energy(b_kv, p)
                        + rollback_energy(b_kv, max(k + 1 - tau, 0.0), p))
                if b_emb is not None:
                    t_oh += float(transport_delay(b_emb, p))
                    e_oh += float(transport_energy(b_emb, p))
                t_net = t0 - t_oh / tau
                e_net = e0 - e_oh / tau
                if t_net <= 0.0 or e_net <= 0.0:
                    continue
                # the batched verify is ONE weight pass per round (see
                # verify_delay), so the per-delivered-token forward
                # workload is 1/tau of a plain decode step's
                scale = 1.0 / tau
                p_v = dataclasses.replace(
                    p, n_flop_agent=p.n_flop_agent * scale,
                    n_flop_server=p.n_flop_server * scale)
                inner = solve_sca(lam, p_v, t_net, e_net, b_max)
                if inner is None:
                    continue
                kv_gap = distortion_gap(b_kv, lam_kv)
                joint = inner.objective + kv_weight * kv_gap
                delay = speculative_round_delay(
                    inner.b_hat, inner.f, inner.f_server, b_draft, k,
                    tau, p, b_emb=b_emb, b_kv=b_kv) / tau
                energy = speculative_round_energy(
                    inner.b_hat, inner.f, inner.f_server, b_draft, k,
                    tau, p, b_emb=b_emb, b_kv=b_kv) / tau
                dec = DecodeSolution(
                    b_kv=int(b_kv), inner=inner, objective=joint,
                    kv_gap=kv_gap, delay=delay, energy=energy)
                cand = SpeculativeSolution(
                    b_draft=int(b_draft), k=int(k), alpha=alpha,
                    tokens_per_round=tau, inner=dec,
                    objective=joint / tau, delay=delay, energy=energy)
                if best is None or cand.objective < best.objective:
                    best = cand
    return best
