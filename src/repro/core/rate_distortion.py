"""Rate-distortion analysis for LAIM weight quantization (paper §IV).

Weight magnitudes are modeled i.i.d. Exponential(lam) (paper eq. (3),
empirically validated in Fig. 2).  Under the L1 distortion measure
``d(theta, theta_hat) = |theta - theta_hat|`` the paper derives:

  * Proposition 4.1 (Shannon-type lower bound):
        R(D) >= -log2(2 lam D)          <=>  D^L(R) = 1 / (lam 2^{R+1})
  * Proposition 4.2 (Laplacian test-channel upper bound):
        R(D) <= log2(1/(lam D) + lam D/(lam D + 1))
        <=>  D^U(R) = (1/(2 lam)) (sqrt(1 + 4/(2^R - 1)) - 1)

plus a numerical Blahut-Arimoto estimate of the true D(R) that must sit
between the two bounds (paper Fig. 4).  All of that lives here.

Everything is plain ``jnp`` so it can be jitted / vmapped / used inside the
co-design optimizer (§V) without host round-trips.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "exponential_mle",
    "exponential_entropy",
    "rate_lower_bound",
    "rate_upper_bound",
    "distortion_lower_bound",
    "distortion_upper_bound",
    "BlahutArimotoResult",
    "blahut_arimoto_distortion_rate",
]


# ---------------------------------------------------------------------------
# Source statistics
# ---------------------------------------------------------------------------

def exponential_mle(magnitudes: jax.Array) -> jax.Array:
    """MLE of the Exponential rate parameter, lam_hat = 1 / mean(|theta|).

    Accepts any array of parameter magnitudes (flattened internally).
    Zero-guard keeps the estimator finite for degenerate all-zero inputs.
    """
    m = jnp.mean(jnp.abs(magnitudes))
    return 1.0 / jnp.maximum(m, jnp.finfo(jnp.float32).tiny)


def exponential_entropy(lam: jax.Array) -> jax.Array:
    """Differential entropy h(Theta) = log2(e / lam) of Exponential(lam).

    Paper eq. (21).
    """
    return jnp.log2(jnp.e / lam)


# ---------------------------------------------------------------------------
# Analytic bounds (Propositions 4.1 and 4.2)
# ---------------------------------------------------------------------------

def rate_lower_bound(distortion: jax.Array, lam: jax.Array) -> jax.Array:
    """R^L(D) = -log2(2 lam D)  (paper eq. (23))."""
    return -jnp.log2(2.0 * lam * distortion)


def distortion_lower_bound(rate: jax.Array, lam: jax.Array) -> jax.Array:
    """D^L(R) = 1 / (lam 2^{R+1})  (paper eq. (24))."""
    return 1.0 / (lam * jnp.exp2(rate + 1.0))


def rate_upper_bound(distortion: jax.Array, lam: jax.Array) -> jax.Array:
    """R^U(D) = log2( 1/(lam D) + lam D / (lam D + 1) )  (paper eq. (25))."""
    ld = lam * distortion
    return jnp.log2(1.0 / ld + ld / (ld + 1.0))


def distortion_upper_bound(rate: jax.Array, lam: jax.Array) -> jax.Array:
    """D^U(R) = (1/(2 lam)) (sqrt(1 + 4/(2^R - 1)) - 1)  (paper eq. (26)).

    Valid for rate > 0; we clamp the denominator so that rate -> 0+ gives a
    large-but-finite distortion instead of inf (useful inside optimizers).
    """
    denom = jnp.maximum(jnp.exp2(rate) - 1.0, jnp.finfo(jnp.float32).tiny)
    return (jnp.sqrt(1.0 + 4.0 / denom) - 1.0) / (2.0 * lam)


def codesign_objective(bitwidth: jax.Array, lam: jax.Array) -> jax.Array:
    """The (P1)/(P2) objective: D^U(b-1) - D^L(b-1).

    The paper spends one bit on the sign (magnitude-only quantization), so a
    b-bit code has rate R = b - 1 on the magnitude source.
    """
    r = bitwidth - 1.0
    return distortion_upper_bound(r, lam) - distortion_lower_bound(r, lam)


# ---------------------------------------------------------------------------
# Blahut-Arimoto numerical D(R) (paper Fig. 4 reference curve)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlahutArimotoResult:
    """One (rate, distortion) sweep point per Lagrange multiplier."""

    rates: np.ndarray        # bits per symbol
    distortions: np.ndarray  # mean |theta - theta_hat|
    betas: np.ndarray        # Lagrange multipliers used for the sweep


@partial(jax.jit, static_argnames=("n_iters",))
def _ba_fixed_beta(p_x: jax.Array, dmat: jax.Array, beta: jax.Array,
                   n_iters: int = 200):
    """Classic Blahut-Arimoto inner loop for one Lagrange multiplier.

    ``p_x``  : source pmf over the discretized alphabet, shape [S].
    ``dmat`` : distortion matrix d(x, x_hat), shape [S, Shat].
    Returns (rate_bits, distortion).
    """
    shat = dmat.shape[1]
    q = jnp.full((shat,), 1.0 / shat)  # output marginal

    def body(q, _):
        # test channel update: w(xhat|x) ~ q(xhat) exp(-beta d)
        log_w = jnp.log(q)[None, :] - beta * dmat
        log_w = log_w - jax.scipy.special.logsumexp(log_w, axis=1, keepdims=True)
        w = jnp.exp(log_w)
        # marginal update
        q_new = p_x @ w
        q_new = q_new / jnp.sum(q_new)
        return q_new, None

    q, _ = jax.lax.scan(body, q, None, length=n_iters)

    log_w = jnp.log(q)[None, :] - beta * dmat
    log_w = log_w - jax.scipy.special.logsumexp(log_w, axis=1, keepdims=True)
    w = jnp.exp(log_w)
    joint = p_x[:, None] * w
    distortion = jnp.sum(joint * dmat)
    # I(X; Xhat) in bits
    q_marg = jnp.maximum(p_x @ w, 1e-30)
    mi = jnp.sum(joint * (log_w - jnp.log(q_marg)[None, :])) / jnp.log(2.0)
    return mi, distortion


def blahut_arimoto_distortion_rate(
    lam: float,
    *,
    n_source: int = 256,
    n_repro: int = 256,
    theta_max_quantiles: float = 0.9999,
    betas: np.ndarray | None = None,
    n_iters: int = 300,
) -> BlahutArimotoResult:
    """Numerically estimate D(R) for Exponential(lam) under |.| distortion.

    The continuous source is discretized on a fine grid up to the
    ``theta_max_quantiles`` quantile (paper §VI-B does exactly this), the
    reproduction alphabet spans the same range, and the discrete
    rate-distortion problem is solved by BA per Lagrange multiplier beta.
    Sweeping beta traces out the D(R) curve.
    """
    if betas is None:
        betas = np.geomspace(0.05 * lam, 2000.0 * lam, 48)

    theta_max = -np.log1p(-theta_max_quantiles) / lam  # exponential quantile
    src = np.linspace(0.0, theta_max, n_source)
    pdf = lam * np.exp(-lam * src)
    p_x = pdf / pdf.sum()
    repro = np.linspace(0.0, theta_max, n_repro)
    dmat = np.abs(src[:, None] - repro[None, :])

    p_x_j = jnp.asarray(p_x, jnp.float32)
    dmat_j = jnp.asarray(dmat, jnp.float32)

    rates, dists = [], []
    for beta in betas:
        r, d = _ba_fixed_beta(p_x_j, dmat_j, jnp.float32(beta), n_iters=n_iters)
        rates.append(float(r))
        dists.append(float(d))
    return BlahutArimotoResult(
        rates=np.asarray(rates), distortions=np.asarray(dists),
        betas=np.asarray(betas))
