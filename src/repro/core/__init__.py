"""Paper core: distortion approximation, rate-distortion bounds, quantizers,
and the joint bit-width x frequency co-design (paper §III-§V)."""

from .cost_model import (SystemParams, total_delay, total_energy,  # noqa: F401
                         transport_delay, transport_energy)
from .codesign import (CodesignSolution, distortion_gap, solve_oracle,  # noqa: F401
                       solve_sca, feasible_bitwidth,
                       min_energy_under_deadline, net_budgets)
from .baselines import (solve_fixed_frequency, solve_feasible_random,  # noqa: F401
                        solve_ppo)
from .quantization import (QuantConfig, QuantPlan, QuantizedTensor,  # noqa: F401
                           quantize, dequantize, quantize_dequantize,
                           quantize_tree, quantize_tree_stacked,
                           fake_quantize_tree, qat_quantize, max_quant_error,
                           pack_int4, unpack_int4, as_plan, wire_bytes)
from .fleet import (FleetAgent, FleetSolution, min_share_for,  # noqa: F401
                    shared_params, solve_equal_split, solve_fleet)
from .mixed_precision import (LayerStats, MixedSolution,  # noqa: F401
                              decoder_layer_stats, allocate_bits,
                              best_uniform_bits, max_mean_bits,
                              allocation_objective, uniform_objective,
                              plan_from_bits)
from .rate_distortion import (exponential_mle, exponential_entropy,  # noqa: F401
                              rate_lower_bound, rate_upper_bound,
                              distortion_lower_bound, distortion_upper_bound,
                              blahut_arimoto_distortion_rate)
from .distortion import (induced_l1_norm, param_distortion,  # noqa: F401
                         chain_bound_coefficients, fc_chain_bound,
                         measured_output_distortion, taylor_surrogate_bound,
                         estimate_grad_norm_H)
