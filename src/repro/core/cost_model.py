"""Inference delay & energy model (paper §II-D, eqs. (4)-(9)).

The paper's model:

  on-agent  delay   t(b_hat, f)  = b_hat N_FLOP / (b f c)              (4)
  on-server delay   t~(f~)       = N~_FLOP / (f~ c~)                   (5)
  on-agent  energy  e(b_hat, f)  = eta  (b_hat N_FLOP / (b c)) psi f^2 (6)
  on-server energy  e~(f~)       = eta~ (N~_FLOP / c~) psi~ f~^2       (7)
  totals            T = t + t~,  E = e + e~                            (8),(9)

plus (our addition, used by the serving engine and the multi-pod mapping) an
optional transport term for the intermediate embedding: the boundary
activation of size S_emb bytes at bit-width b_emb over a link of rate
``link_bps`` — this is the Wi-Fi uplink in the paper's testbed and the
ICI/DCN hop in the pod mapping — and, symmetric with it, an uplink
*transmit-energy* term ``tx_power_w × transport_delay`` so link-aware
plans account for radio energy.  Both default to 0 so the faithful model
(computation-dominated, as the paper assumes) is the baseline.

All functions are jnp-pure so the co-design optimizer can differentiate
through them.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["SystemParams", "agent_delay", "server_delay", "agent_energy",
           "server_energy", "transport_delay", "transport_energy",
           "kv_delay", "kv_energy", "total_delay", "total_energy",
           "draft_delay", "draft_energy", "verify_delay", "verify_energy",
           "rollback_delay", "rollback_energy",
           "speculative_round_delay", "speculative_round_energy"]


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Hardware/system constants of §II-D and §VI-C.

    Defaults reproduce the paper's simulation setup: f_max = 2 GHz (device),
    f~_max = 10 GHz (server), c = 32 / c~ = 128 FLOPs/cycle, PUE eta = 1 /
    eta~ = 2, psi = 2e-29, psi~ = 1e-28 W/(cycle/s)^3.
    """

    n_flop_agent: float          # N_FLOP: full-precision on-agent FLOPs
    n_flop_server: float         # N~_FLOP
    b_full: float = 16.0         # b: full-precision storage bit-width
    c_agent: float = 32.0
    c_server: float = 128.0
    f_max: float = 2.0e9
    f_server_max: float = 10.0e9
    eta_agent: float = 1.0
    eta_server: float = 2.0
    psi_agent: float = 2.0e-29
    psi_server: float = 1.0e-28
    # optional transport (0 = faithful computation-only model)
    emb_bytes_full: float = 0.0  # boundary embedding bytes at full precision
    link_bps: float = 0.0        # uplink rate in bytes/s; 0 disables
    tx_power_w: float = 0.0      # radio transmit power; 0 disables tx energy
    # optional KV-cache traffic (decode serving; 0 = prefill-only model).
    # Each decode step streams the whole cache through the memory system,
    # so its cost scales with the *stored* bit-width b_kv exactly the way
    # the uplink scales with b_emb.
    kv_bytes_full: float = 0.0   # KV cache bytes/step at full precision
    kv_bw_bps: float = 0.0       # cache memory bandwidth in bytes/s
    kv_power_w: float = 0.0      # cache access power; 0 disables kv energy


def agent_delay(b_hat, f, p: SystemParams):
    """Eq. (4)."""
    return b_hat * p.n_flop_agent / (p.b_full * f * p.c_agent)


def server_delay(f_server, p: SystemParams):
    """Eq. (5)."""
    return p.n_flop_server / (f_server * p.c_server)


def transport_delay(b_emb, p: SystemParams):
    """Embedding uplink time (0 when link modeling is disabled)."""
    if p.link_bps <= 0.0 or p.emb_bytes_full <= 0.0:
        # python scalar, not jnp.float32: keeps host-side float64 codesign
        # math at full precision when the term is summed in
        return 0.0
    return (b_emb / p.b_full) * p.emb_bytes_full / p.link_bps


def transport_energy(b_emb, p: SystemParams):
    """Uplink radio energy: tx power × uplink time (0 when disabled).

    Symmetric with :func:`transport_delay`, so the codesign feasibility
    check can bill the radio the same way it bills the link time."""
    if p.tx_power_w <= 0.0:
        return 0.0
    return p.tx_power_w * transport_delay(b_emb, p)


def kv_delay(b_kv, p: SystemParams):
    """Per-step KV-cache read time at stored bit-width ``b_kv``.

    Mirrors :func:`transport_delay`: linear in the bit-width, 0 when
    cache modeling is disabled, and a python scalar so host-side
    codesign math stays float64."""
    if p.kv_bw_bps <= 0.0 or p.kv_bytes_full <= 0.0:
        return 0.0
    return (b_kv / p.b_full) * p.kv_bytes_full / p.kv_bw_bps


def kv_energy(b_kv, p: SystemParams):
    """KV-cache access energy: access power × read time (0 when disabled)."""
    if p.kv_power_w <= 0.0:
        return 0.0
    return p.kv_power_w * kv_delay(b_kv, p)


def agent_energy(b_hat, f, p: SystemParams):
    """Eq. (6)."""
    return p.eta_agent * (b_hat * p.n_flop_agent / (p.b_full * p.c_agent)) \
        * p.psi_agent * f ** 2


def server_energy(f_server, p: SystemParams):
    """Eq. (7)."""
    return p.eta_server * (p.n_flop_server / p.c_server) \
        * p.psi_server * f_server ** 2


def draft_delay(b_draft, k, p: SystemParams):
    """Draft phase of one speculative round (DESIGN.md §16): ``k``
    greedy agent-partition forwards at draft bit-width ``b_draft``.

    Drafting is latency-critical and pinned at ``f_max``, which keeps
    the term independent of the codesign's frequency variables — it
    reduces the (T0, E0) budgets the way the transport share does."""
    return k * agent_delay(b_draft, p.f_max, p)


def draft_energy(b_draft, k, p: SystemParams):
    """Energy of the draft phase (eq. (6) at ``f_max``, ``k`` times)."""
    return k * agent_energy(b_draft, p.f_max, p)


def verify_delay(b_hat, f, f_server, k, p: SystemParams):
    """Verify phase of one speculative round: one *batched* forward over
    the ``k`` drafted positions plus the correction/bonus position, at
    the class operating point (b̂, f, f̃).

    Decode forwards are weight-stream bound, so computing ``k + 1``
    positions under one weight pass costs one per-token forward in both
    time and energy — that amortization (plus the once-per-round uplink)
    is the speculative win the codesign trades against the draft
    overhead and the acceptance loss (DESIGN.md §16).  ``k`` is accepted
    for signature symmetry with :func:`draft_delay` but does not enter."""
    del k
    return agent_delay(b_hat, f, p) + server_delay(f_server, p)


def verify_energy(b_hat, f, f_server, k, p: SystemParams):
    """Energy of the verify phase: one weight pass (eqs. (6)-(7)),
    mirroring :func:`verify_delay`'s bandwidth-bound batching model."""
    del k
    return agent_energy(b_hat, f, p) + server_energy(f_server, p)


def rollback_delay(b_kv, n_rejected, p: SystemParams):
    """Rollback cost: the speculative cache entries written for the
    ``n_rejected`` tokens the verifier refused must be truncated — one
    discarded cache write per rejected draft, billed at the stored
    bit-width (0 when cache modeling is disabled)."""
    return n_rejected * kv_delay(b_kv, p)


def rollback_energy(b_kv, n_rejected, p: SystemParams):
    """Energy of truncating rejected speculative cache writes."""
    return n_rejected * kv_energy(b_kv, p)


def speculative_round_delay(b_hat, f, f_server, b_draft, k, tau,
                            p: SystemParams, b_emb=None, b_kv=None):
    """Expected wall delay of one draft/uplink/verify/rollback cycle
    delivering ``tau`` tokens in expectation (DESIGN.md §16).

    The uplink fires once per *round* (tokens + boundary hidden state),
    not once per token — that amortization is the speculative win.  The
    cache is read ``k`` times by the draft chain plus once by the
    batched verify forward; the expected ``k + 1 - tau`` rejected
    entries are billed as rollback truncation."""
    t = draft_delay(b_draft, k, p) \
        + verify_delay(b_hat, f, f_server, k, p)
    if b_emb is not None:
        t = t + transport_delay(b_emb, p)
    if b_kv is not None:
        t = t + (k + 1) * kv_delay(b_kv, p) \
            + rollback_delay(b_kv, max(k + 1 - tau, 0.0), p)
    return t


def speculative_round_energy(b_hat, f, f_server, b_draft, k, tau,
                             p: SystemParams, b_emb=None, b_kv=None):
    """Expected energy of one speculative round, mirroring
    :func:`speculative_round_delay` term for term."""
    e = draft_energy(b_draft, k, p) \
        + verify_energy(b_hat, f, f_server, k, p)
    if b_emb is not None:
        e = e + transport_energy(b_emb, p)
    if b_kv is not None:
        e = e + (k + 1) * kv_energy(b_kv, p) \
            + rollback_energy(b_kv, max(k + 1 - tau, 0.0), p)
    return e


def total_delay(b_hat, f, f_server, p: SystemParams, b_emb=None,
                b_kv=None):
    """Eq. (8) (+ optional transport and KV-cache terms)."""
    t = agent_delay(b_hat, f, p) + server_delay(f_server, p)
    if b_emb is not None:
        t = t + transport_delay(b_emb, p)
    if b_kv is not None:
        t = t + kv_delay(b_kv, p)
    return t


def total_energy(b_hat, f, f_server, p: SystemParams, b_emb=None,
                 b_kv=None):
    """Eq. (9) (+ optional uplink transmit energy and KV-cache access
    energy, mirroring :func:`total_delay`'s optional terms)."""
    e = agent_energy(b_hat, f, p) + server_energy(f_server, p)
    if b_emb is not None:
        e = e + transport_energy(b_emb, p)
    if b_kv is not None:
        e = e + kv_energy(b_kv, p)
    return e
