"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code.  [arXiv:2405.04324; hf]

MQA (kv=1): the KV head cannot TP-shard, so decode caches shard on batch
(default rules fall back via divisibility).  34B params -> fsdp weights,
two-level remat scan (8 x 11 layers).
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    norm="rmsnorm", act="gelu", rope_theta=1.0e4,  # gpt_bigcode: non-gated MLP
    fsdp=True, remat_block=11,
    split_layer=22,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="granite-34b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=192, vocab_size=512, fsdp=False, remat_block=2,
        split_layer=1)
