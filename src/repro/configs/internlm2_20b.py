"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA.  [arXiv:2403.17297; hf]
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    norm="rmsnorm", act="silu", rope_theta=1.0e6,
    fsdp=True, remat_block=8,
    split_layer=12,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="internlm2-20b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=512, fsdp=False, remat_block=2,
        split_layer=1)
