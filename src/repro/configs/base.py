"""Model & shape configuration schema.

Every assigned architecture is one ``ModelConfig`` (exact published numbers)
plus a ``smoke()`` reduction of the same family for CPU tests.  Shapes are
the four assigned input-shape cells; helpers below build the (arch x shape)
grid the dry-run walks.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # expert hidden size (0 -> d_ff)
    moe_every: int = 1                # MoE replaces MLP every k-th layer
    capacity_factor: float = 1.25

    # --- hybrid (Jamba-style) ---
    attn_period: int = 0              # 1 attention layer per `attn_period`
    mamba_d_state: int = 64
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_headdim: int = 64           # SSD head dim

    # --- xLSTM ---
    slstm_period: int = 0             # 1 sLSTM per `slstm_period` blocks

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    sliding_window: int = 0           # 0 = full attention

    # --- encoder-decoder ---
    n_enc_layers: int = 0             # >0 -> enc-dec; n_layers is decoder depth

    # --- misc arch ---
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    tie_embeddings: bool = False
    frontend: Literal["none", "vision", "audio"] = "none"
    vis_frac: float = 0.5             # fraction of seq given to stub embeds

    # --- numerics ---
    param_dtype: str = "float32"
    dtype: str = "float32"

    # --- distribution ---
    fsdp: bool = False                # shard weight 'embed' axis over data
    remat_block: int = 0              # outer-scan block size (0 = single scan)
    scan_layers: bool = True

    # --- co-inference (the paper's feature) ---
    split_layer: int = -1             # agent/server boundary; -1 -> L // 4

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.split_layer < 0:
            object.__setattr__(self, "split_layer", max(1, self.n_layers // 4))
        if self.n_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ----- derived sizes -----
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_moe_layer(self, idx: int) -> bool:
        if not self.n_experts:
            return False
        return (idx % self.moe_every) == (self.moe_every - 1)

    def is_attn_layer(self, idx: int) -> bool:
        """Hybrid models: one attention layer per `attn_period`."""
        if self.attn_period <= 0:
            return True
        return (idx % self.attn_period) == (self.attn_period - 1)

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        per_mlp = 3 * d * f if self.act == "silu" else 2 * d * f
        per_moe = (3 * d * self.moe_d_ff) * self.n_experts + d * self.n_experts
        per_mamba = self._mamba_params()
        total = emb
        n_dec = self.n_layers
        for i in range(n_dec):
            if self.family == "hybrid" and not self.is_attn_layer(i):
                total += per_mamba
            elif self.family == "ssm":
                total += self._xlstm_params()
                continue
            else:
                total += per_attn
            if self.is_moe_layer(i):
                total += per_moe
            elif f > 0:
                total += per_mlp
        for _ in range(self.n_enc_layers):
            total += per_attn + per_mlp
            total += per_attn  # decoder cross-attention (counted here)
        return float(total)

    def _mamba_params(self) -> int:
        d_in = self.d_model * self.mamba_expand
        n = self.mamba_d_state
        nh = d_in // self.mamba_headdim
        return (self.d_model * (2 * d_in + 2 * n + nh)  # in_proj(x,z)+B,C,dt
                + d_in * self.mamba_d_conv              # depthwise conv
                + d_in * self.d_model)                  # out_proj

    def _xlstm_params(self) -> int:
        d = self.d_model
        dq = self.q_dim
        # mLSTM block: q,k,v projections + gates + out + ffn-ish up/down
        return d * dq * 3 + d * self.n_heads * 3 + dq * d + 2 * d * 4 * d

    def active_param_count(self) -> float:
        """MoE: parameters touched per token (for MODEL_FLOPS = 6 N_active D)."""
        if not self.n_experts:
            return self.param_count()
        dense_like = dataclasses.replace(
            self, n_experts=0, experts_per_token=0)
        dense = dense_like.param_count()
        # add back the active experts' share on MoE layers
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        active_moe = n_moe_layers * (
            3 * self.d_model * self.moe_d_ff * self.experts_per_token
            + self.d_model * self.n_experts)
        n_mlp_replaced = n_moe_layers * 3 * self.d_model * self.d_ff
        # dense count already included a full MLP on those layers when d_ff>0;
        # for MoE archs d_ff is the expert size so remove the double count
        return float(dense - n_mlp_replaced + active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)

#: archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason) for one (arch x shape) cell per the assignment."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "SKIP (full attention; no sub-quadratic path)"
    return True, ""


def smoke_shape(kind: str) -> ShapeSpec:
    """Reduced shapes for CPU smoke tests."""
    if kind == "train":
        return ShapeSpec("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 32, 2, "prefill")
    return ShapeSpec("smoke_decode", 32, 2, "decode")
