"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone only (Mistral-7B); the anyres vision frontend is a STUB:
input_specs supplies precomputed patch embeddings for vis_frac of the
sequence (anyres: up to 5 tiles x 576 patch tokens; at train_4k that is
~70%% of the 4096 budget -> vis_frac=0.7).
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    norm="rmsnorm", act="silu", rope_theta=1.0e6,
    frontend="vision", vis_frac=0.7,
    fsdp=True, remat_block=8,
    split_layer=8,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="llava-next-mistral-7b-smoke", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512, fsdp=False,
        remat_block=2, split_layer=1)
