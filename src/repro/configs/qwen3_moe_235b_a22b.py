"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128e top-8 — 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8, moe_d_ff=1536, moe_every=1,
    norm="rmsnorm", act="silu", rope_theta=1.0e6,
    fsdp=True,
    split_layer=23,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="qwen3-moe-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=512, n_experts=8,
        experts_per_token=2, moe_d_ff=96, fsdp=False, split_layer=1)
