"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published full config;
``get_smoke(arch_id)`` a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib

from .base import (ModelConfig, ShapeSpec, ALL_SHAPES, TRAIN_4K,  # noqa: F401
                   PREFILL_32K, DECODE_32K, LONG_500K, cell_applicable,
                   smoke_shape)

ARCH_IDS = (
    "stablelm-3b",
    "qwen2-0.5b",
    "granite-34b",
    "internlm2-20b",
    "xlstm-350m",
    "llava-next-mistral-7b",
    "seamless-m4t-large-v2",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "jamba-1.5-large-398b",
)

#: paper's own evaluation models (§VI)
PAPER_IDS = ("fcdnn-16", "blip2-proxy", "git-proxy")

_MODULES = {
    "stablelm-3b": "stablelm_3b",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-34b": "granite_34b",
    "internlm2-20b": "internlm2_20b",
    "xlstm-350m": "xlstm_350m",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "fcdnn-16": "fcdnn16",
    "blip2-proxy": "blip2_proxy",
    "git-proxy": "git_proxy",
}


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).FULL


def get_smoke(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke()
