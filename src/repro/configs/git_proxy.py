"""GIT-base proxy (paper §VI): visual encoder + text decoder, 176.62M
params, 212.27 GFLOPs to first token.  Reduced-scale stand-in with the same
decoupled structure for the distortion/codesign benchmarks."""

import dataclasses

from .base import ModelConfig

N_FLOP_FIRST_TOKEN = 212.27e9   # paper §VI-A
N_PARAMS = 176.62e6

FULL = ModelConfig(
    name="git-proxy", family="vlm",
    n_layers=6, d_model=192, n_heads=6, n_kv_heads=6,
    d_ff=768, vocab_size=2048,
    norm="layernorm", act="gelu",
    frontend="vision", vis_frac=0.5,
    split_layer=2,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(FULL, n_layers=3, d_model=48, n_heads=4,
                               n_kv_heads=4, head_dim=12, d_ff=96,
                               vocab_size=512, split_layer=1)
