"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks.  [arXiv:2405.04517; unverified]

xLSTM[7:1]: one sLSTM block per 8 (slstm_period=8).  Fully recurrent ->
O(1) decode state -> runs long_500k.
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_period=8,
    norm="layernorm", act="gelu",
    split_layer=8,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="xlstm-350m-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=4, vocab_size=512, slstm_period=4, split_layer=4)
