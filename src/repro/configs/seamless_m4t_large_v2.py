"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

Encoder-decoder backbone (24 enc + 24 dec); the speech frontend is a STUB
(precomputed frame embeddings).  Shape cells split seq budget 50/50 between
encoder frames and decoder tokens (EXPERIMENTS.md).
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    n_enc_layers=24,
    norm="layernorm", act="gelu",
    frontend="audio",
    split_layer=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="seamless-m4t-large-v2-smoke", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=512, n_enc_layers=2,
        split_layer=1)
