"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b; unverified]

StableLM-2 family: LayerNorm, SiLU-gated MLP, RoPE.
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", act="silu", rope_theta=1.0e4,
    split_layer=8,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="stablelm-3b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab_size=512, split_layer=1)
