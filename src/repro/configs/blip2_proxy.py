"""BLIP-2 proxy (paper §VI): decoupled vision-encoder + LLM architecture.

We cannot ship BLIP-2-2.7b weights offline; the *proxy* keeps the paper's
co-inference-relevant structure (frozen frontend -> Q-Former-like boundary ->
LM) at a reduced scale for the distortion/codesign benchmarks.  The paper's
FLOP figure (533.66 GFLOPs to first token, 3.75B params) parameterizes the
cost model in benchmarks; this config parameterizes the measured-distortion
experiments.
"""

import dataclasses

from .base import ModelConfig

N_FLOP_FIRST_TOKEN = 533.66e9   # paper §VI-A
N_PARAMS = 3.75e9

FULL = ModelConfig(
    name="blip2-proxy", family="vlm",
    n_layers=8, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=1024, vocab_size=2048,
    norm="layernorm", act="gelu",
    frontend="vision", vis_frac=0.5,
    split_layer=2,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(FULL, n_layers=4, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=160,
                               vocab_size=512, split_layer=1)
