"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

Expert weights dominate (~1T total, ~32B active): EP over the model axis
(384/16 = 24 experts per slice) x FSDP on the expert 'embed' axis ->
512-way parameter sharding on the multi-pod mesh.
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, experts_per_token=8, moe_d_ff=2048, moe_every=1,
    norm="rmsnorm", act="silu", rope_theta=5.0e4,
    fsdp=True,
    split_layer=15,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="kimi-k2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=512, n_experts=8,
        experts_per_token=2, moe_d_ff=96, fsdp=False, split_layer=1)
