"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias.  [arXiv:2407.10671; hf]

Qwen2: RMSNorm, SwiGLU, RoPE theta=1e6, QKV bias, tied embeddings (0.5B).
14 heads do not divide the 16-way model axis; the fused q dim (896) does, so
TP shards the fused axis (see parallel/sharding.py divisibility rules).
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64,
    qkv_bias=True, tie_embeddings=True,
    norm="rmsnorm", act="silu", rope_theta=1.0e6,
    split_layer=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="qwen2-0.5b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=512, split_layer=1)
