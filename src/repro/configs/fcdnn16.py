"""FCDNN-16 (paper §VI-A): 16-hidden-layer fully connected autoencoder with
ReLU, encoder dims [64,128,256,512,256,128,64,32], symmetric decoder.
Used to validate Proposition 3.1 (benchmarks/distortion.py)."""

ENCODER_DIMS = (64, 128, 256, 512, 256, 128, 64, 32)
DECODER_DIMS = tuple(reversed(ENCODER_DIMS))
INPUT_DIM = 784  # MNIST-like

# not a ModelConfig — this is the paper's toy FC model; see
# repro/models/fcdnn.py for init/apply.
FULL = None


def smoke():
    return None
