"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

Super-block of 8 (7 Mamba + 1 attention), MoE every other layer.
Sub-quadratic (Mamba-dominant) -> runs long_500k with the 9 attention
caches sharded along the sequence axis.
"""

import dataclasses

from .base import ModelConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_d_ff=24576, moe_every=2,
    attn_period=8, mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    mamba_headdim=128,
    norm="rmsnorm", act="silu",
    fsdp=True,
    split_layer=16,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, name="jamba-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, n_experts=4,
        experts_per_token=2, moe_d_ff=128, mamba_headdim=32, fsdp=False,
        split_layer=4)
