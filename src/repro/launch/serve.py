"""Co-inference serving driver:
``python -m repro.launch.serve --arch qwen2-0.5b --smoke``.

``--fleet <spec.json>`` serves a multi-agent fleet from one shared edge
server (DESIGN.md §11): the spec lists heterogeneous agents (arch, QoS
budgets, weights, optional per-agent environment traces), the fleet
allocator splits the server frequency across them (water-filling joint
allocation or the equal-split baseline), and every agent serves through
its own member engine over shared codesign/compile caches — see
``examples/fleet_spec.json`` for the format.

Demonstrates the paper's full loop on real (reduced) models, through the
batched serving engine (DESIGN.md §7) by default: per-QoS-class joint
(b̂, f, f̃) co-design solved once per class via the codesign cache, a
request queue packed into per-class batches, agent stage at b̂ ->
embedding uplink -> server stage -> logits, with batch-level and
per-request delay/energy accounting.  ``--engine sequential`` runs the
original one-request-at-a-time path for comparison; the two produce
bitwise-identical logits per request.  ``--mixed-precision`` replaces
the scalar b̂ per class with the layer-wise bit allocation of
``core.mixed_precision`` (DESIGN.md §8).

``--env-trace`` picks a canned dynamic environment (``repro.env``
presets: Markov Wi-Fi, Rayleigh fading, Table I profile replay, battery
drain, or the combined ``edge-day``) and serves through the online
adaptive engine (DESIGN.md §9); ``--adaptive-policy`` chooses the
static / adaptive / oracle controller.

``--chaos-trace <spec.json>`` injects a seeded fault trace (DESIGN.md
§15: link outages, uplink corruption, server preemption, fleet agent
dropout — see ``examples/chaos_spec.json``) and serves through the
``ServingSupervisor``, which retries with backoff, retransmits
corrupted uplinks, fails over to degraded device-only serving, and
crash-recovers in-flight decode state; ``--chaos-bare`` drops the
defenses for the unsupervised baseline.  Works with every queued
engine (batched / adaptive / decode / fleet); ``--engine sequential``
has no queue to supervise and rejects the flag.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke
from ..core import baselines as bl
from ..core import codesign as cd
from ..core.cost_model import SystemParams
from ..data import MarkovLMConfig, MarkovLMDataset
from ..env import presets as env_presets
from ..env.faults import chaos_from_spec
from ..models.registry import build_model
from ..obs import NULL_METRICS, NULL_TRACER, MetricsRegistry, Tracer
from ..runtime import (AdaptiveCoInferenceEngine, BatchedCoInferenceEngine,
                       CodesignCache, CoInferenceEngine, DecodeEngine,
                       FleetAgentSpec, FleetCoInferenceEngine, QosClass,
                       ServingSupervisor, SpeculativeDecodeEngine,
                       greedy_decode_reference)
from ..runtime.decode_engine import decode_protocol_gap

# the realizable draft-container rungs --speculative may pin
SPEC_DRAFT_CHOICES = (2, 4, 8)

ENV_TRACES = {
    "wifi-markov": env_presets.wifi_markov,
    "rayleigh": env_presets.rayleigh_fading,
    "profiles": env_presets.profile_replay,
    "battery": env_presets.battery_drain,
    "edge-day": env_presets.edge_day,
    "constant": env_presets.constant,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sequential"])
    ap.add_argument("--requests", type=int, default=12,
                    help="number of queued requests (batched engine)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4,
                    help="requests per serve_batch (sequential engine)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--t0", type=float, default=3.5)
    ap.add_argument("--e0", type=float, default=2.0)
    ap.add_argument("--path", default="fake", choices=["fake", "kernel"])
    ap.add_argument("--decode", action="store_true",
                    help="serve autoregressive decode through the "
                         "continuous-batching engine over a quantized KV "
                         "cache (DESIGN.md §12): requests admit into free "
                         "batch slots mid-flight and retire independently, "
                         "with per-class b_kv chosen by the codesign")
    ap.add_argument("--max-new", type=int, default=16,
                    help="tokens to generate per request (--decode)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative co-inference (DESIGN.md §16): the "
                         "agent partition drafts --lookahead tokens per "
                         "round at --draft-bits, the server verifies them "
                         "in one batched forward with longest-accepted-"
                         "prefix rollback; implies --decode")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="draft bit-width b_draft for --speculative "
                         f"(one of {SPEC_DRAFT_CHOICES})")
    ap.add_argument("--lookahead", type=int, default=4,
                    help="draft tokens per speculative round (k >= 1)")
    ap.add_argument("--parity-check", action="store_true",
                    help="replay every --decode request through the "
                         "non-batched sequential reference and assert "
                         "bitwise-identical greedy tokens")
    ap.add_argument("--compiled", action="store_true",
                    help="serve through the compiled fast path "
                         "(DESIGN.md §10): one AOT-compiled bucket-padded "
                         "agent->transport->server executable per "
                         "(plan, bucket), precompiled via warmup()")
    ap.add_argument("--mixed-precision", action="store_true",
                    help="per-layer bit allocation (DESIGN.md §8) instead "
                         "of one uniform b̂ per QoS class")
    ap.add_argument("--env-trace", default=None,
                    choices=sorted(ENV_TRACES),
                    help="serve under a canned dynamic environment "
                         "(DESIGN.md §9) through the adaptive engine")
    ap.add_argument("--env-seed", type=int, default=0)
    ap.add_argument("--adaptive-policy", default="adaptive",
                    choices=["static", "adaptive", "oracle"],
                    help="controller for --env-trace serving")
    ap.add_argument("--fleet", default=None, metavar="SPEC.json",
                    help="serve a multi-agent fleet from one shared edge "
                         "server (DESIGN.md §11); the JSON spec lists the "
                         "agents — see examples/fleet_spec.json")
    ap.add_argument("--allocator", default=None,
                    choices=["joint", "equal"],
                    help="fleet share allocator: water-filling joint "
                         "codesign or the equal-split baseline "
                         "(default: the spec's choice, else joint)")
    ap.add_argument("--chaos-trace", default=None, metavar="SPEC.json",
                    help="inject a seeded fault trace (DESIGN.md §15) and "
                         "serve through the ServingSupervisor — see "
                         "examples/chaos_spec.json for the format")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="override the chaos spec's seed")
    ap.add_argument("--chaos-bare", action="store_true",
                    help="unsupervised baseline: same injected faults, no "
                         "retry/failover/recovery — faults lose work")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="write a Chrome trace-event JSON of the run "
                         "(DESIGN.md §14) — load it in Perfetto/"
                         "chrome://tracing or feed tools/trace_summary.py")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS.json",
                    help="write a JSON metrics snapshot (counters/gauges/"
                         "histograms, DESIGN.md §14) at the end of the run")
    args = ap.parse_args(argv)

    # observability is strictly opt-in: without the flags the engines get
    # the module-level no-op singletons and pay nothing (DESIGN.md §14)
    tracer = Tracer() if args.trace_out else NULL_TRACER
    metrics = MetricsRegistry() if args.metrics_out else NULL_METRICS
    try:
        rc = _dispatch(args, tracer, metrics)
    finally:
        _write_obs(args, tracer, metrics)
    return rc


def _load_chaos(args):
    """Parse --chaos-trace into a ChaosTrace, or (None, rc) on failure —
    same one-line-error/exit-2 contract as the fleet spec path."""
    if args.chaos_trace is None:
        return None, None
    if args.engine == "sequential" and args.fleet is None \
            and not args.decode and args.env_trace is None:
        print("error: --chaos-trace needs a queued engine to supervise; "
              "--engine sequential serves one call at a time. Use the "
              "batched/adaptive/decode/fleet modes.", file=sys.stderr)
        return None, 2
    spec_path = pathlib.Path(args.chaos_trace)
    try:
        spec = json.loads(spec_path.read_text(encoding="utf-8"))
        chaos = chaos_from_spec(spec, seed=args.chaos_seed)
    except (OSError, ValueError) as e:
        print(f"error: cannot load chaos trace {spec_path}: {e}",
              file=sys.stderr)
        return None, 2
    return chaos, None


def _supervise(eng, chaos, args, tracer, metrics):
    """Wrap an engine for --chaos-trace serving (None chaos = no wrap)."""
    if chaos is None:
        return None
    return ServingSupervisor(eng, chaos=chaos,
                             supervised=not args.chaos_bare,
                             seed=chaos.seed, tracer=tracer,
                             metrics=metrics)


def _print_resilience(sup):
    r = sup.report()
    print(f"resilience [{r.mode}]: delivered {r.delivered}/"
          f"{r.requests_total} (failed {r.failed}, shed {r.shed}) "
          f"retries={r.retries} retransmits={r.retransmits} "
          f"failovers={r.failovers} recoveries={r.recoveries} "
          f"reallocations={r.reallocations} faults={r.faults_seen} "
          f"tokens lost/dup={r.tokens_lost}/{r.tokens_duplicated} "
          f"goodput={r.goodput:.1f} {r.goodput_unit}")


def _dispatch(args, tracer, metrics):
    chaos, rc = _load_chaos(args)
    if rc is not None:
        return rc
    if args.speculative:
        if args.lookahead < 1:
            print(f"error: --lookahead {args.lookahead} is not a valid "
                  "draft length; speculative decode drafts k >= 1 tokens "
                  "per round", file=sys.stderr)
            return 2
        if args.draft_bits not in SPEC_DRAFT_CHOICES:
            print(f"error: --draft-bits {args.draft_bits} is off the "
                  f"realizable draft ladder {SPEC_DRAFT_CHOICES}; the "
                  "draft weights live in the same quantized containers "
                  "as every other plan (DESIGN.md §16)", file=sys.stderr)
            return 2
        args.decode = True      # speculative serving is a decode mode
    if args.fleet is not None:
        return serve_fleet(args, tracer, metrics, chaos=chaos)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg is None:
        # fcdnn-16: the paper's toy FC benchmark model ships no
        # ModelConfig — fail like any other unservable arch, not with a
        # build_model traceback
        print(f"error: arch {args.arch} has no servable model config "
              "(it is the distortion-benchmark toy model, not a "
              "transformer); pick a DecoderLM-family arch "
              "(e.g. qwen2-0.5b, stablelm-3b)", file=sys.stderr)
        return 2
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    err = unsupported_model_reason(model, args.arch, args.compiled,
                                   decode=args.decode,
                                   speculative=args.speculative)
    if err is not None:
        print(f"error: {err}", file=sys.stderr)
        return 2

    tokens = args.batch * args.seq
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    sysp = SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens)

    if args.decode:
        return serve_decode(cfg, model, params, sysp, args, tracer, metrics,
                            chaos=chaos)
    if args.env_trace is not None:
        return serve_adaptive(cfg, model, params, args, tracer, metrics,
                              chaos=chaos)
    if args.engine == "batched":
        return serve_batched(cfg, model, params, sysp, args, tracer, metrics,
                             chaos=chaos)
    return serve_sequential(cfg, model, params, sysp, args, tracer, metrics)


def _write_obs(args, tracer, metrics):
    """Flush --trace-out / --metrics-out files (in a finally, so a failed
    run still leaves a loadable partial trace behind for debugging)."""
    if args.trace_out and tracer.enabled:
        tracer.write(args.trace_out)
        print(f"trace: {len(tracer.events)} events -> {args.trace_out}")
    if args.metrics_out and metrics.enabled:
        metrics.write(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")


def unsupported_model_reason(model, arch: str, compiled: bool,
                             decode: bool = False,
                             speculative: bool = False):
    """One-line reason this model cannot serve the invocation, or None.

    Mirrors the engine constructors' protocol checks so the driver can
    fail with a clear message instead of a constructor traceback:
    co-inference needs the DecoderLM ``run_layers`` protocol at all,
    ``--compiled`` additionally needs the ``embed`` +
    ``run_layers_window`` hooks the fast path traces (DESIGN.md §10),
    and ``--decode`` / ``--speculative`` need the full DecoderLM
    KV-cache decode protocol (DESIGN.md §12, §16).  One function serves
    both the flag path and the fleet-spec path, so the hook
    requirements live in exactly one place.
    """
    if speculative:
        gap = decode_protocol_gap(model)
        if gap is not None:
            return (f"--speculative does not support arch {arch}: {gap}. "
                    "Drop --speculative or pick a dense DecoderLM-family "
                    "arch (e.g. qwen2-0.5b, stablelm-3b).")
    if decode:
        gap = decode_protocol_gap(model)
        if gap is not None:
            return (f"--decode does not support arch {arch}: {gap}. "
                    "Drop --decode or pick a dense DecoderLM-family arch "
                    "(e.g. qwen2-0.5b, stablelm-3b).")
    if compiled and not (hasattr(model, "embed")
                         and hasattr(model, "run_layers_window")):
        return (f"--compiled does not support arch {arch}: "
                f"{type(model).__name__} lacks the embed/"
                "run_layers_window hooks the compiled fast path traces "
                "(DESIGN.md §10). Drop --compiled or pick a dense "
                "DecoderLM-family arch (e.g. qwen2-0.5b, stablelm-3b).")
    if not hasattr(model, "run_layers"):
        return (f"arch {arch} is not servable: {type(model).__name__} "
                "lacks run_layers; co-inference split execution needs "
                "the DecoderLM protocol")
    return None


def serve_sequential(cfg, model, params, sysp, args,
                     tracer=NULL_TRACER, metrics=NULL_METRICS):
    eng = CoInferenceEngine(model, params, sysp, path=args.path,
                            compiled=args.compiled,
                            tracer=tracer, metrics=metrics)
    print(f"arch={cfg.name} split={cfg.split_layer}/{cfg.n_layers} "
          f"lambda_hat={eng.lam:.2f} path={args.path} engine=sequential "
          f"compiled={args.compiled}")

    qos = QosClass("interactive", t0=args.t0, e0=args.e0)
    if args.mixed_precision:
        msol = eng.auto_configure_mixed(qos)
        if msol is None:
            print(f"(P1) infeasible under T0={args.t0}s E0={args.e0}J")
            return 1
        print(f"mixed codesign: bits={list(msol.bits)} "
              f"(mean {msol.mean_bits:.2f}, uniform best "
              f"b_hat={msol.uniform_b}) f={msol.f / 1e9:.2f}GHz "
              f"f~={msol.f_server / 1e9:.2f}GHz "
              f"bound={msol.objective:.3e} (uniform "
              f"{msol.uniform_objective:.3e}) "
              f"T={msol.delay:.3f}s E={msol.energy:.3f}J")
    else:
        sol = eng.auto_configure(qos)
        if sol is None:
            print(f"(P1) infeasible under T0={args.t0}s E0={args.e0}J")
            return 1
        print(f"codesign: b_hat={sol.b_hat} f={sol.f / 1e9:.2f}GHz "
              f"f~={sol.f_server / 1e9:.2f}GHz gap={sol.objective:.3e} "
              f"T={sol.delay:.3f}s E={sol.energy:.3f}J "
              f"(SCA iters={sol.iterations})")

    for name, solver in (("oracle", cd.solve_oracle),
                         ("fixed-freq", bl.solve_fixed_frequency),
                         ("ppo", bl.solve_ppo)):
        s = solver(eng.lam, sysp, args.t0, args.e0)
        print(f"  {name:11s}: " + (
            f"b_hat={s.b_hat} gap={s.objective:.3e}" if s else "infeasible"))

    ds = MarkovLMDataset(MarkovLMConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        batch_size=args.batch))
    batch = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}
    logits, stats = eng.serve_batch(batch)
    print(f"served batch {batch['tokens'].shape}: logits {logits.shape}")
    print(f"  agent {stats.agent_delay_s * 1e3:.2f}ms + uplink "
          f"{stats.transport_delay_s * 1e3:.2f}ms + server "
          f"{stats.server_delay_s * 1e3:.2f}ms = "
          f"{stats.total_delay_s * 1e3:.2f}ms, {stats.energy_j:.3f}J, "
          f"emb {stats.emb_bytes / 1024:.1f}KiB at b_emb={eng.b_emb}")
    return 0


def serve_adaptive(cfg, model, params, args,
                   tracer=NULL_TRACER, metrics=NULL_METRICS, chaos=None):
    """Serve a request stream spread across a dynamic-environment trace
    through ``AdaptiveCoInferenceEngine`` (DESIGN.md §9)."""
    env = ENV_TRACES[args.env_trace](seed=args.env_seed)
    # (P1) decisions at the calibrated workload scale (DESIGN.md §7), so
    # the (T0, E0) region — and hence the environment — is genuinely
    # active regardless of the smoke model's real FLOPs
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11,
                        emb_bytes_full=4.0e5, tx_power_w=0.25)
    classes = [
        QosClass("realtime", t0=max(args.t0 / 3.0, 0.2),
                 e0=max(args.e0 / 2.0, 0.2)),
        QosClass("interactive", t0=args.t0, e0=args.e0),
    ]
    eng = AdaptiveCoInferenceEngine(
        model, params, sysp, classes=classes, max_batch=args.max_batch,
        path=args.path, environment=env, policy=args.adaptive_policy,
        mixed_precision=args.mixed_precision, compiled=args.compiled,
        tracer=tracer, metrics=metrics)
    print(f"arch={cfg.name} env={args.env_trace} (seed {args.env_seed}, "
          f"{env.n_steps} x {env.dt_s}s) policy={args.adaptive_policy} "
          f"engine=adaptive")
    for c in classes:
        s = eng.solution_for(c.name)
        print(f"  class {c.name:12s} (T0={c.t0:.2f}s, E0={c.e0:.2f}J): "
              f"b_hat={s.b_hat} f={s.f / 1e9:.2f}GHz "
              f"f~={s.f_server / 1e9:.2f}GHz")

    sup = _supervise(eng, chaos, args, tracer, metrics)
    front = sup if sup is not None else eng
    # arrivals spread across the trace so the stream *experiences* it
    rng = np.random.default_rng(1)
    span = env.horizon_s * 0.9
    for i in range(args.requests):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(args.seq // 2,
                                                  args.seq + 1)))
        front.submit(toks, classes[i % len(classes)].name,
                     arrival_s=i * span / max(args.requests, 1))
    responses = front.drain()

    print(f"served {len(responses)} requests in "
          f"{len(eng.batch_history)} batches:")
    for b in eng.batch_history:
        print(f"  [{b.qos:12s}] n={b.batch_size} b_hat={b.b_hat:2d} "
              f"f={b.f / 1e9:.2f}GHz T={b.batch_delay_s * 1e3:8.2f}ms "
              f"E={b.energy_j:.3f}J")
    rep = eng.adaptive_report()
    print(f"adaptive report: replans={rep.replans} "
          f"(switches={rep.plan_switches}, degraded="
          f"{rep.degraded_batches}) deadline violations="
          f"{rep.deadline_violations}/{rep.requests_served} "
          f"weight variants={rep.weight_variants} "
          f"env keys={rep.env_keys_seen}")
    for e in eng.replan_events:
        print(f"  t={e.t_s:7.2f}s [{e.qos:12s}] {e.reason}: "
              f"b {e.b_before:.0f} -> {e.b_after:.0f}"
              + (" (degraded)" if e.degraded else ""))
    if sup is not None:
        _print_resilience(sup)
    return 0


def serve_decode(cfg, model, params, sysp, args,
                 tracer=NULL_TRACER, metrics=NULL_METRICS, chaos=None):
    """Continuous-batching greedy decode over a quantized KV cache
    (DESIGN.md §12) through ``DecodeEngine``."""
    # give the codesign a KV-cost term sized to this model's cache so the
    # b_kv rung is a real decision, not a free variable (DESIGN.md §12):
    # a full-precision cache read costs 0.5s/1.0J per step, so the tight
    # realtime budget forces a lower rung while loose budgets keep b_full
    kv_full = (2.0 * cfg.n_layers * args.max_batch
               * (args.seq + args.max_new) * cfg.n_kv_heads
               * max(cfg.head_dim, 1) * np.dtype(cfg.dtype).itemsize)
    # a speculative round streams the cache k+1 times (DESIGN.md §16),
    # so the same choke that makes b_kv a real decision for plain decode
    # would starve every (b_draft, k) point; twice the bandwidth keeps
    # the rung decision live under both round models
    kv_bw = kv_full * (2.0 if args.speculative else 1.0)
    sysp = dataclasses.replace(sysp, kv_bytes_full=kv_full,
                               kv_bw_bps=kv_bw, kv_power_w=2.0)
    classes = [
        QosClass("realtime", t0=max(args.t0 / 3.0, 0.2),
                 e0=max(args.e0 / 2.0, 0.2)),
        QosClass("interactive", t0=args.t0, e0=args.e0),
    ]
    cache = CodesignCache()
    try:
        if args.speculative:
            # pin the draft menus to the requested point: the codesign
            # still solves (b̂, f, f̃, b_kv) jointly around it
            eng = SpeculativeDecodeEngine(
                model, params, sysp, classes=classes,
                max_batch=args.max_batch, max_new_tokens=args.max_new,
                mixed_precision=args.mixed_precision,
                draft_bits=args.draft_bits, lookahead=args.lookahead,
                draft_ladder=(args.draft_bits,),
                lookahead_menu=(args.lookahead,),
                codesign_cache=cache, tracer=tracer, metrics=metrics)
        else:
            eng = DecodeEngine(model, params, sysp, classes=classes,
                               max_batch=args.max_batch,
                               max_new_tokens=args.max_new,
                               mixed_precision=args.mixed_precision,
                               codesign_cache=cache,
                               tracer=tracer, metrics=metrics)
    except ValueError as e:
        print(e)
        return 1
    mode = "speculative" if args.speculative else "decode"
    print(f"arch={cfg.name} split={cfg.split_layer}/{cfg.n_layers} "
          f"lambda_hat={eng.lam:.2f} lambda_kv={eng.lam_kv:.2f} "
          f"engine={mode} max_batch={args.max_batch} "
          f"max_new={args.max_new} admission={eng.admission}")
    import time
    t0 = time.perf_counter()
    n = eng.warmup(args.seq)
    print(f"warmup: {n} decode variants compiled in "
          f"{time.perf_counter() - t0:.1f}s")
    for c in classes:
        s = eng.solution_for(c.name)
        bdesc = "/".join(map(str, s.bits)) if args.mixed_precision \
            else str(s.b_hat)
        spec_desc = ""
        if args.speculative:
            b_d, k = eng.draft_schedule(c.name)
            spec_desc = f" b_draft={b_d} k={k}"
        print(f"  class {c.name:12s} (T0={c.t0:.2f}s, E0={c.e0:.2f}J): "
              f"b_hat={bdesc} b_kv={s.b_kv} f={s.f / 1e9:.2f}GHz "
              f"f~={s.f_server / 1e9:.2f}GHz bound={s.objective:.3e}"
              f"{spec_desc}")

    sup = _supervise(eng, chaos, args, tracer, metrics)
    front = sup if sup is not None else eng
    rng = np.random.default_rng(0)
    prompts = {}
    for i in range(args.requests):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(max(args.seq // 2, 1),
                                                  args.seq + 1)))
        rid = front.submit(toks, classes[i % len(classes)].name,
                           arrival_s=0.01 * i)
        prompts[rid] = (np.asarray(toks), classes[i % len(classes)].name)
    responses = front.drain()

    rep = eng.report()
    print(f"served {rep.requests_served} requests, "
          f"{rep.tokens_generated} tokens in {rep.decode_rounds} rounds "
          f"({rep.prefills} prefills):")
    for cs in rep.classes:
        print(f"  [{cs.qos:12s}] n={cs.requests} b_kv={cs.b_kv} "
              f"ttft={cs.ttft_mean_s * 1e3:.2f}ms "
              f"(max {cs.ttft_max_s * 1e3:.2f}ms) "
              f"itl={cs.itl_mean_s * 1e3:.2f}ms")
    ratio = rep.kv_bytes / rep.kv_bytes_full if rep.kv_bytes_full else 1.0
    print(f"decode report: throughput={rep.throughput_tps:.1f} tok/s "
          f"(modeled), {rep.throughput_rps:.1f} req/s, "
          f"kv cache {rep.kv_bytes / 1024:.1f}KiB "
          f"({ratio:.2f}x of full precision) "
          f"energy={rep.total_energy_j:.3f}J")
    print(f"compile cache: {rep.compiled_variants} variants, "
          f"{rep.compile_hits} hits / {rep.compile_misses} misses")
    if args.speculative:
        st = eng.spec_stats()
        print(f"speculative: {st.rounds} rounds, "
              f"acceptance={st.acceptance_rate:.2f}, "
              f"accepted/round={st.accepted_per_round:.2f}, "
              f"tokens/round={st.tokens_per_round:.2f}")
    if sup is not None:
        _print_resilience(sup)

    if args.parity_check:
        for r in responses:
            toks, qos = prompts[r.request_id]
            ref = greedy_decode_reference(
                model, eng.class_params(qos), toks, len(r.tokens),
                b_kv=r.b_kv, compile_cache=eng.compile_cache)
            if not np.array_equal(np.asarray(r.tokens), ref):
                print(f"error: parity mismatch on request {r.request_id}",
                      file=sys.stderr)
                return 1
        print(f"parity: all {len(responses)} requests bitwise-match the "
              "sequential reference")
    return 0


def serve_batched(cfg, model, params, sysp, args,
                  tracer=NULL_TRACER, metrics=NULL_METRICS, chaos=None):
    classes = [
        QosClass("realtime", t0=max(args.t0 / 3.0, 0.2),
                 e0=max(args.e0 / 2.0, 0.2)),
        QosClass("interactive", t0=args.t0, e0=args.e0),
        QosClass("batch", t0=args.t0 * 2.0, e0=args.e0 * 2.0),
    ]
    cache = CodesignCache()
    try:
        eng = BatchedCoInferenceEngine(
            model, params, sysp, classes=classes, max_batch=args.max_batch,
            path=args.path, codesign_cache=cache,
            mixed_precision=args.mixed_precision,
            compiled=args.compiled,
            tracer=tracer, metrics=metrics)
    except ValueError as e:
        print(e)
        return 1
    print(f"arch={cfg.name} split={cfg.split_layer}/{cfg.n_layers} "
          f"lambda_hat={eng.engine.lam:.2f} path={args.path} "
          f"engine=batched max_batch={args.max_batch} "
          f"mixed_precision={args.mixed_precision} "
          f"compiled={args.compiled}")
    if args.compiled:
        # precompile every (class plan, seq bucket) variant up front so
        # serving below never stalls on an XLA compile (DESIGN.md §10)
        import time
        t0 = time.perf_counter()
        n = eng.warmup(args.seq)
        print(f"warmup: {n} forward variants compiled in "
              f"{time.perf_counter() - t0:.1f}s")
    for c in classes:
        s = eng.solution_for(c.name)
        if args.mixed_precision:
            print(f"  class {c.name:12s} (T0={c.t0:.2f}s, E0={c.e0:.2f}J): "
                  f"bits={list(s.bits)} (mean {s.mean_bits:.2f}) "
                  f"f={s.f / 1e9:.2f}GHz f~={s.f_server / 1e9:.2f}GHz "
                  f"bound={s.objective:.3e} "
                  f"(uniform b_hat={s.uniform_b}: "
                  f"{s.uniform_objective:.3e})")
        else:
            print(f"  class {c.name:12s} (T0={c.t0:.2f}s, E0={c.e0:.2f}J): "
                  f"b_hat={s.b_hat} f={s.f / 1e9:.2f}GHz "
                  f"f~={s.f_server / 1e9:.2f}GHz gap={s.objective:.3e}")

    sup = _supervise(eng, chaos, args, tracer, metrics)
    front = sup if sup is not None else eng
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(args.seq // 2,
                                                  args.seq + 1)))
        front.submit(toks, classes[i % len(classes)].name)
    responses = front.drain()

    print(f"served {len(responses)} requests in "
          f"{len(eng.batch_history)} batches:")
    for b in eng.batch_history:
        bdesc = "/".join(map(str, b.plan_bits)) if b.plan_bits \
            else f"{b.b_hat:2d}"
        print(f"  [{b.qos:12s}] n={b.batch_size} b_hat={bdesc} "
              f"({b.agent_path}) occupancy={b.occupancy:.2f} "
              f"T={b.batch_delay_s * 1e3:.2f}ms "
              f"(amortized {b.amortized_delay_s * 1e3:.2f}ms/req) "
              f"E={b.energy_j:.3f}J wait<= {b.queue_wait_max_s * 1e3:.2f}ms")
    rep = eng.report()
    print(f"report: mean_batch={rep.mean_batch_size:.2f} "
          f"occupancy={rep.mean_occupancy:.2f} "
          f"throughput={rep.throughput_rps:.0f} req/s (modeled) "
          f"energy={rep.total_energy_j:.3f}J")
    print(f"codesign cache: {cache.misses} (P1) solves for "
          f"{len(responses)} requests ({cache.hits} hits)")
    if args.compiled:
        print(f"compile cache: {rep.compiled_variants} variants, "
              f"{rep.compile_hits} hits / {rep.compile_misses} misses "
              f"(every batch after warmup is a hit)")
    if sup is not None:
        _print_resilience(sup)
    return 0


def serve_fleet(args, tracer=NULL_TRACER, metrics=NULL_METRICS, chaos=None):
    """Serve a multi-agent fleet from a JSON spec (DESIGN.md §11).

    The spec's ``agents`` list gives one entry per fleet member: ``name``
    and ``arch`` (required), ``t0``/``e0`` budgets, optional ``weight``,
    ``b_emb``, ``sysp`` field overrides (any ``SystemParams`` field),
    ``env_trace``/``env_seed``/``policy`` for a per-agent dynamic
    environment, and ``requests``/``seq`` per-agent traffic overrides.
    Top-level keys ``allocator``, ``max_batch``, ``path``, ``compiled``,
    ``mixed_precision``, ``requests_per_agent``, and ``seq`` set fleet-
    wide defaults; ``--allocator`` wins over the spec's when passed.
    """
    spec_path = pathlib.Path(args.fleet)
    try:
        spec = json.loads(spec_path.read_text(encoding="utf-8"))
    except (OSError, ValueError) as e:
        print(f"error: cannot read fleet spec {spec_path}: {e}",
              file=sys.stderr)
        return 2
    if not isinstance(spec, dict) or not spec.get("agents"):
        print(f"error: fleet spec {spec_path} must be a JSON object with "
              "a non-empty 'agents' list", file=sys.stderr)
        return 2

    allocator = args.allocator if args.allocator is not None \
        else spec.get("allocator", "joint")
    max_batch = int(spec.get("max_batch", args.max_batch))
    path = spec.get("path", args.path)
    compiled = bool(spec.get("compiled", args.compiled))
    mixed = bool(spec.get("mixed_precision", args.mixed_precision))
    n_default = int(spec.get("requests_per_agent", args.requests))
    seq_default = int(spec.get("seq", args.seq))

    models = {}
    specs, traffic = [], {}
    for a in spec["agents"]:
        # every per-agent failure mode — missing keys, unknown arch or
        # env trace, bad sysp field names, non-numeric values — reports
        # which agent entry is broken, as a one-line error
        label = a.get("name", f"#{len(specs)}") \
            if isinstance(a, dict) else f"#{len(specs)}"
        try:
            arch = a["arch"]
            if arch not in models:
                cfg = get_smoke(arch) if args.smoke else get_config(arch)
                model = build_model(cfg)
                models[arch] = (model,
                                model.init(jax.random.PRNGKey(len(models))))
            model, params = models[arch]
            err = unsupported_model_reason(model, arch, compiled)
            if err is not None:
                raise ValueError(err)
            sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
            if a.get("sysp"):
                sysp = dataclasses.replace(sysp, **a["sysp"])
            env = None
            if a.get("env_trace"):
                if a["env_trace"] not in ENV_TRACES:
                    raise ValueError(
                        f"unknown env_trace {a['env_trace']!r}; have "
                        f"{sorted(ENV_TRACES)}")
                env = ENV_TRACES[a["env_trace"]](
                    seed=int(a.get("env_seed", args.env_seed)))
            specs.append(FleetAgentSpec(
                name=a["name"], model=model, params=params, sysp=sysp,
                qos=QosClass(a["name"], t0=float(a.get("t0", args.t0)),
                             e0=float(a.get("e0", args.e0))),
                weight=float(a.get("weight", 1.0)),
                b_emb=int(a.get("b_emb", 8)),
                environment=env, policy=a.get("policy", "adaptive")))
            traffic[a["name"]] = (int(a.get("requests", n_default)),
                                  int(a.get("seq", seq_default)))
        except (KeyError, TypeError, ValueError, AttributeError) as e:
            print(f"error: fleet agent {label!r}: {e}", file=sys.stderr)
            return 2

    try:
        fleet = FleetCoInferenceEngine(specs, allocator=allocator,
                                       max_batch=max_batch, path=path,
                                       compiled=compiled,
                                       mixed_precision=mixed,
                                       tracer=tracer, metrics=metrics)
    except (TypeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if compiled:
        n = fleet.warmup(max(s for _, s in traffic.values()))
        print(f"warmup: {n} compiled forward variants across the fleet")

    print(f"fleet: {len(specs)} agents, allocator={allocator} "
          f"max_batch={max_batch} path={path}")
    for s, share in zip(specs, fleet.allocation.shares):
        sol = fleet.solution_for(s.name)
        bdesc = "/".join(map(str, sol.bits)) if mixed else str(sol.b_hat)
        envd = f" env={type(s.environment).__name__}" \
            if s.environment is not None else ""
        print(f"  agent {s.name:12s} share={share:.3f} "
              f"(T0={s.qos.t0:.2f}s, E0={s.qos.e0:.2f}J, "
              f"w={s.weight:g}): b_hat={bdesc} f={sol.f / 1e9:.2f}GHz "
              f"f~={sol.f_server / 1e9:.2f}GHz "
              f"bound={sol.objective:.3e}{envd}")

    sup = _supervise(fleet, chaos, args, tracer, metrics)
    front = sup if sup is not None else fleet
    rng = np.random.default_rng(0)
    for s in specs:
        n_req, seq = traffic[s.name]
        cfg = s.model.cfg
        for i in range(n_req):
            toks = rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(max(seq // 2, 1),
                                                      seq + 1)))
            front.submit(s.name, toks)
    front.drain()

    rep = fleet.report()
    print(f"\nserved {rep.requests_served} requests in "
          f"{rep.batches_served} batches across the fleet:")
    for pa in rep.per_agent:
        print(f"  agent {pa.name:12s} n={pa.requests_served} "
              f"batches={pa.batches_served} "
              f"occupancy={pa.mean_occupancy:.2f} "
              f"clock={pa.clock_s * 1e3:8.2f}ms E={pa.energy_j:.3f}J "
              f"violations={pa.deadline_violations}")
    print(f"fleet report: aggregate bound={rep.aggregate_bound:.4e} "
          f"makespan={rep.makespan_s * 1e3:.2f}ms "
          f"throughput={rep.throughput_rps:.0f} req/s (modeled) "
          f"energy={rep.total_energy_j:.3f}J")
    print(f"shared codesign cache: {rep.codesign_misses} solves, "
          f"{rep.codesign_hits} hits across {rep.n_agents} agents")
    if compiled:
        print(f"shared compile cache: {rep.compiled_variants} variants, "
              f"{rep.compile_hits} hits / {rep.compile_misses} misses")
    if sup is not None:
        _print_resilience(sup)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
