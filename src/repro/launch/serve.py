"""Co-inference serving driver:
``python -m repro.launch.serve --arch qwen2-0.5b --smoke``.

Demonstrates the paper's full loop on real (reduced) models: per-QoS-class
joint (b̂, f, f̃) co-design -> agent stage at b̂ -> embedding uplink ->
server stage -> logits + delay/energy report, for both solver and baselines.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..core import baselines as bl
from ..core import codesign as cd
from ..core.cost_model import SystemParams
from ..data import MarkovLMConfig, MarkovLMDataset
from ..models.registry import build_model
from ..runtime import CoInferenceEngine, QosClass


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--t0", type=float, default=3.5)
    ap.add_argument("--e0", type=float, default=2.0)
    ap.add_argument("--path", default="fake", choices=["fake", "kernel"])
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tokens = args.batch * args.seq
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    sysp = SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens)

    eng = CoInferenceEngine(model, params, sysp, path=args.path)
    print(f"arch={cfg.name} split={cfg.split_layer}/{cfg.n_layers} "
          f"lambda_hat={eng.lam:.2f} path={args.path}")

    qos = QosClass("interactive", t0=args.t0, e0=args.e0)
    sol = eng.auto_configure(qos)
    if sol is None:
        print(f"(P1) infeasible under T0={args.t0}s E0={args.e0}J")
        return 1
    print(f"codesign: b_hat={sol.b_hat} f={sol.f / 1e9:.2f}GHz "
          f"f~={sol.f_server / 1e9:.2f}GHz gap={sol.objective:.3e} "
          f"T={sol.delay:.3f}s E={sol.energy:.3f}J "
          f"(SCA iters={sol.iterations})")

    for name, solver in (("oracle", cd.solve_oracle),
                         ("fixed-freq", bl.solve_fixed_frequency),
                         ("ppo", bl.solve_ppo)):
        s = solver(eng.lam, sysp, args.t0, args.e0)
        print(f"  {name:11s}: " + (
            f"b_hat={s.b_hat} gap={s.objective:.3e}" if s else "infeasible"))

    ds = MarkovLMDataset(MarkovLMConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        batch_size=args.batch))
    batch = {"tokens": jnp.asarray(ds.batch_at(0)["tokens"])}
    logits, stats = eng.serve_batch(batch)
    print(f"served batch {batch['tokens'].shape}: logits {logits.shape}")
    print(f"  agent {stats.agent_delay_s * 1e3:.2f}ms + uplink "
          f"{stats.transport_delay_s * 1e3:.2f}ms + server "
          f"{stats.server_delay_s * 1e3:.2f}ms = "
          f"{stats.total_delay_s * 1e3:.2f}ms, {stats.energy_j:.3f}J, "
          f"emb {stats.emb_bytes / 1024:.1f}KiB at b_emb={eng.b_emb}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
