"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the locally available devices (host mesh) with the full
production stack: sharded params, QAT on the agent partition, checkpointing,
optional int8-EF gradient compression.  The same Trainer lowers on the
512-chip production mesh in dryrun.py — this entry point is the
"actually execute" half.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from ..configs import get_config, get_smoke
from ..data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from ..checkpoint import CheckpointManager
from ..models.registry import build_model
from ..optim import AdamW, cosine_schedule
from ..runtime import TrainConfig, Trainer
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--qat-bits", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=0,
                    help="data-parallel degree (0 = all devices)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=args.data or n_dev, model=1)

    ds = MarkovLMDataset(MarkovLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch))
    loader = ShardedLoader(ds)

    ckpt = CheckpointManager(args.ckpt_dir, save_interval=args.ckpt_every) \
        if args.ckpt_dir else None
    opt = AdamW(learning_rate=cosine_schedule(args.lr, 20, args.steps))
    tr = Trainer(model, opt, mesh,
                 TrainConfig(qat_bits=args.qat_bits,
                             grad_compression=args.grad_compression,
                             log_every=10),
                 ckpt=ckpt)
    print(f"arch={cfg.name} params={cfg.param_count():.3g} "
          f"devices={n_dev} qat_bits={args.qat_bits}")
    _, history = tr.fit(loader, args.steps,
                        on_metrics=lambda m: print(
                            f"step {m['step']:5d} loss {m['loss']:.4f} "
                            f"gnorm {m['grad_norm']:.3f} "
                            f"{m['steps_per_s']:.2f} it/s"))
    if history:
        first, last = history[0]["loss"], history[-1]["loss"]
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
