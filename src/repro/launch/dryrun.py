import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on the production mesh and extract the roofline inputs.

MUST be run as its own process (the device-count flag above is locked at
first jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun

Per cell it jits the *real* step (full train step with optimizer update, or
prefill / one-token decode against the full-size KV cache), lowers with
ShapeDtypeStruct inputs (no allocation), compiles under GSPMD, and records:

  memory_analysis        — per-device argument/output/temp/peak bytes
  cost_analysis          — XLA's flops/bytes counters (loop bodies counted
                           once — see hloparse docstring)
  hloparse.analyze       — loop-aware per-device FLOPs / HBM bytes /
                           collective bytes from the post-SPMD HLO text

Variants (--variant) are the §Perf hillclimb levers:
  baseline      bf16 params/compute, paper-faithful execution
  seqshard      + Megatron-style sequence-parallel activations
  int8w         int8-resident weights (serving cells; the paper's knob)
  int8w+seqshard, gradcomp (int8 EF cross-pod gradients; train, multipod)
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs import ALL_SHAPES, ARCH_IDS, cell_applicable, get_config
from ..configs.base import ModelConfig, ShapeSpec
from ..core.quantization import QuantConfig, quantize_tree_stacked
from ..models.registry import build_model
from .mesh import set_mesh
from ..optim import AdamW, AdamWState
from ..parallel.sharding import (activation_sharding, batch_shardings,
                                 default_rules, replicated, tree_shardings)
from .mesh import make_production_mesh

#: archs large enough that the residual stream must be sequence-sharded
#: between blocks for activations (saved-for-backward) to fit HBM
BIG_ARCHS = ("granite-34b", "internlm2-20b", "kimi-k2-1t-a32b",
             "qwen3-moe-235b-a22b", "jamba-1.5-large-398b",
             "llava-next-mistral-7b")


def _to_bf16(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, dtype="bfloat16",
                               param_dtype="bfloat16")


def _cell_fn_and_args(model, cfg: ModelConfig, shape: ShapeSpec,
                      variant: str, mesh, rules):
    """Build (fn, arg_structs, in_shardings, donate) for one cell."""
    axes = model.logical_axes()
    p_structs = model.param_structs()
    p_sh = tree_shardings(axes, p_structs, rules, mesh)
    in_specs = model.input_specs(shape)
    b_sh = batch_shardings(in_specs, rules, mesh)
    int8w = "int8w" in variant

    if int8w:
        # int8-resident weights: the serving-side realization of the paper's
        # bit-width knob (QuantizedTensor leaves dequantize on read)
        qcfg = QuantConfig(bits=8, granularity="per-channel")
        qp_structs = jax.eval_shape(
            lambda t: quantize_tree_stacked(t, qcfg), p_structs)
        qp_sh = _shard_quantized(p_sh, p_structs, qp_structs, mesh)
        p_structs, p_sh = qp_structs, qp_sh

    if shape.kind == "train":
        opt = AdamW(learning_rate=1e-4)
        o_structs = jax.eval_shape(opt.init, p_structs) if not int8w else None

        if "gradcomp" in variant and "pod" in mesh.axis_names:
            # explicit pod axis: per-pod grads -> int8 EF -> all-gather(int8)
            from jax.sharding import PartitionSpec as P
            from ..optim import compress_tree

            def train_step(params, opt_state, batch):
                def per_pod(params, opt_state, batch):
                    loss, grads = jax.value_and_grad(model.loss)(params,
                                                                 batch)
                    err = jax.tree_util.tree_map(
                        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
                    grads, _ = compress_tree(grads, err, axis_name="pod")
                    params, opt_state, _ = opt.update(grads, opt_state,
                                                      params)
                    return params, opt_state, jax.lax.pmean(loss, "pod")
                return jax.shard_map(
                    per_pod, mesh=mesh,
                    in_specs=(P(), P(), P("pod")),
                    out_specs=(P(), P(), P()),
                    axis_names={"pod"}, check_vma=False)(
                        params, opt_state, batch)
        else:
            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                params, opt_state, metrics = opt.update(grads, opt_state,
                                                        params)
                return params, opt_state, loss

        o_sh = AdamWState(step=replicated(mesh),
                          m=jax.tree_util.tree_map(lambda s: s, p_sh),
                          v=jax.tree_util.tree_map(lambda s: s, p_sh))
        fn = train_step
        args = (p_structs, o_structs, in_specs)
        shardings = (p_sh, o_sh, b_sh)
        donate = (0, 1)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        fn = prefill_step
        args = (p_structs, in_specs)
        shardings = (p_sh, b_sh)
        donate = ()
    else:  # decode
        c_structs = model.cache_specs(shape)
        c_axes = model.cache_axes()
        c_sh = tree_shardings(c_axes, c_structs, rules, mesh)

        def decode_step(params, cache, batch):
            return model.decode_step(params, cache, batch)
        fn = decode_step
        args = (p_structs, c_structs, in_specs)
        shardings = (p_sh, c_sh, b_sh)
        donate = (1,)
    return fn, args, shardings, donate


def _shard_quantized(p_sh, p_structs, qp_structs, mesh):
    """Shardings for the quantized tree: codes inherit the float leaf's
    sharding, scales replicate (tiny), non-quantized leaves keep theirs.

    The quantized tree is structurally the float tree with some leaves
    replaced by QuantizedTensor nodes, so a structural map against the
    original sharding tree pairs every leaf exactly."""
    del p_structs
    from ..core.quantization import QuantizedTensor

    def one(qt, sh):
        if isinstance(qt, QuantizedTensor):
            return QuantizedTensor(codes=sh, scale=replicated(mesh),
                                   bits=qt.bits, scheme=qt.scheme)
        return sh

    return jax.tree_util.tree_map(
        one, qp_structs, p_sh,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def run_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool,
             variant: str = "baseline") -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline record."""
    from . import hloparse

    cfg = _to_bf16(get_config(arch))
    ok, reason = cell_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    t0 = time.monotonic()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        long_ctx = shape.name == "long_500k"
        rules = default_rules(cfg, long_context=long_ctx)
        if "cacheshard" in variant:
            # flash-decoding style: KV cache sharded along the sequence
            # axis over the TP group (partial-softmax combine under GSPMD)
            rules["cache_seq"] = "model"
        if "notp" in variant:
            # small models: tensor parallelism wastes the 16-way model axis
            # on per-layer activation all-gathers; replicate weights and
            # give the model axis to the sequence instead (+seqshard)
            for k in ("heads", "kv", "kv_heads", "ffn", "vocab"):
                rules[k] = None
        model = build_model(cfg)
        fn, args, shardings, donate = _cell_fn_and_args(
            model, cfg, shape, variant, mesh, rules)

        seq_spec = None
        if "seqshard" in variant or "notp" in variant or (
                arch in BIG_ARCHS and shape.kind == "train"
                and "noseqshard" not in variant):
            # under gradcomp the pod axis is manual inside the shard_map,
            # so the activation constraint may only name the auto axes
            batch_axes = ("pod", "data") \
                if (multi_pod and "gradcomp" not in variant) else ("data",)
            seq_spec = P(batch_axes if len(batch_axes) > 1
                         else batch_axes[0], "model")

        from ..parallel.sharding import flash_attention_mode
        flash_ctx = flash_attention_mode(
            mesh if "flash" in variant else None)
        with set_mesh(mesh):
            with activation_sharding(seq_spec), flash_ctx:
                jitted = jax.jit(fn, in_shardings=shardings,
                                 donate_argnums=donate)
                lowered = jitted.lower(*args)
            compiled = lowered.compile()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        costs = hloparse.analyze(hlo_text)

        rec.update(
            status="ok",
            compile_s=round(time.monotonic() - t0, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
            },
            cost_analysis={
                "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
                "bytes_accessed": float(cost.get("bytes accessed", 0.0))
                if cost else 0.0,
            },
            hlo={
                "flops_per_device": costs.flops,
                "hbm_bytes_per_device": costs.hbm_bytes,
                "collective_bytes_per_device": costs.collective_bytes,
                "collective_breakdown": costs.collective_breakdown,
                "n_while": costs.n_while,
                "trip_counts": costs.trip_counts[:32],
            },
            model_stats={
                "params": cfg.param_count(),
                "active_params": cfg.active_param_count(),
                "tokens": shape.global_batch * (
                    shape.seq_len if shape.kind != "decode" else 1),
                "kind": shape.kind,
            },
        )
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.monotonic() - t0, 1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' or comma list")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(ALL_SHAPES) if args.shape == "all" else [
        s for s in ALL_SHAPES if s.name in args.shape.split(",")]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               variant=args.variant)
                results.append(rec)
                tag = f"{arch}|{shape.name}|{rec['mesh']}|{args.variant}"
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mb = rec["memory"]["argument_bytes"] / 2 ** 30
                    extra = (f" args={mb:.2f}GiB "
                             f"flops/dev={rec['hlo']['flops_per_device']:.3g}"
                             f" coll/dev="
                             f"{rec['hlo']['collective_bytes_per_device']:.3g}"
                             f" ({rec['compile_s']}s)")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{status:5s}] {tag}{extra}", flush=True)
                fname = (f"{arch}_{shape.name}_{rec['mesh'].replace('x','-')}"
                         f"_{args.variant}.json")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"of {len(results)} cells")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
