"""Post-SPMD HLO accounting for the roofline analysis.

XLA's ``compiled.cost_analysis()`` does NOT multiply while-loop bodies by
their trip count (verified empirically — a scan over 4 vs 8 layers reports
identical flops), so scan-over-layers models would be undercounted by ~L.
This module re-derives the three roofline inputs directly from the
scheduled post-partitioning HLO text:

  * flops            — 2 * prod(result_dims) * prod(contracting_dims) per
                       ``dot``, walked through the call graph with while
                       trip-count multipliers (fusion/call/cond too).  Trip
                       counts come from the ``known_trip_count`` backend
                       config XLA attaches to compiled while ops (fallback:
                       largest constant in the condition computation).
  * hbm bytes        — per top-level instruction: operand + result bytes at
                       *fusion boundaries* (post-fusion HLO means fusion
                       internals stay on-chip, which is the right HBM-traffic
                       model).  dynamic-slice counts its *result* bytes and
                       dynamic-update-slice its *update* bytes — the scan
                       path slices per-layer weights out of stacked buffers
                       every iteration and must not be billed the full stack.
  * collective bytes — result bytes for all-gather / all-to-all /
                       collective-permute, operand bytes for all-reduce /
                       reduce-scatter, again with loop multipliers.

Everything is *per device* (the HLO is the per-partition program), matching
the per-chip roofline denominators.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (tuples sum their elements)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]

    def type_map(self) -> Dict[str, str]:
        """instruction name -> result type (operands are referenced by name
        in scheduled HLO, so byte/flop accounting resolves through this)."""
        return {i.name: i.result_type for i in self.instructions}


# Header: "%name (args...) -> type {"  — args may contain nested parens
# (tuple-typed params), so only anchor on name, "(", "->" and trailing "{".
_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
# Instruction: "%name = <type> opcode(..." where <type> is either a tuple
# "(...)" (no internal parens in HLO types) or "dtype[dims]{layout}".
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")

_TRIP_RE = re.compile(r'known_trip_count[^0-9]+(\d+)')


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None or ("->" in line and stripped.endswith("{")
                               and "=" not in line.split("->")[0]):
            hdr = _COMP_HDR.match(line)
            if hdr:
                current = Computation(hdr.group(1), [])
                comps[current.name] = current
                continue
        if current is None:
            continue
        if stripped == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if m:
            current.instructions.append(Instruction(
                name=m.group(1), result_type=m.group(2),
                opcode=m.group(3), raw=stripped))
    return comps


def _called_comps(instr: Instruction) -> List[Tuple[str, str]]:
    """(role, computation_name) pairs referenced by this instruction."""
    out = []
    for role in ("body", "condition", "calls", "to_apply",
                 "branch_computations", "true_computation",
                 "false_computation"):
        for m in re.finditer(role + r"=\{?%?([\w\.\-, %]+)\}?", instr.raw):
            for name in re.split(r"[,\s%]+", m.group(1)):
                if name:
                    out.append((role, name))
    return out


def _trip_count(instr: Instruction,
                comps: Dict[str, Computation]) -> int:
    """Trip count of a while: backend_config known_trip_count, else the
    largest integer constant in the condition computation, else 1."""
    m = _TRIP_RE.search(instr.raw)
    if m:
        return int(m.group(1))
    called = dict()
    for role, name in _called_comps(instr):
        called.setdefault(role, name)
    cond = comps.get(called.get("condition", ""))
    best = 1
    if cond is not None:
        for ins in cond.instructions:
            if ins.opcode == "constant":
                cm = re.search(r"constant\((\d+)\)", ins.raw)
                if cm:
                    best = max(best, int(cm.group(1)))
    return best


_COLLECTIVES_RESULT = ("all-gather", "all-to-all", "collective-permute")
_COLLECTIVES_OPERAND = ("all-reduce", "reduce-scatter")


def _operand_names(instr: Instruction) -> List[str]:
    """Operand instruction names (scheduled HLO references by %name)."""
    m = re.search(re.escape(instr.opcode) + r"\((.*)", instr.raw)
    if not m:
        return []
    args = m.group(1)
    # stop at metadata / backend_config / annotation clauses
    args = re.split(r"(?:, )?(?:metadata=|backend_config=|sharding=|"
                    r"calls=|to_apply=|condition=|body=|"
                    r"lhs_contracting_dims=|dimensions=|"
                    r"dynamic_slice_sizes=)", args)[0]
    return re.findall(r"%([\w\.\-]+)", args)


def _operand_types(instr: Instruction, types: Dict[str, str]) -> List[str]:
    return [types[n] for n in _operand_names(instr) if n in types]


def _dot_flops(instr: Instruction, types: Dict[str, str]) -> float:
    dims = _shape_dims(instr.result_type)
    out = 1.0
    for d in dims:
        out *= d
    names = _operand_names(instr)
    lhs_type = types.get(names[0]) if names else None
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    contract = 1.0
    if lhs_type and cm and cm.group(1):
        lhs_dims = _shape_dims(lhs_type)
        for i in cm.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out * contract


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)
    n_dots: int = 0


_SKIP_BYTES = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call")


def _fusion_bytes(instr: Instruction, types: Dict[str, str],
                  comps: Dict[str, "Computation"]) -> float:
    """Effective HBM bytes of one fusion, looking inside its computation.

    Two in-place/slicing patterns would otherwise be billed the full buffer
    per loop iteration (catastrophically wrong for scan models):
      * root is a dynamic-update-slice (loop-carried KV-cache / saved-
        activation stack writes) -> bill 2x the update size, not the stack;
      * a parameter only consumed by dynamic-slice (per-layer weight /
        cache reads out of the stacked buffer) -> bill the slice sizes.
    Everything else: full operand + result bytes (the fusion boundary is an
    HBM round-trip).
    """
    fc = None
    for _, name in _called_comps(instr):
        if name in comps:
            fc = comps[name]
            break
    if fc is None:
        b = _shape_bytes(instr.result_type)
        for t in _operand_types(instr, types):
            b += _shape_bytes(t)
        return b

    ftypes = fc.type_map()
    # map fusion parameter number -> parameter instruction name
    param_names: Dict[int, str] = {}
    for ins in fc.instructions:
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.raw)
            if m:
                param_names[int(m.group(1))] = ins.name
    # consumer map: name -> list of consuming instructions
    consumers: Dict[str, List[Instruction]] = {}
    for ins in fc.instructions:
        for op_name in _operand_names(ins):
            consumers.setdefault(op_name, []).append(ins)

    def effective_read(pname: str, full: int) -> float:
        cons = consumers.get(pname, [])
        if not cons:
            return 0.0
        # follow through bitcasts/converts of the parameter
        sliced = 0.0
        for c in cons:
            if c.opcode == "dynamic-slice":
                sliced += _shape_bytes(c.result_type)
            elif c.opcode == "dynamic-update-slice" and \
                    _operand_names(c)[:1] == [pname]:
                # in-place destination of a DUS: the buffer is written
                # through, not read (the update itself is billed at the root)
                sliced += 0.0
            elif c.opcode in ("bitcast", "copy", "convert"):
                sliced += effective_read(c.name, full)
            else:
                return float(full)
        return min(sliced, float(full))

    total = 0.0
    op_types = _operand_types(instr, types)
    for i, t in enumerate(op_types):
        pname = param_names.get(i)
        full = _shape_bytes(t)
        total += effective_read(pname, full) if pname else full

    # result: in-place DUS roots bill update size only
    root = fc.instructions[-1] if fc.instructions else None
    def _root_dus(ins) -> Optional[Instruction]:
        if ins is None:
            return None
        if ins.opcode == "dynamic-update-slice":
            return ins
        if ins.opcode in ("bitcast", "copy", "convert", "tuple"):
            for op_name in _operand_names(ins):
                hit = _root_dus(next((x for x in fc.instructions
                                      if x.name == op_name), None))
                if hit is not None:
                    return hit
        return None

    dus = _root_dus(root)
    if dus is not None:
        ops_t = _operand_types(dus, ftypes)
        upd = _shape_bytes(ops_t[1]) if len(ops_t) > 1 else 0
        total += 2 * upd
    else:
        total += _shape_bytes(instr.result_type)
    return total


def analyze(text: str, entry: Optional[str] = None) -> HloCosts:
    comps = parse_hlo(text)
    if not comps:
        return HloCosts()
    entry_m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    entry = entry or (entry_m.group(1) if entry_m else next(iter(comps)))
    costs = HloCosts()

    seen_stack: List[str] = []

    def walk(comp_name: str, mult: float, *, in_fusion: bool):
        if comp_name not in comps or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        comp = comps[comp_name]
        types = comp.type_map()
        for ins in comp.instructions:
            op = ins.opcode
            if op == "dot":
                costs.flops += mult * _dot_flops(ins, types)
                costs.n_dots += 1
            # ---- HBM bytes at fusion boundaries ----
            if not in_fusion and op not in _SKIP_BYTES:
                if op == "dynamic-slice":
                    b = 2 * _shape_bytes(ins.result_type)
                elif op == "dynamic-update-slice":
                    ops_t = _operand_types(ins, types)
                    upd = _shape_bytes(ops_t[1]) if len(ops_t) > 1 else \
                        _shape_bytes(ins.result_type)
                    b = 2 * upd
                elif op == "fusion":
                    b = _fusion_bytes(ins, types, comps)
                else:
                    b = _shape_bytes(ins.result_type)
                    for t in _operand_types(ins, types):
                        b += _shape_bytes(t)
                costs.hbm_bytes += mult * b
            # ---- collectives ----
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES_RESULT:
                if op.endswith("-done"):
                    pass  # counted at -start
                else:
                    b = mult * _shape_bytes(ins.result_type)
                    costs.collective_bytes += b
                    costs.collective_breakdown[base] = \
                        costs.collective_breakdown.get(base, 0.0) + b
            elif base in _COLLECTIVES_OPERAND:
                if not op.endswith("-done"):
                    ops_t = _operand_types(ins, types)
                    b = mult * (sum(_shape_bytes(t) for t in ops_t)
                                or _shape_bytes(ins.result_type))
                    costs.collective_bytes += b
                    costs.collective_breakdown[base] = \
                        costs.collective_breakdown.get(base, 0.0) + b
            # ---- recursion ----
            if op == "while":
                trip = _trip_count(ins, comps)
                costs.n_while += 1
                costs.trip_counts.append(trip)
                for role, name in _called_comps(ins):
                    if role == "body":
                        walk(name, mult * trip, in_fusion=in_fusion)
            elif op == "fusion":
                for _, name in _called_comps(ins):
                    walk(name, mult, in_fusion=True)
            elif op in ("call", "conditional", "custom-call", "map",
                        "reduce", "reduce-window", "sort", "scatter",
                        "select-and-scatter", "async-start"):
                for _, name in _called_comps(ins):
                    walk(name, mult, in_fusion=True)
        seen_stack.pop()

    walk(entry, 1.0, in_fusion=False)
    return costs
