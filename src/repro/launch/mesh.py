"""Production mesh definitions (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis carries pure
data parallelism (gradient all-reduce, optionally int8-compressed).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist in newer releases; older ones
    default to Auto axes anyway, so omit the kwarg when absent."""
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across jax versions: new releases take
    ``(shape, axis_names)``, old ones a tuple of ``(name, size)`` pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on new jax,
    the classic ``with mesh:`` (Mesh.__enter__) on versions without it."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return compat_make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline targets; assignment §ROOFLINE)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip, one direction)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB HBM per v5e chip
