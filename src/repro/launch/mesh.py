"""Production mesh definitions (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis carries pure
data parallelism (gradient all-reduce, optionally int8-compressed).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants (roofline targets; assignment §ROOFLINE)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip, one direction)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB HBM per v5e chip
