"""Roofline analysis over dry-run records (assignment §ROOFLINE ANALYSIS).

Reads the JSON records ``dryrun.py`` wrote and derives, per cell:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
  memory term     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
  collective term = collective_bytes_per_device / link_bw       (50e9 B/s)

(The parsed HLO is the per-partition program, so the per-chip denominators
apply directly — dividing whole-program totals by the chip count is the
same thing.)

Plus: MODEL_FLOPS (6·N·D train / 2·N_active·B decode+prefill), the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and the
roofline fraction = ideal_time / max(term) where ideal_time is the
MODEL_FLOPS compute bound.  Emits a markdown table for EXPERIMENTS.md.

Usage:
    python -m repro.launch.roofline --in results/dryrun --md
    python -m repro.launch.roofline --in results/dryrun --compare baseline \
        int8w   # hillclimb before/after deltas
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops_per_device(rec: Dict[str, Any]) -> float:
    """MODEL_FLOPS (useful flops) per device for this cell."""
    ms = rec["model_stats"]
    chips = CHIPS[rec["mesh"]]
    n_active = ms["active_params"]
    tokens = ms["tokens"]
    if ms["kind"] == "train":
        total = 6.0 * n_active * tokens        # fwd 2ND + bwd 4ND
    else:                                       # prefill or one decode step
        total = 2.0 * n_active * tokens
    return total / chips


def _attention_flops_per_device(rec: Dict[str, Any]) -> float:
    """Analytic self-attention matmul FLOPs for the 'flash' variants (the
    fused kernel's dots live inside a custom-call, invisible to the HLO dot
    census): 2 matmuls x 2BS^2·H·dh x 1/2 (causal) per attention layer;
    x3.5 for train (bwd dq/dk/dv + in-kernel recompute)."""
    from ..configs import get_config
    cfg = get_config(rec["arch"])
    ms = rec["model_stats"]
    chips = CHIPS[rec["mesh"]]
    # shape cell geometry
    from ..configs.base import ALL_SHAPES
    shape = next(s for s in ALL_SHAPES if s.name == rec["shape"])
    if shape.kind == "decode":
        return 0.0  # decode path never uses the fused prefill kernel
    per = getattr(cfg, "attn_period", 0)
    n_attn = (cfg.n_layers // per) if (cfg.family == "hybrid" and per) \
        else (0 if cfg.family == "ssm" else cfg.n_layers + cfg.n_enc_layers)
    b, s = shape.global_batch, shape.seq_len
    if cfg.n_enc_layers:
        s = s // 2  # enc-dec splits the budget (encdec.input_specs)
    fwd = n_attn * 2.0 * 2.0 * b * s * s * cfg.q_dim * 0.5
    mult = 3.5 if shape.kind == "train" else 1.0
    return fwd * mult / chips


def roofline_terms(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    hlo = dict(rec["hlo"])
    if "flash" in rec.get("variant", ""):
        hlo["flops_per_device"] = hlo["flops_per_device"] \
            + _attention_flops_per_device(rec)
    compute_s = hlo["flops_per_device"] / PEAK_FLOPS_BF16
    memory_s = hlo["hbm_bytes_per_device"] / HBM_BW
    collective_s = hlo["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ideal_s = mf / PEAK_FLOPS_BF16
    bound_s = max(terms.values())
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_dev": mf,
        "useful_ratio": mf / max(hlo["flops_per_device"], 1e-30),
        "ideal_s": ideal_s,
        "bound_s": bound_s,
        "roofline_fraction": ideal_s / max(bound_s, 1e-30),
        "collective_breakdown": hlo.get("collective_breakdown", {}),
        "memory_analysis": rec.get("memory", {}),
    }
    return out


def load_records(directory: str, variant: Optional[str] = None
                 ) -> List[Dict[str, Any]]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if variant and rec.get("variant") != variant:
            continue
        recs.append(rec)
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    if x >= 1e-6:
        return f"{x * 1e6:.1f}us"
    return f"{x * 1e9:.1f}ns"


def markdown_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | variant | compute | memory | collective "
           "| dominant | useful | roofline |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['variant']} "
            f"| {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.1%} |")
    return "\n".join(lines)


def compare_table(base: List[Dict[str, Any]], new: List[Dict[str, Any]]
                  ) -> str:
    """Before/after on the dominant term for matching cells."""
    key = lambda r: (r["arch"], r["shape"], r["mesh"])  # noqa: E731
    base_by = {key(r): r for r in base}
    lines = ["| cell | dominant (before) | before | after | delta |",
             "|---|---|---|---|---|"]
    for r in new:
        b = base_by.get(key(r))
        if b is None:
            continue
        dom = b["dominant"]
        before = b[f"{dom}_s"]
        after = r[f"{dom}_s"]
        delta = (after - before) / max(before, 1e-30)
        lines.append(
            f"| {r['arch']}/{r['shape']}/{r['mesh']} | {dom} "
            f"| {_fmt_s(before)} | {_fmt_s(after)} | {delta:+.1%} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="indir", default="results/dryrun")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "NEW"),
                    default=None)
    ap.add_argument("--md", action="store_true", help="markdown output")
    ap.add_argument("--out", default=None, help="write table to file")
    args = ap.parse_args(argv)

    if args.compare:
        base = [t for r in load_records(args.indir, args.compare[0])
                if (t := roofline_terms(r))]
        new = [t for r in load_records(args.indir, args.compare[1])
               if (t := roofline_terms(r))]
        table = compare_table(base, new)
    else:
        rows = [t for r in load_records(args.indir, args.variant)
                if (t := roofline_terms(r))]
        rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
        table = markdown_table(rows)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
