"""Error-feedback int8 gradient compression (beyond-paper distributed trick).

The paper's thesis — bit-width as a first-class resource knob — applies to
the *gradient* traffic of data-parallel training just as it does to serving
weights.  This wrapper quantizes gradients to int8 (per-leaf absmax scaling)
before the cross-pod all-reduce and keeps the quantization residual locally
("error feedback", Seide et al. 2014 / Karimireddy et al. 2019), which
provably preserves convergence for smooth objectives.

Used by the training loop when ``grad_compression='int8_ef'``: the pod-axis
all-reduce then moves 4x fewer bytes (bf16->int8 halves, f32->int8 quarters),
directly shrinking the collective roofline term of the multi-pod mesh.

Implementation note: the cross-pod reduction is an int8 all-gather + local
weighted sum (per-pod scales gathered alongside) inside ``shard_map`` over
the 'pod' axis — 1 wire byte per element, and each pod's codes are weighted
by its *own* scale (an int32 psum with averaged scales would be both 4x the
bytes and wrong for heterogeneous scales).  Without an axis name it degrades
to pure quantize+dequantize with error feedback (single-pod tests cover the
numerics).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array,
                        axis_name: Optional[str] = None):
    """Quantize (g + err) to int8, (optionally) all-reduce in low precision,
    dequantize; returns (g_hat, new_err)."""
    gf = g.astype(jnp.float32) + err
    q, scale = _quantize_leaf(gf)
    if axis_name is not None:
        qs = jax.lax.all_gather(q, axis_name)            # [P, ...] int8
        ss = jax.lax.all_gather(scale, axis_name)        # [P]
        n = qs.shape[0]
        g_hat = jnp.tensordot(ss, qs.astype(jnp.float32),
                              axes=(0, 0)) / n           # mean of pod grads
    else:
        g_hat = q.astype(jnp.float32) * scale
    new_err = gf - (q.astype(jnp.float32) * scale)
    return g_hat.astype(g.dtype), new_err


def compress_tree(grads, err_state, axis_name: Optional[str] = None):
    """Apply error-feedback compression to every leaf; returns
    (compressed_grads, new_err_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [compress_decompress(g, e, axis_name)
           for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_ratio(dtype=jnp.float32) -> float:
    """Wire-byte reduction vs uncompressed all-reduce."""
    return jnp.dtype(dtype).itemsize / 1.0  # int8 = 1 byte
