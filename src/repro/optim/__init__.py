"""From-scratch optimizers + schedules + gradient compression."""

from .adamw import AdamW, AdamWState, cosine_schedule, global_norm, linear_schedule  # noqa: F401
from .grad_compress import compress_tree, init_error_state  # noqa: F401
