"""AdamW + schedules + global-norm clipping (from scratch; no optax here).

State is a pytree mirroring params (m, v) + a scalar step count, so the
sharding rules that apply to params apply verbatim to the optimizer state —
which is what makes the FSDP configs ZeRO-like: m/v shard with the weights.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree_util.tree_map(jnp.copy, zeros))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.float32(self.learning_rate)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12)) \
            if self.clip_norm > 0 else jnp.float32(1.0)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p_new, m_new, v_new

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.float32(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return fn


def linear_schedule(peak_lr: float, warmup_steps: int, total_steps: int):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, peak_lr * (1 - prog))
    return fn
