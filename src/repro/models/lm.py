"""Decoder-only language model (dense / MoE / VLM / audio-decoder families).

One scan-over-layers transformer whose per-layer block is configured by the
``ModelConfig``.  Provides the full protocol the framework needs:

  init / logical_axes / param_structs      (params + sharding metadata)
  loss / forward                           (training)
  prefill / init_cache / decode_step       (serving)
  run_layers                               (co-inference split execution)
  input_specs                              (dry-run ShapeDtypeStruct stand-ins)

Multimodal stubs: for ``frontend != none`` the input dict carries precomputed
``embeds`` [B, S_vis, D] (the assignment mandates the modality frontend be a
stub) which are concatenated before the token embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..kernels.decode_attn import quantized_decode_attention
from ..kernels.quantize import kv_quantize
from ..parallel.sharding import constrain_activations
from . import layers as L
from . import moe as M


def _split_tree(tree, lo, hi):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


class DecoderLM:
    """Config-driven decoder-only LM."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._axes = None
        # scan requires layer homogeneity: all layers MoE or all dense
        if cfg.n_experts and cfg.moe_every != 1:
            raise ValueError("DecoderLM supports moe_every=1; interleaved "
                             "MoE belongs to the hybrid model")

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _build(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        emb_p, emb_ax = L.init_embeddings(cfg, ks[0])
        attn_p, attn_ax = L.init_attention(cfg, ks[1], layers=cfg.n_layers)
        ln1_p, ln1_ax = L.init_norm(cfg, cfg.d_model)
        ln2_p, ln2_ax = L.init_norm(cfg, cfg.d_model)
        lnf_p, lnf_ax = L.init_norm(cfg, cfg.d_model)

        def stack_norm(p, ax):
            sp = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
                p)
            sax = jax.tree_util.tree_map(
                lambda t: ("layers",) + t, ax,
                is_leaf=lambda x: isinstance(x, tuple))
            return sp, sax

        ln1_p, ln1_ax = stack_norm(ln1_p, ln1_ax)
        ln2_p, ln2_ax = stack_norm(ln2_p, ln2_ax)

        if cfg.n_experts:
            ffn_p, ffn_ax = M.init_moe(cfg, ks[2], layers=cfg.n_layers)
        else:
            ffn_p, ffn_ax = L.init_mlp(cfg, ks[2], layers=cfg.n_layers)

        params = {"embed": emb_p,
                  "layers": {"attn": attn_p, "ffn": ffn_p,
                             "ln1": ln1_p, "ln2": ln2_p},
                  "final_norm": lnf_p}
        axes = {"embed": emb_ax,
                "layers": {"attn": attn_ax, "ffn": ffn_ax,
                           "ln1": ln1_ax, "ln2": ln2_ax},
                "final_norm": lnf_ax}
        self._axes = axes
        return params

    def init(self, rng):
        return self._build(rng)

    def logical_axes(self):
        if self._axes is None:
            jax.eval_shape(self._build, jax.random.PRNGKey(0))
        return self._axes

    def param_structs(self):
        return jax.eval_shape(self._build, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    # blocks
    # ------------------------------------------------------------------
    def _block(self, lp, x, positions, *, blockwise=True):
        cfg = self.cfg
        h = L.apply_norm(cfg, x, lp["ln1"])
        q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
        if blockwise:
            attn = L.blockwise_attention(
                q, k, v, causal=True, window=cfg.sliding_window)
        else:  # tiny sequences: direct path (used by smoke tests)
            attn = L.blockwise_attention(
                q, k, v, causal=True, window=cfg.sliding_window,
                q_block=max(16, q.shape[1]), kv_block=max(16, k.shape[1]))
        x = x + attn.reshape(x.shape[:2] + (cfg.q_dim,)) \
            @ lp["attn"]["wo"].astype(x.dtype)
        h2 = L.apply_norm(cfg, x, lp["ln2"])
        if cfg.n_experts:
            y, aux = M.apply_moe(cfg, lp["ffn"], h2)
        else:
            y, aux = L.apply_mlp(cfg, lp["ffn"], h2), jnp.float32(0.0)
        return x + y, aux

    def _run_stack(self, layer_params, x, positions,
                   remat_block: Optional[int] = None):
        cfg = self.cfg
        n = jax.tree_util.tree_leaves(layer_params)[0].shape[0]
        remat_block = cfg.remat_block if remat_block is None else remat_block

        def one(carry, lp):
            x, aux = carry
            x = constrain_activations(x)
            x, a = self._block(lp, x, positions)
            return (x, aux + a), None

        if remat_block > 1 and n % remat_block == 0 and n > remat_block:
            # two-level remat: checkpoint wraps the INNER scan so backward
            # stores only n/remat_block outer carries and recomputes each
            # block — peak activation memory O(n/k + k) instead of O(n).
            nb = n // remat_block
            blk = jax.tree_util.tree_map(
                lambda a: a.reshape((nb, remat_block) + a.shape[1:]),
                layer_params)

            def outer(carry, bp):
                c, _ = jax.lax.scan(one, carry, bp)
                return c, None

            outer = jax.checkpoint(outer)
            (x, aux), _ = jax.lax.scan(outer, (x, jnp.float32(0.0)), blk)
        elif cfg.scan_layers:
            one = jax.checkpoint(one)
            (x, aux), _ = jax.lax.scan(one, (x, jnp.float32(0.0)),
                                       layer_params)
        else:
            aux = jnp.float32(0.0)
            for i in range(n):
                lp = jax.tree_util.tree_map(lambda a: a[i], layer_params)
                x, a = self._block(lp, x, positions)
                aux = aux + a
        return x, aux

    def run_layers(self, params, x, positions, lo: int, hi: int):
        """Co-inference split execution: layers [lo, hi) on activations x."""
        sub = _split_tree(params["layers"], lo, hi)
        return self._run_stack(sub, x, positions, remat_block=0)

    def run_layers_window(self, params, x, positions, lo, hi):
        """Split execution with *runtime* bounds: layers [lo, hi) applied
        through a ``lax.while_loop`` whose trip count XLA cannot see.

        Pass ``lo``/``hi`` as int32 *arrays* (concrete in eager mode,
        traced arguments inside a jit): the loop body then compiles to
        one isolated XLA sub-computation regardless of window size, so
        its bits are identical whether the window runs eagerly or inlined
        in a larger jitted graph — a static-length scan would be unrolled
        and re-fused at short trip counts.  This bit-stability is what
        the compiled serving fast path's bitwise-identity invariant
        builds on (DESIGN.md §10).  Forward-only (no aux, no remat); the
        training path keeps :meth:`_run_stack`'s scan.
        """
        lp = params["layers"]
        lo = jnp.asarray(lo, jnp.int32)
        hi = jnp.asarray(hi, jnp.int32)

        def cond(carry):
            return carry[0] < hi

        def body(carry):
            i, x = carry
            sl = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False), lp)
            x = constrain_activations(x)
            x, _ = self._block(sl, x, positions)
            return (i + 1, x)

        _, x = jax.lax.while_loop(cond, body, (lo, x))
        return x, jnp.float32(0.0)

    # ------------------------------------------------------------------
    # embedding plumbing (handles the multimodal stub)
    # ------------------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        parts = []
        if "embeds" in batch:
            parts.append(batch["embeds"].astype(dtype))
        if "tokens" in batch:
            parts.append(L.embed_tokens(params["embed"], batch["tokens"],
                                        dtype))
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return x, positions

    def embed(self, params, batch):
        """Public embedding hook: batch dict -> (x [B, S, D], positions
        [B, S]).  The compiled serving fast path (runtime/fastpath.py)
        traces through this; models exposing it (plus ``run_layers``)
        are fast-path capable."""
        return self._embed(params, batch)

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------
    def forward(self, params, batch):
        x, positions = self._embed(params, batch)
        x, aux = self._run_stack(params["layers"], x, positions)
        x = L.apply_norm(self.cfg, x, params["final_norm"])
        return L.unembed(self.cfg, params["embed"], x), aux

    def loss(self, params, batch):
        # CE from hidden states with chunked unembedding — the full
        # [B, S, V] logits tensor never materializes (layers.py docstring)
        x, positions = self._embed(params, batch)
        x, aux = self._run_stack(params["layers"], x, positions)
        x = L.apply_norm(self.cfg, x, params["final_norm"])
        labels = batch["labels"]
        # multimodal: loss only over the trailing text positions
        if x.shape[1] != labels.shape[1]:
            x = x[:, -labels.shape[1]:]
        ce = L.chunked_cross_entropy(self.cfg, x, params["embed"], labels,
                                     batch.get("loss_mask"))
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def prefill(self, params, batch, last_index=None):
        """Full-sequence pass building the KV cache; returns (last-position
        logits, cache).

        ``last_index`` ([B] int32, optional) names each row's true final
        prompt position for right-padded batches: logits are gathered
        there instead of at column s-1, and the cache ``len`` becomes
        ``last_index + 1`` per row.  Causal attention makes positions
        <= last_index independent of the padding, so the gathered logits
        and the live cache prefix are bitwise those of the unpadded
        prompt (the decode engine's bucket invariant, DESIGN.md §12).
        """
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        b, s = x.shape[0], x.shape[1]

        # collect per-layer K/V as scan outputs
        def step(x, lp):
            h = L.apply_norm(cfg, x, lp["ln1"])
            q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
            attn = L.blockwise_attention(q, k, v, causal=True,
                                         window=cfg.sliding_window)
            x = x + attn.reshape(x.shape[:2] + (cfg.q_dim,)) \
                @ lp["attn"]["wo"].astype(x.dtype)
            h2 = L.apply_norm(cfg, x, lp["ln2"])
            if cfg.n_experts:
                y, _ = M.apply_moe(cfg, lp["ffn"], h2)
            else:
                y = L.apply_mlp(cfg, lp["ffn"], h2)
            return x + y, (k.astype(jnp.dtype(cfg.dtype)),
                           v.astype(jnp.dtype(cfg.dtype)))

        x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
        x = L.apply_norm(cfg, x, params["final_norm"])
        if last_index is None:
            sel = x[:, -1:]
            lens = jnp.full((b,), s, jnp.int32)
        else:
            idx = jnp.asarray(last_index, jnp.int32)
            sel = jax.vmap(
                lambda row, i: jax.lax.dynamic_slice_in_dim(row, i, 1, 0)
            )(x, idx)
            lens = idx + 1
        logits = L.unembed(cfg, params["embed"], sel)[:, 0]
        cache = {"k": ks, "v": vs, "len": lens}
        return logits, cache

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "len": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(self):
        t = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": t, "v": t, "len": ("batch",)}

    def decode_step(self, params, cache, batch):
        """One token: batch = {'token': [B,1], 'pos': [B]}."""
        cfg = self.cfg
        tok, pos = batch["token"], batch["pos"]
        x = L.embed_tokens(params["embed"], tok, jnp.dtype(cfg.dtype))
        positions = pos[:, None]

        def step(x, lp_and_cache):
            lp, kc, vc = lp_and_cache
            h = L.apply_norm(cfg, x, lp["ln1"])
            q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
            # write new kv at position pos
            b = x.shape[0]
            kc = jax.vmap(lambda c, kk, pp: jax.lax.dynamic_update_slice(
                c, kk, (pp, 0, 0)))(kc, k, pos)
            vc = jax.vmap(lambda c, vv, pp: jax.lax.dynamic_update_slice(
                c, vv, (pp, 0, 0)))(vc, v, pos)
            attn = L.decode_attention(q, kc, vc, pos + 1,
                                      window=cfg.sliding_window)
            x = x + attn.reshape(b, 1, cfg.q_dim) \
                @ lp["attn"]["wo"].astype(x.dtype)
            h2 = L.apply_norm(cfg, x, lp["ln2"])
            if cfg.n_experts:
                y, _ = M.apply_moe(cfg, lp["ffn"], h2,
                                   path="dense" if cfg.n_experts <= 8
                                   else "dispatch",
                                   group_size=min(1024, b))
            else:
                y = L.apply_mlp(cfg, lp["ffn"], h2)
            return x + y, (kc, vc)

        x, (ks, vs) = jax.lax.scan(step, x,
                                   (params["layers"], cache["k"],
                                    cache["v"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
        return logits, new_cache

    def decode_step_q(self, params, qcache, batch, *, b_kv: int):
        """One token straight over the *quantized* cache (DESIGN.md §13).

        ``qcache`` is the decode engine's device-resident container:
        ``k_codes``/``v_codes`` [L, B, T, KV, dh] (int8 codes for
        b_kv < 16, the raw cfg.dtype container otherwise),
        ``k_scales``/``v_scales`` [L, B, T, KV] f32 (ones for raw), plus
        per-row ``len``.  Unlike :meth:`decode_step`, the cache is never
        dequantized wholesale: the fresh entry is quantized *before* it
        is written (so this step's own attention reads it through the
        same dequant map every later step will), and attention runs via
        :func:`quantized_decode_attention`, which dequantizes per-tile
        in VMEM.  b_kv >= 16 stores raw values with unit scales — an
        exact path through the identical kernel.
        """
        cfg = self.cfg
        tok, pos = batch["token"], batch["pos"]
        x = L.embed_tokens(params["embed"], tok, jnp.dtype(cfg.dtype))
        positions = pos[:, None]

        def write_row(c, entry, pp):
            # one row: entry [1, ...] into cache [T, ...] at position pp
            return jax.lax.dynamic_update_slice(
                c, entry, (pp,) + (0,) * (c.ndim - 1))

        def step(x, lp_and_cache):
            lp, kc, vc, ksc, vsc = lp_and_cache
            h = L.apply_norm(cfg, x, lp["ln1"])
            q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
            b = x.shape[0]
            if b_kv < 16:
                k_new, ks_new = kv_quantize(k, b_kv)
                v_new, vs_new = kv_quantize(v, b_kv)
            else:
                k_new, v_new = k.astype(kc.dtype), v.astype(vc.dtype)
                ks_new = jnp.ones(k.shape[:-1], jnp.float32)
                vs_new = jnp.ones(v.shape[:-1], jnp.float32)
            kc = jax.vmap(write_row)(kc, k_new.astype(kc.dtype), pos)
            vc = jax.vmap(write_row)(vc, v_new.astype(vc.dtype), pos)
            ksc = jax.vmap(write_row)(ksc, ks_new, pos)
            vsc = jax.vmap(write_row)(vsc, vs_new, pos)
            attn = quantized_decode_attention(
                q, kc, vc, ksc, vsc, pos + 1, window=cfg.sliding_window)
            x = x + attn.reshape(b, 1, cfg.q_dim) \
                @ lp["attn"]["wo"].astype(x.dtype)
            h2 = L.apply_norm(cfg, x, lp["ln2"])
            if cfg.n_experts:
                y, _ = M.apply_moe(cfg, lp["ffn"], h2,
                                   path="dense" if cfg.n_experts <= 8
                                   else "dispatch",
                                   group_size=min(1024, b))
            else:
                y = L.apply_mlp(cfg, lp["ffn"], h2)
            return x + y, (kc, vc, ksc, vsc)

        x, (ks, vs, kss, vss) = jax.lax.scan(
            step, x, (params["layers"], qcache["k_codes"],
                      qcache["v_codes"], qcache["k_scales"],
                      qcache["v_scales"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        new_cache = {"k_codes": ks, "v_codes": vs, "k_scales": kss,
                     "v_scales": vss, "len": qcache["len"] + 1}
        return logits, new_cache

    # ------------------------------------------------------------------
    # dry-run input specs
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        multimodal = cfg.frontend != "none"
        if shape.kind in ("train", "prefill"):
            out = {}
            if multimodal:
                sv = int(S * cfg.vis_frac) // 16 * 16
                st = S - sv
                out["embeds"] = sds((B, sv, cfg.d_model), dt)
                out["tokens"] = sds((B, st), i32)
                if shape.kind == "train":
                    out["labels"] = sds((B, st), i32)
            else:
                out["tokens"] = sds((B, S), i32)
                if shape.kind == "train":
                    out["labels"] = sds((B, S), i32)
            return out
        # decode: one token against a cache of length S
        return {"token": sds((B, 1), i32), "pos": sds((B,), i32)}

    def cache_specs(self, shape: ShapeSpec):
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
