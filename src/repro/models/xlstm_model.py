"""xLSTM language model (arXiv:2405.04517): mLSTM + sLSTM blocks.

Super-block of ``slstm_period`` layers: (period-1) mLSTM blocks followed by
one sLSTM block (the paper's xLSTM[7:1] ratio with period 8).  Scan over
super-blocks.  d_ff = 0: the gating/up-projections live inside the cells, no
separate FFN (matching the assigned config).

Fully recurrent -> O(1) decode state -> runs the long_500k cell.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..parallel.sharding import constrain_activations
from . import layers as L
from . import ssm as S


class XLSTMModel:
    def __init__(self, cfg: ModelConfig):
        per = cfg.slstm_period or 8
        assert cfg.n_layers % per == 0, "n_layers must divide by slstm_period"
        self.cfg = cfg
        self.per = per
        self.n_m = per - 1
        self.n_blocks = cfg.n_layers // per
        self._axes = None

    def _build(self, rng):
        cfg, nb = self.cfg, self.n_blocks
        ks = jax.random.split(rng, 4)
        emb_p, emb_ax = L.init_embeddings(cfg, ks[0])
        ml_ax = S.init_mlstm(cfg, ks[1], layers=self.n_m)[1]
        sl_ax = S.init_slstm(cfg, ks[2])[1]

        def over_blocks(fn, key):
            return jax.vmap(lambda k: fn(k)[0])(jax.random.split(key, nb))

        ml_p = over_blocks(lambda k: S.init_mlstm(cfg, k, layers=self.n_m),
                           ks[1])
        sl_p = over_blocks(lambda k: S.init_slstm(cfg, k), ks[2])
        ln = jnp.ones((nb, self.per, cfg.d_model), jnp.float32)
        lnf_p, lnf_ax = L.init_norm(cfg, cfg.d_model)

        def prepend(ax):
            return jax.tree_util.tree_map(
                lambda t: ("blocks",) + t, ax,
                is_leaf=lambda x: isinstance(x, tuple))

        params = {"embed": emb_p,
                  "blocks": {"mlstm": ml_p, "slstm": sl_p, "ln": ln},
                  "final_norm": lnf_p}
        self._axes = {"embed": emb_ax,
                      "blocks": {"mlstm": prepend(ml_ax),
                                 "slstm": prepend(sl_ax),
                                 "ln": ("blocks", "layers", "embed")},
                      "final_norm": lnf_ax}
        return params

    def init(self, rng):
        return self._build(rng)

    def logical_axes(self):
        if self._axes is None:
            jax.eval_shape(self._build, jax.random.PRNGKey(0))
        return self._axes

    def param_structs(self):
        return jax.eval_shape(self._build, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    def _super_block(self, bp, x):
        cfg = self.cfg
        for slot in range(self.per):
            h = L.rmsnorm(x, bp["ln"][slot])
            if slot < self.n_m:
                mp = jax.tree_util.tree_map(lambda a: a[slot], bp["mlstm"])
                x = x + S.mlstm_forward(cfg, mp, h)
            else:
                x = x + S.slstm_forward(cfg, bp["slstm"], h)
        return x

    def _hidden(self, params, batch):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))

        def one(x, bp):
            return self._super_block(bp, constrain_activations(x)), None

        one = jax.checkpoint(one)
        x, _ = jax.lax.scan(one, x, params["blocks"])
        return L.apply_norm(cfg, x, params["final_norm"])

    def forward(self, params, batch):
        x = self._hidden(params, batch)
        return L.unembed(self.cfg, params["embed"], x), jnp.float32(0.0)

    def loss(self, params, batch):
        x = self._hidden(params, batch)
        return L.chunked_cross_entropy(self.cfg, x, params["embed"],
                                       batch["labels"])

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        del max_len  # recurrent: O(1) state
        cfg, nb = self.cfg, self.n_blocks
        h, dh = cfg.n_heads, cfg.head_dim
        d = cfg.d_model
        z = jnp.zeros
        return {
            "mC": z((nb, self.n_m, batch, h, dh, dh), jnp.float32),
            "mn": z((nb, self.n_m, batch, h, dh), jnp.float32),
            "mm": jnp.full((nb, self.n_m, batch, h), -1e30, jnp.float32),
            "sh": z((nb, batch, d), jnp.float32),
            "sc": z((nb, batch, d), jnp.float32),
            "sn": z((nb, batch, d), jnp.float32),
            "sm": jnp.full((nb, batch, d), -1e30, jnp.float32),
            "len": z((batch,), jnp.int32),
        }

    def cache_axes(self):
        return {"mC": ("blocks", "layers", "batch", "heads", "head_dim",
                       "head_dim2"),
                "mn": ("blocks", "layers", "batch", "heads", "head_dim"),
                "mm": ("blocks", "layers", "batch", "heads"),
                "sh": ("blocks", "batch", "embed"),
                "sc": ("blocks", "batch", "embed"),
                "sn": ("blocks", "batch", "embed"),
                "sm": ("blocks", "batch", "embed"),
                "len": ("batch",)}

    def prefill(self, params, batch):
        """Recurrent prefill: run the full forward and also produce the final
        states by replaying through decode-style chunk reductions.  For the
        dry-run we return logits plus a fresh-state cache advanced by `len`
        (states computed with a second pass in chunked form)."""
        logits, _ = self.forward(params, batch)
        b, s = batch["tokens"].shape
        cache = self.init_cache(b, 0)
        cache["len"] = jnp.full((b,), s, jnp.int32)
        return logits[:, -1], cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tok = batch["token"]
        x = L.embed_tokens(params["embed"], tok, jnp.dtype(cfg.dtype))

        def one(x, inp):
            bp, mC, mn, mm, sh, sc, sn, sm = inp
            mC_new, mn_new, mm_new = [], [], []
            for slot in range(self.per):
                h = L.rmsnorm(x, bp["ln"][slot])
                if slot < self.n_m:
                    mp = jax.tree_util.tree_map(lambda a: a[slot],
                                                bp["mlstm"])
                    st = {"C": mC[slot], "n": mn[slot], "m": mm[slot]}
                    y, st = S.mlstm_decode_step(cfg, mp, h, st)
                    mC_new.append(st["C"])
                    mn_new.append(st["n"])
                    mm_new.append(st["m"])
                    x = x + y
                else:
                    st = {"h": sh, "c": sc, "n": sn, "m": sm}
                    y, st = S.slstm_decode_step(cfg, bp["slstm"], h, st)
                    sh2, sc2, sn2, sm2 = st["h"], st["c"], st["n"], st["m"]
                    x = x + y
            return x, (jnp.stack(mC_new), jnp.stack(mn_new),
                       jnp.stack(mm_new), sh2, sc2, sn2, sm2)

        x, (mC, mn, mm, sh, sc, sn, sm) = jax.lax.scan(
            one, x, (params["blocks"], cache["mC"], cache["mn"],
                     cache["mm"], cache["sh"], cache["sc"], cache["sn"],
                     cache["sm"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        return logits, {"mC": mC, "mn": mn, "mm": mm, "sh": sh, "sc": sc,
                        "sn": sn, "sm": sm, "len": cache["len"] + 1}

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        B, S_ = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            out = {"tokens": sds((B, S_), jnp.int32)}
            if shape.kind == "train":
                out["labels"] = sds((B, S_), jnp.int32)
            return out
        return {"token": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}

    def cache_specs(self, shape: ShapeSpec):
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
