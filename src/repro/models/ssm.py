"""State-space & recurrent sequence mixers: Mamba-2 (SSD) and xLSTM blocks.

TPU adaptation (DESIGN.md §3): the GPU reference implementations use fused
selective-scan CUDA kernels; here the recurrences are *chunked* — quadratic
attention-like matmuls within a chunk (MXU-friendly) plus a `lax.scan` over
chunks carrying the recurrent state.  Memory stays O(chunk²·H) instead of
O(S·H·N·P), and the chunk matmuls are what the MXU wants.

  * ``mamba_forward``  — Mamba-2 / SSD with scalar-per-head decay.
  * ``mlstm_forward``  — xLSTM matrix-memory cell, chunked, with the
    max-stabilized exponential gating of the xLSTM paper.
  * ``slstm_forward``  — xLSTM scalar cell with hidden-state recurrence
    (inherently sequential -> `lax.scan` over time).

Decode steps carry tiny O(1) states, which is exactly why these families run
the ``long_500k`` cell (see DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

def mamba_dims(cfg):
    d_in = cfg.d_model * cfg.mamba_expand
    n_heads = d_in // cfg.mamba_headdim
    return d_in, cfg.mamba_d_state, n_heads, cfg.mamba_headdim


def init_mamba(cfg, key, layers: Optional[int] = None):
    d = cfg.d_model
    d_in, n, h, _p = mamba_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)

    def mk(k, i, o):
        if layers is None:
            return dense_init(k, i, o, dt)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dt))(
            jax.random.split(k, layers))

    def vec(val, shape):
        full = (layers,) + shape if layers is not None else shape
        return jnp.full(full, val, jnp.float32)

    p = {
        "in_x": mk(ks[0], d, d_in), "in_z": mk(ks[1], d, d_in),
        "in_B": mk(ks[2], d, n), "in_C": mk(ks[3], d, n),
        "in_dt": mk(ks[4], d, h),
        "conv_x": vec(0.0, (cfg.mamba_d_conv, d_in)) + 1.0 / cfg.mamba_d_conv,
        "A_log": vec(0.0, (h,)),          # A = -exp(A_log) = -1
        "D": vec(1.0, (h,)),
        "dt_bias": vec(0.0, (h,)),
        "norm": vec(1.0, (d_in,)),
        "out": mk(ks[5], d_in, d),
    }
    lead = ("layers",) if layers is not None else ()
    ax = {
        "in_x": lead + ("embed", "ffn"), "in_z": lead + ("embed", "ffn"),
        "in_B": lead + ("embed", "state"), "in_C": lead + ("embed", "state"),
        "in_dt": lead + ("embed", "heads"),
        "conv_x": lead + ("conv", "ffn"),
        "A_log": lead + ("heads",), "D": lead + ("heads",),
        "dt_bias": lead + ("heads",),
        "norm": lead + ("ffn",),
        "out": lead + ("ffn", "embed"),
    }
    return p, ax


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along time.  x: [B,S,C]; w: [K,C].

    ``state`` = last K-1 inputs from the previous segment ([B,K-1,C]) for
    decode; returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


def mamba_forward(cfg, p, x, chunk: int = 256):
    """x: [B, S, D] -> [B, S, D] (full-sequence / prefill path)."""
    b, s, d = x.shape
    d_in, n, h, pd = mamba_dims(cfg)
    xb = x @ p["in_x"].astype(x.dtype)
    z = x @ p["in_z"].astype(x.dtype)
    xb, _ = _causal_conv(xb, p["conv_x"])
    xb = jax.nn.silu(xb)
    bc = (x @ p["in_B"].astype(x.dtype)).astype(jnp.float32)     # [B,S,N]
    cc = (x @ p["in_C"].astype(x.dtype)).astype(jnp.float32)     # [B,S,N]
    dt_r = (x @ p["in_dt"].astype(x.dtype)).astype(jnp.float32)  # [B,S,H]
    dt = jax.nn.softplus(dt_r + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                                      # [H]
    log_decay = dt * a                                            # [B,S,H] <=0

    xh = xb.reshape(b, s, h, pd).astype(jnp.float32)
    xbar = xh * dt[..., None]                                     # input scale

    c_len = min(chunk, s)
    nc = -(-s // c_len)
    pad = nc * c_len - s
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))

    xbar = xbar.reshape(b, nc, c_len, h, pd)
    bc = bc.reshape(b, nc, c_len, n)
    cc = cc.reshape(b, nc, c_len, n)
    la = log_decay.reshape(b, nc, c_len, h)

    def chunk_step(hstate, inp):
        xc, bcc, ccc, lac = inp       # [B,L,H,P], [B,L,N], [B,L,N], [B,L,H]
        cum = jnp.cumsum(lac, axis=1)                    # [B,L,H] inclusive
        # intra-chunk: attn[b,h,i,j] = (C_i . B_j) exp(cum_i - cum_j), j <= i
        scores = jnp.einsum("bin,bjn->bij", ccc, bcc)    # [B,L,L]
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # [B,L(i),L(j),H]
        li = jnp.arange(xc.shape[1])
        causal = li[:, None] >= li[None, :]
        attn = jnp.where(causal[None, :, :, None],
                         jnp.exp(decay) * scores[..., None], 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", attn, xc)
        # inbound state contribution: C_i . h_in * exp(cum_i)
        y = y + jnp.einsum("bin,bhnp,bih->bihp", ccc, hstate, jnp.exp(cum))
        # outbound state
        last = cum[:, -1:, :]                             # [B,1,H]
        w = jnp.exp(last - cum)                           # [B,L,H]
        h_new = jnp.einsum("bjn,bjhp,bjh->bhnp", bcc, xc, w) \
            + jnp.exp(last[:, 0, :])[:, :, None, None] * hstate
        return h_new, y

    h0 = jnp.zeros((b, h, n, pd), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0,
                         (xbar.swapaxes(0, 1), bc.swapaxes(0, 1),
                          cc.swapaxes(0, 1), la.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(b, nc * c_len, h, pd)[:, :s]
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    # gated RMSNorm then out-projection (Mamba-2 style)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm"]
    return (yf.astype(x.dtype)) @ p["out"].astype(x.dtype)


def mamba_init_state(cfg, batch, dtype=jnp.float32):
    d_in, n, h, pd = mamba_dims(cfg)
    return {"ssm": jnp.zeros((batch, h, n, pd), jnp.float32),
            "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype)}


def mamba_decode_step(cfg, p, x, state):
    """x: [B, 1, D]; state from :func:`mamba_init_state`."""
    b, _, d = x.shape
    d_in, n, h, pd = mamba_dims(cfg)
    xb = x @ p["in_x"].astype(x.dtype)
    z = x @ p["in_z"].astype(x.dtype)
    xb, conv_state = _causal_conv(xb, p["conv_x"], state["conv"])
    xb = jax.nn.silu(xb)
    bc = (x @ p["in_B"].astype(x.dtype)).astype(jnp.float32)[:, 0]   # [B,N]
    cc = (x @ p["in_C"].astype(x.dtype)).astype(jnp.float32)[:, 0]
    dt_r = (x @ p["in_dt"].astype(x.dtype)).astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt_r + p["dt_bias"])                        # [B,H]
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                           # [B,H]
    xh = xb.reshape(b, h, pd).astype(jnp.float32)
    xbar = xh * dt[..., None]
    hs = state["ssm"] * a[:, :, None, None] \
        + jnp.einsum("bn,bhp->bhnp", bc, xbar)
    y = jnp.einsum("bn,bhnp->bhp", cc, hs) + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm"]
    out = yf.astype(x.dtype) @ p["out"].astype(x.dtype)
    return out, {"ssm": hs, "conv": conv_state}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunked) and sLSTM (scalar, sequential)
# ---------------------------------------------------------------------------

def init_mlstm(cfg, key, layers: Optional[int] = None):
    d, qd, h = cfg.d_model, cfg.q_dim, cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)

    def mk(k, i, o):
        if layers is None:
            return dense_init(k, i, o, dt)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dt))(
            jax.random.split(k, layers))

    def vec(val, *shape):
        full = (layers,) + shape if layers is not None else shape
        return jnp.full(full, val, jnp.float32)

    p = {"wq": mk(ks[0], d, qd), "wk": mk(ks[1], d, qd),
         "wv": mk(ks[2], d, qd),
         "w_i": mk(ks[3], d, h), "w_f": mk(ks[4], d, h),
         "b_i": vec(0.0, h), "b_f": vec(3.0, h),
         "w_o": mk(ks[5], d, qd),     # sigmoid output gate (vector)
         "wout": mk(ks[6], qd, d)}
    lead = ("layers",) if layers is not None else ()
    ax = {"wq": lead + ("embed", "heads"), "wk": lead + ("embed", "heads"),
          "wv": lead + ("embed", "heads"),
          "w_i": lead + ("embed", "head_vec"),
          "w_f": lead + ("embed", "head_vec"),
          "b_i": lead + ("head_vec",), "b_f": lead + ("head_vec",),
          "w_o": lead + ("embed", "heads"), "wout": lead + ("heads", "embed")}
    return p, ax


def mlstm_forward(cfg, p, x, chunk: int = 256):
    """Chunked matrix-LSTM.  x: [B, S, D] -> [B, S, D].

    Recurrence (per head, stabilizer m):
        m_t = max(log f_t + m_{t-1}, i_t)
        C_t = e^{log f_t + m_{t-1} - m_t} C_{t-1} + e^{i_t - m_t} k_t v_t^T
        n_t = (same) n_{t-1} + e^{i_t - m_t} k_t
        y_t = (q_t C_t) / max(|q_t n_t|, e^{-m_t})
    Chunked: within-chunk pairs via masked matmul, cross-chunk via scan.
    """
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, h, dh) * dh ** -0.5
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, h, dh)
    i_raw = (x @ p["w_i"].astype(x.dtype)).astype(jnp.float32) + p["b_i"]
    f_raw = (x @ p["w_f"].astype(x.dtype)).astype(jnp.float32) + p["b_f"]
    log_f = jax.nn.log_sigmoid(f_raw)                      # [B,S,H]
    o_gate = jax.nn.sigmoid(
        (x @ p["w_o"].astype(x.dtype)).astype(jnp.float32))

    c_len = min(chunk, s)
    nc = -(-s // c_len)
    pad = nc * c_len - s
    if pad:
        def pz(t):
            return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = pz(q), pz(k), pz(v)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e9)
        log_f = pz(log_f)

    qc = q.reshape(b, nc, c_len, h, dh).astype(jnp.float32)
    kc = k.reshape(b, nc, c_len, h, dh).astype(jnp.float32)
    vc = v.reshape(b, nc, c_len, h, dh).astype(jnp.float32)
    ic = i_raw.reshape(b, nc, c_len, h)
    fc = log_f.reshape(b, nc, c_len, h)

    def chunk_step(carry, inp):
        cs, ns, ms = carry            # [B,H,dh,dh], [B,H,dh], [B,H]
        qb, kb, vb, ib, fb = inp
        cumf = jnp.cumsum(fb, axis=1)                     # [B,L,H]
        # local log-weights of source j at target i: cumf_i - cumf_j + i_j
        li = jnp.arange(qb.shape[1])
        causal = li[:, None] >= li[None, :]
        lw = (cumf[:, :, None, :] - cumf[:, None, :, :]
              + ib[:, None, :, :])                        # [B,i,j,H]
        lw = jnp.where(causal[None, :, :, None], lw, -jnp.inf)
        # inbound-state log-weight at target i: cumf_i + m_state
        lw_state = cumf + ms[:, None, :]                  # [B,L,H]
        m_loc = jnp.maximum(jnp.max(lw, axis=2), lw_state)  # [B,L,H]
        m_loc = jnp.maximum(m_loc, -1e30)
        w = jnp.exp(lw - m_loc[:, :, None, :])
        w = jnp.where(causal[None, :, :, None], w, 0.0)   # [B,i,j,H]
        scores = jnp.einsum("bihd,bjhd->bijh", qb, kb) * w
        y = jnp.einsum("bijh,bjhd->bihd", scores, vb)
        denom = jnp.einsum("bijh,bjhd,bihd->bih", w, kb, qb)
        w_state = jnp.exp(lw_state - m_loc)               # [B,L,H]
        y = y + jnp.einsum("bihd,bhde,bih->bihe", qb, cs, w_state)
        denom = denom + jnp.einsum("bihd,bhd,bih->bih", qb, ns, w_state)
        y = y / jnp.maximum(jnp.abs(denom), jnp.exp(-m_loc))[..., None]
        # ---- state update to end of chunk ----
        last = cumf[:, -1:, :]                            # [B,1,H]
        m_new = jnp.maximum(last[:, 0] + ms,
                            jnp.max(last - cumf + ib, axis=1))
        wk = jnp.exp(last - cumf + ib - m_new[:, None, :])  # [B,L,H]
        decay = jnp.exp(last[:, 0] + ms - m_new)            # [B,H]
        c_new = decay[:, :, None, None] * cs \
            + jnp.einsum("bjh,bjhd,bjhe->bhde", wk, kb, vb)
        n_new = decay[:, :, None] * ns \
            + jnp.einsum("bjh,bjhd->bhd", wk, kb)
        return (c_new, n_new, m_new), y

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, ys = jax.lax.scan(chunk_step, (c0, n0, m0),
                         (qc.swapaxes(0, 1), kc.swapaxes(0, 1),
                          vc.swapaxes(0, 1), ic.swapaxes(0, 1),
                          fc.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(b, nc * c_len, h, dh)[:, :s]
    y = y.reshape(b, s, h * dh) * o_gate
    return y.astype(x.dtype) @ p["wout"].astype(x.dtype)


def mlstm_init_state(cfg, batch):
    h, dh = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_decode_step(cfg, p, x, state):
    """x: [B, 1, D] single-token step; O(1) state."""
    b, _, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32) \
        * dh ** -0.5
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, h, dh).astype(jnp.float32)
    i_raw = (x @ p["w_i"].astype(x.dtype)).astype(jnp.float32)[:, 0] + p["b_i"]
    f_raw = (x @ p["w_f"].astype(x.dtype)).astype(jnp.float32)[:, 0] + p["b_f"]
    log_f = jax.nn.log_sigmoid(f_raw)
    o_gate = jax.nn.sigmoid(
        (x @ p["w_o"].astype(x.dtype)).astype(jnp.float32))[:, 0]
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    fg = jnp.exp(log_f + state["m"] - m_new)
    ig = jnp.exp(i_raw - m_new)
    c_new = fg[:, :, None, None] * state["C"] \
        + ig[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = fg[:, :, None] * state["n"] + ig[:, :, None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = (y.reshape(b, 1, h * dh) * o_gate[:, None, :]).astype(x.dtype)
    out = y @ p["wout"].astype(x.dtype)
    return out, {"C": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (scalar cell, hidden-state recurrence -> sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(cfg, key, layers: Optional[int] = None):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)

    def mk(k, i, o):
        if layers is None:
            return dense_init(k, i, o, dt)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dt))(
            jax.random.split(k, layers))

    def rec(k):
        # block-diagonal recurrent weights: per head [dh, dh], 4 gates
        def one(kk):
            return jax.vmap(lambda k2: dense_init(k2, dh, dh, dt) * 0.5)(
                jax.random.split(kk, h * 4)).reshape(4, h, dh, dh)
        if layers is None:
            return one(k)
        return jax.vmap(one)(jax.random.split(k, layers))

    def vec(val, *shape):
        full = (layers,) + shape if layers is not None else shape
        return jnp.full(full, val, jnp.float32)

    p = {"wx": mk(ks[0], d, 4 * d),   # z, i, f, o pre-activations from x
         "r": rec(ks[1]),
         "b": vec(0.0, 4, d),
         "wout": mk(ks[2], d, d)}
    lead = ("layers",) if layers is not None else ()
    ax = {"wx": lead + ("embed", "gates"),
          "r": lead + ("gate4", "head_vec", "hd1", "hd2"),
          "b": lead + ("gate4", "embed"),
          "wout": lead + ("embed", "embed2")}
    return p, ax


def slstm_forward(cfg, p, x):
    """Sequential sLSTM.  x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xg = (x @ p["wx"].astype(x.dtype)).astype(jnp.float32)
    xg = xg.reshape(b, s, 4, d) + p["b"]

    def step(carry, xt):
        hs, c, n, m = carry            # [B,D], [B,D], [B,D], [B,D]
        hh = hs.reshape(b, h, dh)
        rg = jnp.einsum("ghij,bhj->gbhi", p["r"].astype(jnp.float32), hh)
        rg = rg.reshape(4, b, d)
        z = jnp.tanh(xt[:, 0] + rg[0])
        i_log = xt[:, 1] + rg[1]
        f_log = jax.nn.log_sigmoid(xt[:, 2] + rg[2])
        o = jax.nn.sigmoid(xt[:, 3] + rg[3])
        m_new = jnp.maximum(f_log + m, i_log)
        ig = jnp.exp(i_log - m_new)
        fg = jnp.exp(f_log + m - m_new)
        c_new = fg * c + ig * z
        n_new = jnp.maximum(fg * n + ig, 1.0)
        h_new = o * c_new / n_new
        return (h_new, c_new, n_new, m_new), h_new

    zeros = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), -1e30, jnp.float32)
    (_, _, _, _), ys = jax.lax.scan(step, (zeros, zeros, zeros, m0),
                                    xg.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).astype(x.dtype)
    return y @ p["wout"].astype(x.dtype)


def slstm_init_state(cfg, batch):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}


def slstm_decode_step(cfg, p, x, state):
    """x: [B, 1, D]."""
    b, _, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xg = (x @ p["wx"].astype(x.dtype)).astype(jnp.float32)
    xg = (xg.reshape(b, 4, d) + p["b"])
    hh = state["h"].reshape(b, h, dh)
    rg = jnp.einsum("ghij,bhj->gbhi", p["r"].astype(jnp.float32), hh)
    rg = rg.reshape(4, b, d)
    z = jnp.tanh(xg[:, 0] + rg[0])
    i_log = xg[:, 1] + rg[1]
    f_log = jax.nn.log_sigmoid(xg[:, 2] + rg[2])
    o = jax.nn.sigmoid(xg[:, 3] + rg[3])
    m_new = jnp.maximum(f_log + state["m"], i_log)
    ig = jnp.exp(i_log - m_new)
    fg = jnp.exp(f_log + state["m"] - m_new)
    c_new = fg * state["c"] + ig * z
    n_new = jnp.maximum(fg * state["n"] + ig, 1.0)
    h_new = o * c_new / n_new
    out = h_new[:, None, :].astype(x.dtype) @ p["wout"].astype(x.dtype)
    return out, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}
