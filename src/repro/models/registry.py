"""Model factory: ModelConfig -> model instance."""

from __future__ import annotations

from ..configs.base import ModelConfig
from .encdec import EncDecModel
from .hybrid import HybridLM
from .lm import DecoderLM
from .xlstm_model import XLSTMModel


def build_model(cfg: ModelConfig):
    if cfg.n_enc_layers > 0:
        return EncDecModel(cfg)
    if cfg.family == "hybrid" or cfg.attn_period > 1:
        return HybridLM(cfg)
    if cfg.family == "ssm":
        return XLSTMModel(cfg)
    return DecoderLM(cfg)
