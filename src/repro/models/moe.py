"""Mixture-of-Experts layer: top-k router + two execution paths.

  * ``dense`` path — computes every expert and masks; exact, used for smoke
    tests / tiny expert counts and as the oracle in MoE tests.
  * ``dispatch`` path — GShard-style capacity-based dispatch/combine einsums
    over [groups, tokens, experts, capacity] one-hots.  This is the
    production path: with experts sharded over the ``model`` mesh axis and
    groups over ``data``, GSPMD turns the dispatch/combine contractions into
    the expected all-to-all pattern (visible in the dry-run HLO, counted in
    the collective roofline term).

Weights: ``wi_gate/wi_up: [E, D, F]``, ``wo: [E, F, D]``, router ``[D, E]``
(logical axes ('experts','embed','ffn') etc. — see parallel/sharding.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(cfg, key, layers: Optional[int] = None):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)

    def mk_expert(k, i, o):
        def one(kk):
            return jax.vmap(lambda k2: dense_init(k2, i, o, dt))(
                jax.random.split(kk, e))
        if layers is None:
            return one(k)
        return jax.vmap(one)(jax.random.split(k, layers))

    def mk_router(k):
        if layers is None:
            return dense_init(k, d, e, dt)
        return jax.vmap(lambda kk: dense_init(kk, d, e, dt))(
            jax.random.split(k, layers))

    lead = ("layers",) if layers is not None else ()
    p = {"router": mk_router(ks[0]),
         "wi_gate": mk_expert(ks[1], d, f),
         "wi_up": mk_expert(ks[2], d, f),
         "wo": mk_expert(ks[3], f, d)}
    ax = {"router": lead + ("embed", "experts"),
          "wi_gate": lead + ("experts", "embed", "ffn"),
          "wi_up": lead + ("experts", "embed", "ffn"),
          "wo": lead + ("experts", "ffn", "embed")}
    return p, ax


def _router_probs(cfg, p, x):
    """Softmax router over experts; returns (probs [.., E], logits)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def load_balancing_loss(router_probs, expert_mask):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    e = router_probs.shape[-1]
    f_e = jnp.mean(expert_mask, axis=tuple(range(expert_mask.ndim - 1)))
    p_e = jnp.mean(router_probs, axis=tuple(range(router_probs.ndim - 1)))
    return e * jnp.sum(f_e * p_e)


def apply_moe_dense(cfg, p, x):
    """Oracle path: run all experts, combine with top-k gate weights.

    x: [B, S, D] -> [B, S, D].  Cost scales with n_experts — smoke only.
    """
    probs, _ = _router_probs(cfg, p, x)
    k = cfg.experts_per_token
    topv, topi = jax.lax.top_k(probs, k)                     # [B,S,k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], topi].set(topv)  # [B,S,E]
    g = jnp.einsum("bsd,edf->bsef", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("bsef,efd->bsed", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("bsed,bse->bsd", y, gates.astype(x.dtype))
    aux = load_balancing_loss(probs, (gates > 0).astype(jnp.float32))
    return out, aux


#: per-call token budget for the dispatch indicator tensors.  The GShard
#: dispatch/combine one-hots are O(tokens * E * C) — at kimi-k2 scale
#: (1M tokens, 384 experts) a single-shot dispatch would materialize tens of
#: TB.  Chunking the *sequence* axis (MoE is position-independent) caps the
#: live indicator at chunk_tokens * E * C while total FLOPs stay identical;
#: the chunks run under lax.scan so the HLO holds one chunk body.
MAX_CHUNK_TOKENS = 65536


def apply_moe_dispatch(cfg, p, x, group_size: int = 1024,
                       max_chunk_tokens: int = MAX_CHUNK_TOKENS):
    """GShard capacity dispatch, sequence-chunked.  x: [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    tokens = b * s
    if tokens > max_chunk_tokens and s > 1:
        n = max(-(-tokens // max_chunk_tokens), 1)
        while n <= s and s % n != 0:
            n += 1
        if 1 < n <= s:
            xc = x.reshape(b, n, s // n, d).swapaxes(0, 1)  # [n,B,S/n,D]

            def step(aux, xi):
                y, a = _dispatch_one(cfg, p, xi, group_size)
                return aux + a, y

            aux, ys = jax.lax.scan(step, jnp.float32(0.0), xc)
            return ys.swapaxes(0, 1).reshape(b, s, d), aux / n
    return _dispatch_one(cfg, p, x, group_size)


def _dispatch_one(cfg, p, x, group_size: int = 1024):
    """Single-shot GShard capacity dispatch.

    Tokens are viewed as [G, S_g]; capacity C = ceil(k * S_g * cf / E).
    dispatch one-hot: [G, S_g, E, C]; expert compute on [E, G, C, D].
    Tokens over capacity are dropped (standard GShard semantics; the aux
    loss keeps the router balanced so drops stay rare).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = b * s
    g_sz = min(group_size, tokens)
    n_g = tokens // g_sz
    assert n_g * g_sz == tokens, (
        f"tokens {tokens} not divisible by group size {g_sz}")
    cap = max(int(-(-k * g_sz * cfg.capacity_factor // e)), 1)

    xg = x.reshape(n_g, g_sz, d)
    probs, _ = _router_probs(cfg, p, xg)                      # [G,Sg,E]
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # expert one-hot per assignment slot: [G, Sg, k, E]
    assign = jax.nn.one_hot(topi, e, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue, counted over
    # the flattened (slot-major then token) order
    flat = assign.transpose(0, 2, 1, 3).reshape(n_g, k * g_sz, e)
    pos = jnp.cumsum(flat, axis=1) - flat                     # [G, k*Sg, E]
    pos = pos.reshape(n_g, k, g_sz, e).transpose(0, 2, 1, 3)  # [G,Sg,k,E]
    within = (pos < cap) & (assign > 0)
    pos_cap = jnp.where(within, pos, 0).astype(jnp.int32)
    cap_oh = jax.nn.one_hot(pos_cap, cap, dtype=jnp.float32) \
        * within[..., None]
    # dispatch/combine tensors: [G, Sg, E, C]
    dispatch = jnp.einsum("gske,gskec->gsec", assign, cap_oh)
    combine = jnp.einsum("gsk,gske,gskec->gsec", topv, assign, cap_oh)

    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg)
    gte = jnp.einsum("egcd,edf->egcf", xin, p["wi_gate"].astype(x.dtype))
    up = jnp.einsum("egcd,edf->egcf", xin, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(gte) * up
    yout = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype))
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), yout)

    aux = load_balancing_loss(probs, jnp.max(assign, axis=2))
    return y.reshape(b, s, d), aux


def apply_moe(cfg, p, x, *, path: str = "auto", group_size: int = 1024):
    if path == "auto":
        path = "dense" if cfg.n_experts <= 8 else "dispatch"
    if path == "dense":
        return apply_moe_dense(cfg, p, x)
    return apply_moe_dispatch(cfg, p, x, group_size=group_size)
