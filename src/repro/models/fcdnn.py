"""FCDNN-16 (paper §VI-A): fully connected autoencoder, ReLU, 16 hidden
layers — encoder dims [64,128,256,512,256,128,64,32], symmetric decoder.

This is the model Proposition 3.1 is validated on (paper Fig. 3, left).
Weights are a plain list of [out, in] matrices (the proof's convention:
y = W x, induced-L1 norms over columns); no biases, sigma = ReLU,
sigma(0) = 0 per Assumption 2.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from ..configs.fcdnn16 import DECODER_DIMS, ENCODER_DIMS, INPUT_DIM


def layer_dims(input_dim: int = INPUT_DIM) -> List[int]:
    """[in, h1, ..., h16, out] — 17 weight matrices, 16 hidden layers."""
    return [input_dim, *ENCODER_DIMS, *DECODER_DIMS[1:], input_dim]


def init_fcdnn(key, dims: Sequence[int] | None = None,
               scale: float = 0.5) -> List[jax.Array]:
    """He-style init scaled down so prod ||W||_1 stays finite-ish (the
    chain bound is a product of induced norms; wild inits make it vacuous)."""
    dims = list(dims) if dims is not None else layer_dims()
    ks = jax.random.split(key, len(dims) - 1)
    ws = []
    for k, d_in, d_out in zip(ks, dims[:-1], dims[1:]):
        w = jax.random.normal(k, (d_out, d_in), jnp.float32)
        ws.append(w * scale * (2.0 / d_in) ** 0.5)
    return ws


def apply_fcdnn(weights: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """f(x, W) = W^L relu(W^{L-1} relu(... W^1 x)).  x: [B, D_in]."""
    h = x
    for i, w in enumerate(weights):
        h = h @ w.T
        if i < len(weights) - 1:
            h = jax.nn.relu(h)
    return h


def mse_loss(weights, x):
    """Autoencoder reconstruction loss (the paper trains on MNIST MSE)."""
    y = apply_fcdnn(weights, x)
    return jnp.mean(jnp.square(y - x))
