"""Jamba-style hybrid: Mamba + attention interleaved 7:1, MoE every other
layer (paper: arXiv:2403.19887).

Layer layout per super-block of ``attn_period`` (=8) layers:

  in-block idx : 0      1      2      3      4      5      6      7
  mixer        : mamba  mamba  mamba  mamba  mamba  mamba  mamba  ATTN
  ffn          : MLP    MoE    MLP    MoE    MLP    MoE    MLP    MoE

The model scans over super-blocks (params stacked on a leading 'blocks'
axis); within a block the 8 heterogeneous layers are trace-unrolled.  This
keeps the compiled HLO at one super-block body while supporting the 72-layer
full config (9 blocks).

long_500k runs here: decode state is O(1) for the 63 Mamba layers and the 9
attention layers shard their KV cache along the sequence axis
(cache_seq -> 'data'), turning full-cache reads into a
partial-softmax-plus-reduce pattern under GSPMD.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..parallel.sharding import constrain_activations
from . import layers as L
from . import moe as M
from . import ssm as S


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        if cfg.attn_period <= 1:
            raise ValueError("HybridLM needs attn_period > 1")
        assert cfg.n_layers % cfg.attn_period == 0
        self.cfg = cfg
        self.n_blocks = cfg.n_layers // cfg.attn_period
        self.per = cfg.attn_period
        self.n_mamba = self.per - 1
        # ffn schedule within a block: odd indices are MoE
        self.moe_slots = [i for i in range(self.per) if i % 2 == 1]
        self.mlp_slots = [i for i in range(self.per) if i % 2 == 0]
        self._axes = None

    # ------------------------------------------------------------------
    def _build(self, rng):
        cfg, nb = self.cfg, self.n_blocks
        ks = jax.random.split(rng, 8)

        def over_blocks(fn, key):
            sub = jax.random.split(key, nb)
            return jax.vmap(lambda k: fn(k)[0])(sub)

        # build one block's axes by calling the underlying init once with
        # eval_shape (axes are static side outputs)
        emb_p, emb_ax = L.init_embeddings(cfg, ks[0])
        mam_ax = S.init_mamba(cfg, ks[1], layers=self.n_mamba)[1]
        att_ax = L.init_attention(cfg, ks[2])[1]
        mlp_ax = L.init_mlp(cfg, ks[3], d_ff=cfg.d_ff,
                            layers=len(self.mlp_slots))[1]
        moe_ax = M.init_moe(cfg, ks[4], layers=len(self.moe_slots))[1]

        mam_p = over_blocks(lambda k: S.init_mamba(cfg, k,
                                                   layers=self.n_mamba),
                            ks[1])
        att_p = over_blocks(lambda k: L.init_attention(cfg, k), ks[2])
        mlp_p = over_blocks(lambda k: L.init_mlp(
            cfg, k, d_ff=cfg.d_ff, layers=len(self.mlp_slots)), ks[3])
        moe_p = over_blocks(lambda k: M.init_moe(
            cfg, k, layers=len(self.moe_slots)), ks[4])

        ln_mix = jnp.ones((nb, self.per, cfg.d_model), jnp.float32)
        ln_ffn = jnp.ones((nb, self.per, cfg.d_model), jnp.float32)
        lnf_p, lnf_ax = L.init_norm(cfg, cfg.d_model)

        def prepend(ax_tree, name="blocks"):
            return jax.tree_util.tree_map(
                lambda t: (name,) + t, ax_tree,
                is_leaf=lambda x: isinstance(x, tuple))

        params = {"embed": emb_p,
                  "blocks": {"mamba": mam_p, "attn": att_p, "mlp": mlp_p,
                             "moe": moe_p, "ln_mix": ln_mix,
                             "ln_ffn": ln_ffn},
                  "final_norm": lnf_p}
        axes = {"embed": emb_ax,
                "blocks": {"mamba": prepend(mam_ax),
                           "attn": prepend(att_ax),
                           "mlp": prepend(mlp_ax),
                           "moe": prepend(moe_ax),
                           "ln_mix": ("blocks", "layers", "embed"),
                           "ln_ffn": ("blocks", "layers", "embed")},
                "final_norm": lnf_ax}
        self._axes = axes
        return params

    def init(self, rng):
        return self._build(rng)

    def logical_axes(self):
        if self._axes is None:
            jax.eval_shape(self._build, jax.random.PRNGKey(0))
        return self._axes

    def param_structs(self):
        return jax.eval_shape(self._build, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    def _ffn(self, bp, slot, x):
        cfg = self.cfg
        h = L.rmsnorm(x, bp["ln_ffn"][slot])
        if slot in self.moe_slots:
            i = self.moe_slots.index(slot)
            lp = jax.tree_util.tree_map(lambda a: a[i], bp["moe"])
            y, aux = M.apply_moe(cfg, lp, h)
        else:
            i = self.mlp_slots.index(slot)
            lp = jax.tree_util.tree_map(lambda a: a[i], bp["mlp"])
            y, aux = L.apply_mlp(cfg, lp, h), jnp.float32(0.0)
        return x + y, aux

    def _super_block(self, bp, x, positions):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        for slot in range(self.per):
            h = L.rmsnorm(x, bp["ln_mix"][slot])
            if slot < self.n_mamba:
                mp = jax.tree_util.tree_map(lambda a: a[slot], bp["mamba"])
                x = x + S.mamba_forward(cfg, mp, h)
            else:
                q, k, v = L.qkv_project(cfg, bp["attn"], h, positions)
                attn = L.blockwise_attention(q, k, v, causal=True)
                x = x + attn.reshape(x.shape[:2] + (cfg.q_dim,)) \
                    @ bp["attn"]["wo"].astype(x.dtype)
            x, a = self._ffn(bp, slot, x)
            aux = aux + a
        return x, aux

    def _hidden(self, params, batch):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(carry, bp):
            x, aux = carry
            x = constrain_activations(x)
            x, a = self._super_block(bp, x, positions)
            return (x, aux + a), None

        one = jax.checkpoint(one)
        (x, aux), _ = jax.lax.scan(one, (x, jnp.float32(0.0)),
                                   params["blocks"])
        return L.apply_norm(cfg, x, params["final_norm"]), aux

    def forward(self, params, batch):
        x, aux = self._hidden(params, batch)
        return L.unembed(self.cfg, params["embed"], x), aux

    def loss(self, params, batch):
        x, aux = self._hidden(params, batch)
        ce = L.chunked_cross_entropy(self.cfg, x, params["embed"],
                                     batch["labels"])
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg, nb = self.cfg, self.n_blocks
        dt = jnp.dtype(cfg.dtype)
        d_in, n, h, pd = S.mamba_dims(cfg)
        return {
            "k": jnp.zeros((nb, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((nb, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "ssm": jnp.zeros((nb, self.n_mamba, batch, h, n, pd),
                             jnp.float32),
            "conv": jnp.zeros((nb, self.n_mamba, batch,
                               cfg.mamba_d_conv - 1, d_in), dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self):
        t = ("blocks", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": t, "v": t,
                "ssm": ("blocks", "layers", "batch", "heads", "state",
                        "head_dim"),
                "conv": ("blocks", "layers", "batch", "conv", "ffn"),
                "len": ("batch",)}

    def prefill(self, params, batch):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(x, bp):
            aux = jnp.float32(0.0)
            k_out = v_out = None
            for slot in range(self.per):
                h = L.rmsnorm(x, bp["ln_mix"][slot])
                if slot < self.n_mamba:
                    mp = jax.tree_util.tree_map(lambda a: a[slot],
                                                bp["mamba"])
                    x = x + S.mamba_forward(cfg, mp, h)
                else:
                    q, k, v = L.qkv_project(cfg, bp["attn"], h, positions)
                    attn = L.blockwise_attention(q, k, v, causal=True)
                    x = x + attn.reshape(x.shape[:2] + (cfg.q_dim,)) \
                        @ bp["attn"]["wo"].astype(x.dtype)
                    k_out, v_out = (k.astype(jnp.dtype(cfg.dtype)),
                                    v.astype(jnp.dtype(cfg.dtype)))
                x, a = self._ffn(bp, slot, x)
                aux = aux + a
            return x, (k_out, v_out)

        # NOTE: prefill recomputes mamba states at decode start; the serving
        # engine caches them via prefill_with_states when needed (smoke path
        # uses decode-from-scratch which replays the prompt).
        x, (ks, vs) = jax.lax.scan(one, x, params["blocks"])
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
        cache = self.init_cache(b, s)
        cache["k"] = ks
        cache["v"] = vs
        cache["len"] = jnp.full((b,), s, jnp.int32)
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tok, pos = batch["token"], batch["pos"]
        x = L.embed_tokens(params["embed"], tok, jnp.dtype(cfg.dtype))
        b = x.shape[0]
        positions = pos[:, None]

        def one(x, bp_cache):
            bp, kc, vc, ssm, conv = bp_cache
            ssm_new, conv_new = [], []
            for slot in range(self.per):
                h = L.rmsnorm(x, bp["ln_mix"][slot])
                if slot < self.n_mamba:
                    mp = jax.tree_util.tree_map(lambda a: a[slot],
                                                bp["mamba"])
                    st = {"ssm": ssm[slot], "conv": conv[slot]}
                    y, st = S.mamba_decode_step(cfg, mp, h, st)
                    ssm_new.append(st["ssm"])
                    conv_new.append(st["conv"])
                    x = x + y
                else:
                    q, k, v = L.qkv_project(cfg, bp["attn"], h, positions)
                    kc = jax.vmap(
                        lambda c, kk, pp: jax.lax.dynamic_update_slice(
                            c, kk, (pp, 0, 0)))(kc, k, pos)
                    vc = jax.vmap(
                        lambda c, vv, pp: jax.lax.dynamic_update_slice(
                            c, vv, (pp, 0, 0)))(vc, v, pos)
                    attn = L.decode_attention(q, kc, vc, pos + 1)
                    x = x + attn.reshape(b, 1, cfg.q_dim) \
                        @ bp["attn"]["wo"].astype(x.dtype)
                x, _ = self._ffn(bp, slot, x)
            return x, (kc, vc, jnp.stack(ssm_new), jnp.stack(conv_new))

        def scan_fn(x, inp):
            x, out = one(x, inp)
            return x, out

        x, (ks, vs, ssms, convs) = jax.lax.scan(
            scan_fn, x, (params["blocks"], cache["k"], cache["v"],
                         cache["ssm"], cache["conv"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        return logits, {"k": ks, "v": vs, "ssm": ssms, "conv": convs,
                        "len": cache["len"] + 1}

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        B, S_ = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            out = {"tokens": sds((B, S_), jnp.int32)}
            if shape.kind == "train":
                out["labels"] = sds((B, S_), jnp.int32)
            return out
        return {"token": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}

    def cache_specs(self, shape: ShapeSpec):
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
