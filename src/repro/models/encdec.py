"""Encoder-decoder transformer (SeamlessM4T-v2 backbone shape).

Audio family: the modality frontend is a STUB per the assignment — encoder
input is precomputed frame embeddings ``embeds`` [B, S_enc, D].  The decoder
is a standard causal transformer with cross-attention to the encoder output.

Shape mapping for the assigned cells (documented in EXPERIMENTS.md): a cell
with seq_len S gives the encoder S/2 frames and the decoder S/2 tokens;
decode cells hold a decoder self-cache of S/2 and a cross-cache of S/2.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import layers as L


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.n_enc_layers > 0
        self.cfg = cfg
        self._axes = None

    # ------------------------------------------------------------------
    def _build(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 8)
        emb_p, emb_ax = L.init_embeddings(cfg, ks[0])
        enc_attn_p, enc_attn_ax = L.init_attention(cfg, ks[1],
                                                   layers=cfg.n_enc_layers)
        enc_mlp_p, enc_mlp_ax = L.init_mlp(cfg, ks[2],
                                           layers=cfg.n_enc_layers)
        dec_attn_p, dec_attn_ax = L.init_attention(cfg, ks[3],
                                                   layers=cfg.n_layers)
        dec_x_p, dec_x_ax = L.init_attention(cfg, ks[4],
                                             layers=cfg.n_layers)
        dec_mlp_p, dec_mlp_ax = L.init_mlp(cfg, ks[5], layers=cfg.n_layers)

        def norms(n, k):
            return jnp.ones((n, k, cfg.d_model), jnp.float32)

        lnf_p, lnf_ax = L.init_norm(cfg, cfg.d_model)
        params = {"embed": emb_p,
                  "enc": {"attn": enc_attn_p, "mlp": enc_mlp_p,
                          "ln": norms(cfg.n_enc_layers, 2)},
                  "dec": {"attn": dec_attn_p, "cross": dec_x_p,
                          "mlp": dec_mlp_p, "ln": norms(cfg.n_layers, 3)},
                  "final_norm": lnf_p,
                  "enc_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        axes = {"embed": emb_ax,
                "enc": {"attn": enc_attn_ax, "mlp": enc_mlp_ax,
                        "ln": ("layers", "ln_idx", "embed")},
                "dec": {"attn": dec_attn_ax, "cross": dec_x_ax,
                        "mlp": dec_mlp_ax,
                        "ln": ("layers", "ln_idx", "embed")},
                "final_norm": lnf_ax, "enc_norm": ("embed",)}
        self._axes = axes
        return params

    def init(self, rng):
        return self._build(rng)

    def logical_axes(self):
        if self._axes is None:
            jax.eval_shape(self._build, jax.random.PRNGKey(0))
        return self._axes

    def param_structs(self):
        return jax.eval_shape(self._build, jax.random.PRNGKey(0))

    # ------------------------------------------------------------------
    def encode(self, params, embeds):
        cfg = self.cfg
        x = embeds.astype(jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(x, lp):
            h = L.rmsnorm(x, lp["ln"][0])
            q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
            attn = L.blockwise_attention(q, k, v, causal=False)
            x = x + attn.reshape(b, s, cfg.q_dim) \
                @ lp["attn"]["wo"].astype(x.dtype)
            h2 = L.rmsnorm(x, lp["ln"][1])
            x = x + L.apply_mlp(cfg, lp["mlp"], h2)
            return x, None

        one = jax.checkpoint(one)
        x, _ = jax.lax.scan(one, x, params["enc"])
        return L.rmsnorm(x, params["enc_norm"])

    def _dec_block(self, lp, x, positions, enc_kv, self_kv=None, pos=None):
        """One decoder layer.  Training path: enc_kv=(k,v) precomputed per
        layer; decode path passes self_kv caches + pos."""
        cfg = self.cfg
        b = x.shape[0]
        h = L.rmsnorm(x, lp["ln"][0])
        q, k, v = L.qkv_project(cfg, lp["attn"], h, positions)
        if self_kv is None:
            attn = L.blockwise_attention(q, k, v, causal=True)
            new_self = (k, v)
        else:
            kc, vc = self_kv
            kc = jax.vmap(lambda c, kk, pp: jax.lax.dynamic_update_slice(
                c, kk, (pp, 0, 0)))(kc, k, pos)
            vc = jax.vmap(lambda c, vv, pp: jax.lax.dynamic_update_slice(
                c, vv, (pp, 0, 0)))(vc, v, pos)
            attn = L.decode_attention(q, kc, vc, pos + 1)
            new_self = (kc, vc)
        x = x + attn.reshape(x.shape[:2] + (cfg.q_dim,)) \
            @ lp["attn"]["wo"].astype(x.dtype)
        # cross attention (no RoPE on the kv side; keys already projected)
        h2 = L.rmsnorm(x, lp["ln"][1])
        qx = (h2 @ lp["cross"]["wq"].astype(x.dtype)).reshape(
            x.shape[:2] + (cfg.n_heads, cfg.head_dim))
        ek, ev = enc_kv
        if self_kv is None:
            cross = L.blockwise_attention(qx, ek, ev, causal=False)
        else:
            cross = L.decode_attention(
                qx, ek, ev, jnp.full((b,), ek.shape[1], jnp.int32))
        x = x + cross.reshape(x.shape[:2] + (cfg.q_dim,)) \
            @ lp["cross"]["wo"].astype(x.dtype)
        h3 = L.rmsnorm(x, lp["ln"][2])
        return x + L.apply_mlp(cfg, lp["mlp"], h3), new_self

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V from the encoder output (scan)."""
        cfg = self.cfg
        b, s = enc_out.shape[0], enc_out.shape[1]

        def one(_, lp):
            k = (enc_out @ lp["cross"]["wk"].astype(enc_out.dtype)).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            v = (enc_out @ lp["cross"]["wv"].astype(enc_out.dtype)).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            if cfg.qkv_bias:
                pass
            return None, (k, v)

        _, (ek, ev) = jax.lax.scan(one, None, params["dec"])
        return ek, ev

    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        ek, ev = self._cross_kv(params, enc_out)
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(x, lp_kv):
            lp, k, v = lp_kv
            x, _ = self._dec_block(lp, x, positions, (k, v))
            return x, None

        one = jax.checkpoint(one)
        x, _ = jax.lax.scan(one, x, (params["dec"], ek, ev))
        x = L.apply_norm(cfg, x, params["final_norm"])
        return L.unembed(cfg, params["embed"], x), jnp.float32(0.0)

    def _hidden(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        ek, ev = self._cross_kv(params, enc_out)
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(x, lp_kv):
            lp, k, v = lp_kv
            x, _ = self._dec_block(lp, x, positions, (k, v))
            return x, None

        one = jax.checkpoint(one)
        x, _ = jax.lax.scan(one, x, (params["dec"], ek, ev))
        return L.apply_norm(cfg, x, params["final_norm"])

    def loss(self, params, batch):
        x = self._hidden(params, batch)
        return L.chunked_cross_entropy(self.cfg, x, params["embed"],
                                       batch["labels"])

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        half = max(max_len // 2, 1)
        kvs = (cfg.n_layers, batch, half, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kvs, dt), "v": jnp.zeros(kvs, dt),
                "ek": jnp.zeros(kvs, dt), "ev": jnp.zeros(kvs, dt),
                "len": jnp.zeros((batch,), jnp.int32)}

    def cache_axes(self):
        t = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": t, "v": t, "ek": t, "ev": t, "len": ("batch",)}

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        ek, ev = self._cross_kv(params, enc_out)
        x = L.embed_tokens(params["embed"], batch["tokens"],
                           jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def one(x, lp_kv):
            lp, k, v = lp_kv
            x, (sk, sv) = self._dec_block(lp, x, positions, (k, v))
            return x, (sk.astype(jnp.dtype(cfg.dtype)),
                       sv.astype(jnp.dtype(cfg.dtype)))

        x, (ks, vs) = jax.lax.scan(one, x, (params["dec"], ek, ev))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
        cache = {"k": ks, "v": vs, "ek": ek.astype(jnp.dtype(cfg.dtype)),
                 "ev": ev.astype(jnp.dtype(cfg.dtype)),
                 "len": jnp.full((b,), s, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tok, pos = batch["token"], batch["pos"]
        x = L.embed_tokens(params["embed"], tok, jnp.dtype(cfg.dtype))
        positions = pos[:, None]

        def one(x, inp):
            lp, kc, vc, ek, ev = inp
            x, (kc, vc) = self._dec_block(lp, x, positions, (ek, ev),
                                          self_kv=(kc, vc), pos=pos)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(one, x, (params["dec"], cache["k"],
                                            cache["v"], cache["ek"],
                                            cache["ev"]))
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        return logits, {"k": ks, "v": vs, "ek": cache["ek"],
                        "ev": cache["ev"], "len": cache["len"] + 1}

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        B, S_ = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        dt = jnp.dtype(cfg.dtype)
        half = S_ // 2
        if shape.kind in ("train", "prefill"):
            out = {"embeds": sds((B, half, cfg.d_model), dt),
                   "tokens": sds((B, half), jnp.int32)}
            if shape.kind == "train":
                out["labels"] = sds((B, half), jnp.int32)
            return out
        return {"token": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}

    def cache_specs(self, shape: ShapeSpec):
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))
