"""Neural net primitives (pure JAX, functional, pytree params).

Conventions
-----------
* linear weights are ``[in, out]``; attention projections fuse heads into the
  last axis (``wq: [D, H*dh]``) so one logical axis maps to the TP mesh axis.
* every ``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the
  params pytree with tuples of *logical* axis names consumed by
  ``repro.parallel.sharding``.
* attention for long sequences is blockwise (online-softmax scan over KV
  blocks nested in a scan over Q blocks) so no [S, S] score tensor is ever
  materialized — this is the GSPMD-friendly stand-in for a fused attention
  kernel (see DESIGN.md §3).
* norms and softmax accumulate in float32 regardless of compute dtype.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Any  # pytree of tuples of logical axis names


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(cfg, d: int):
    if cfg.norm == "layernorm":
        return ({"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
                {"scale": ("embed",), "bias": ("embed",)})
    return ({"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)})


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, dh]; positions: [B, S] (int)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(cfg, key, layers: Optional[int] = None):
    """GQA projection params; ``layers`` adds a leading stacked-layer axis."""
    ks = jax.random.split(key, 8)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = jnp.dtype(cfg.param_dtype)

    def mk(k, i, o):
        if layers is None:
            return dense_init(k, i, o, dt)
        subs = jax.random.split(k, layers)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dt))(subs)
    p = {"wq": mk(ks[0], d, qd), "wk": mk(ks[1], d, kvd),
         "wv": mk(ks[2], d, kvd), "wo": mk(ks[3], qd, d)}
    lead = ("layers",) if layers is not None else ()
    ax = {"wq": lead + ("embed", "heads"), "wk": lead + ("embed", "kv"),
          "wv": lead + ("embed", "kv"), "wo": lead + ("heads", "embed")}
    if cfg.qkv_bias:
        zeros = (lambda n: jnp.zeros((layers, n) if layers else (n,),
                                     jnp.float32))
        p.update({"bq": zeros(qd), "bk": zeros(kvd), "bv": zeros(kvd)})
        ax.update({"bq": lead + ("heads",), "bk": lead + ("kv",),
                   "bv": lead + ("kv",)})
    return p, ax


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def qkv_project(cfg, p, x, positions):
    """x [B,S,D] -> q [B,S,H,dh], k/v [B,S,KV,dh] with RoPE applied."""
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, causal: bool, q_block: int = 512,
                        kv_block: int = 512, window: int = 0,
                        kv_positions=None, q_positions=None):
    """Memory-bounded attention via online softmax.

    Under ``parallel.sharding.flash_attention_mode`` (the dry-run's "flash"
    variant) this dispatches the fused-kernel path instead — see
    :func:`fused_attention_acct` and kernels/flash.py.

    q: [B, S, H, dh]; k, v: [B, T, KV, dh] with H = KV * G (GQA).
    Scans over KV blocks inside a scan over Q blocks; running (max, sum, acc)
    implement the streaming softmax.  ``window`` > 0 adds a sliding-window
    mask.  Positions default to arange (prefill); pass explicit positions for
    packed/offset cases.

    Block sizes snap to the geometric sequence ladder
    (``kernels.bucketing.seq_bucket``), never to the raw S/T: two calls
    whose lengths share a bucket then partition the (padded) sequence into
    *identical* block shapes, with padding invisible in the masked online
    softmax (masked lanes contribute exp -> 0, fully-masked blocks scale
    by corr = 1).  This makes right-padding a sequence to its bucket
    bitwise invisible — the property the serving engines' batching and
    the compiled fast path's shape bucketing rely on (DESIGN.md §7, §10).
    """
    from ..kernels.bucketing import seq_bucket
    from ..parallel import sharding as _shctx
    if _shctx.flash_mesh() is not None and q_positions is None \
            and kv_positions is None:
        return fused_attention_acct(q, k, v, causal=causal, window=window,
                                    mesh=_shctx.flash_mesh())
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_block = min(q_block, seq_bucket(S))
    kv_block = min(kv_block, seq_bucket(T))
    nq = -(-S // q_block)
    nk = -(-T // kv_block)
    Sp, Tp = nq * q_block, nk * kv_block

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    scale = dh ** -0.5
    qs = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    ks = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vs = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, Sp - S)), constant_values=-1)
    kpos = jnp.pad(kv_positions, ((0, 0), (0, Tp - T)),
                   constant_values=2 ** 30)

    # [B, n, blk, KV, G, dh] views
    qs = qs.reshape(B, nq, q_block, KV, G, dh)
    ks = ks.reshape(B, nk, kv_block, KV, dh)
    vs = vs.reshape(B, nk, kv_block, KV, dh)
    qpos = qpos.reshape(B, nq, q_block)
    kpos = kpos.reshape(B, nk, kv_block)

    def q_step(_, qi):
        qb, qp = qi  # [B, qb, KV, G, dh], [B, qb]

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki  # [B, kb, KV, dh], [B, kb]
            s = jnp.einsum("bqkgd,btkd->bkgqt", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((B, 1, 1, q_block, kv_block), bool)
            if causal:
                mask &= (qp[:, None, None, :, None]
                         >= kp[:, None, None, None, :])
            if window > 0:
                mask &= (qp[:, None, None, :, None]
                         - kp[:, None, None, None, :]) < window
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard all -inf rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            pexp = jnp.exp(s - m_safe[..., None])
            pexp = jnp.where(mask, pexp, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + jnp.sum(pexp, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", pexp.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), kpos.swapaxes(0, 1)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)  # [B, KV, G, qb, dh]

    _, outs = jax.lax.scan(
        q_step, None, (qs.swapaxes(0, 1), qpos.swapaxes(0, 1)))
    # outs: [nq, B, KV, G, qb, dh] -> [B, S, H, dh]
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, dh)
    return outs[:, :S]


def _np_attention_fwd(q, k, v, causal, window):
    """Pure-numpy GQA attention (callbacks must not re-enter JAX).

    q [B,S,H,dh]; k/v [B,T,KV,dh].  Returns (out [B,S,H,dh], p [B,H,S,T])
    in float32 (p is reused by the backward host fn).
    """
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    v = np.asarray(v, np.float32)
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    ke = np.repeat(k, G, axis=2)
    ve = np.repeat(v, G, axis=2)
    s = np.einsum("bshd,bthd->bhst", q, ke) * dh ** -0.5
    qpos = np.arange(S)[:, None]
    kpos = np.arange(T)[None, :]
    mask = np.ones((S, T), bool)
    if causal:
        mask &= qpos + (T - S) >= kpos      # right-aligned when T > S
    if window > 0:
        mask &= (qpos + (T - S) - kpos) < window
    s = np.where(mask[None, None], s, -np.inf)
    m = s.max(axis=-1, keepdims=True)
    m = np.where(np.isfinite(m), m, 0.0)
    p = np.exp(s - m)
    p = np.where(mask[None, None], p, 0.0)
    p = p / np.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = np.einsum("bhst,bthd->bshd", p, ve)
    return out, p


def _naive_attention_host(causal, window, q, k, v):
    """Host-side oracle the accounting callback executes (numpy in/out)."""
    out, _ = _np_attention_fwd(q, k, v, causal, window)
    return out.astype(np.asarray(q).dtype)


def _attention_bwd_host(causal, window, q, k, v, g):
    """Pure-numpy attention backward: (q,k,v,do) -> (dq,dk,dv)."""
    qf = np.asarray(q, np.float32)
    kf = np.asarray(k, np.float32)
    vf = np.asarray(v, np.float32)
    gf = np.asarray(g, np.float32)
    B, S, H, dh = qf.shape
    KV = kf.shape[2]
    G = H // KV
    _, p = _np_attention_fwd(qf, kf, vf, causal, window)   # [B,H,S,T]
    ve = np.repeat(vf, G, axis=2)
    dv_e = np.einsum("bhst,bshd->bthd", p, gf)             # [B,T,H,dh]
    dp = np.einsum("bshd,bthd->bhst", gf, ve)
    ds = p * (dp - np.sum(dp * p, axis=-1, keepdims=True))
    scale = dh ** -0.5
    ke = np.repeat(kf, G, axis=2)
    dq = np.einsum("bhst,bthd->bshd", ds, ke) * scale
    dk_e = np.einsum("bhst,bshd->bthd", ds, qf) * scale
    # GQA: sum grads over the query heads sharing each kv head
    dk = dk_e.reshape(B, -1, KV, G, dh).sum(axis=3)
    dv = dv_e.reshape(B, -1, KV, G, dh).sum(axis=3)
    return (dq.astype(np.asarray(q).dtype),
            dk.astype(np.asarray(k).dtype),
            dv.astype(np.asarray(v).dtype))


def fused_attention_acct(q, k, v, *, causal: bool, window: int = 0, mesh):
    """Flash attention with fused-kernel HBM *accounting* (dry-run path).

    The whole attention runs inside one ``shard_map``'d ``pure_callback``:
    the compiled HLO then shows a single custom-call per (layer, shard) whose
    operands/results are exactly q, k, v -> out — the HBM traffic of the
    Pallas kernel in kernels/flash.py.  On TPU the same call site dispatches
    the real kernel; the callback body computes the identical oracle, so
    this path also *executes* correctly (tests).

    GQA/TP layout (mirrors how flash kernels are actually sharded):
      - batch over ('pod','data') when divisible;
      - KV % model == 0      -> shard q-heads and kv-heads together;
      - H % model == 0       -> shard q-heads, slice the (replicated) kv
                                heads each shard actually needs;
      - otherwise            -> heads replicated (batch-only sharding).

    Differentiable: bwd is a second shard_map'd callback taking
    (q, k, v, do) -> (dq, dk, dv) — the flash backward interface.
    """
    from jax.sharding import PartitionSpec as P

    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes \
        else 1
    bspec = (batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None)) \
        if batch_axes and B % bsz == 0 else None
    m = mesh.shape["model"] if "model" in names else 1

    shard_kv = m > 1 and KV % m == 0
    shard_q = m > 1 and not shard_kv and H % m == 0 and \
        (G % (H // m) == 0 or (H // m) % G == 0)
    h_spec = "model" if (shard_kv or shard_q) else None
    kv_spec = "model" if shard_kv else None
    h_local = H // m if h_spec else H

    def body(q_s, k_s, v_s):
        if shard_q:
            # slice the kv heads this q-head shard needs (kv replicated)
            idx = jax.lax.axis_index("model")
            kv_count = max(h_local // G, 1)
            start = (idx * h_local) // G
            k_s = jax.lax.dynamic_slice_in_dim(k_s, start, kv_count, axis=2)
            v_s = jax.lax.dynamic_slice_in_dim(v_s, start, kv_count, axis=2)
        out_sds = jax.ShapeDtypeStruct(q_s.shape, q_s.dtype)
        return jax.pure_callback(
            functools.partial(_naive_attention_host, causal, window),
            out_sds, q_s, k_s, v_s, vmap_method="sequential")

    in_specs = (P(bspec, None, h_spec, None),
                P(bspec, None, kv_spec, None),
                P(bspec, None, kv_spec, None))
    out_spec = P(bspec, None, h_spec, None)

    @jax.custom_vjp
    def fa(q, k, v):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_spec)(q, k, v)

    def fa_fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def fa_bwd(res, g):
        q, k, v = res

        def bwd_body(q_s, k_s, v_s, g_s):
            if shard_q:
                idx = jax.lax.axis_index("model")
                kv_count = max(h_local // G, 1)
                start = (idx * h_local) // G
                k_s = jax.lax.dynamic_slice_in_dim(k_s, start, kv_count, 2)
                v_s = jax.lax.dynamic_slice_in_dim(v_s, start, kv_count, 2)

            sds = (jax.ShapeDtypeStruct(q_s.shape, q_s.dtype),
                   jax.ShapeDtypeStruct(k_s.shape, k_s.dtype),
                   jax.ShapeDtypeStruct(v_s.shape, v_s.dtype))
            dq, dk, dv = jax.pure_callback(
                functools.partial(_attention_bwd_host, causal, window),
                sds, q_s, k_s, v_s, g_s, vmap_method="sequential")
            if shard_q:
                # scatter the kv-slice grads back + sum across the q shards
                # that share each kv head
                idx = jax.lax.axis_index("model")
                kv_count = max(h_local // G, 1)
                start = (idx * h_local) // G
                zk = jnp.zeros((q_s.shape[0], k.shape[1], KV, dh), k.dtype)
                dk = jax.lax.dynamic_update_slice_in_dim(zk, dk, start, 2)
                dv = jax.lax.dynamic_update_slice_in_dim(zk, dv, start, 2)
                dk = jax.lax.psum(dk, "model")
                dv = jax.lax.psum(dv, "model")
            return dq, dk, dv

        kv_out = P(bspec, None, kv_spec, None) if not shard_q else \
            P(bspec, None, None, None)
        dq, dk, dv = jax.shard_map(
            bwd_body, mesh=mesh,
            in_specs=in_specs + (out_spec,),
            out_specs=(out_spec, kv_out, kv_out))(q, k, v, g)
        return dq, dk, dv

    fa.defvjp(fa_fwd, fa_bwd)
    return fa(q, k, v)


def _decode_partials_host(window, q, k, v, cache_len, offset):
    """Host oracle for one cache shard: unnormalized flash-decoding
    partials (acc, m, l) over the shard's [offset, offset+T_s) positions.
    Pure numpy — callbacks must not re-enter JAX."""
    q = np.asarray(q, np.float32)
    k = np.asarray(k, np.float32)
    v = np.asarray(v, np.float32)
    B, _, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, 1, KV, G, dh)
    s = np.einsum("bqkgd,btkd->bkgqt", qr, k) * dh ** -0.5
    gidx = np.asarray(offset).reshape(-1, 1) + np.arange(T)[None, :]
    ln = np.asarray(cache_len).reshape(-1, 1)
    valid = gidx < ln
    if window > 0:
        valid &= gidx >= (ln - window)
    s = np.where(valid[:, None, None, None, :], s, -np.inf)
    m = s.max(axis=-1)                                        # [B,KV,G,1]
    msafe = np.where(np.isfinite(m), m, 0.0)
    p = np.where(valid[:, None, None, None, :],
                 np.exp(s - msafe[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = np.einsum("bkgqt,btkd->bqkgd", p, v)
    return (acc.astype(np.float32), m.astype(np.float32),
            l.astype(np.float32))


def fused_decode_attention_acct(q, k_cache, v_cache, cache_len, *,
                                window: int, mesh):
    """Flash-decoding with fused-kernel HBM accounting (dry-run path).

    The cache is read once per shard inside a callback (the kernel's HBM
    traffic); sequence-sharded caches combine per-shard (acc, m, l) partials
    with the standard logsumexp merge across the 'model' axis — exactly the
    flash-decoding split-K schedule, with the tiny combine visible as the
    only collective.
    """
    from jax.sharding import PartitionSpec as P

    B, _, H, dh = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    bsz = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes \
        else 1
    bspec = (batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None)) \
        if batch_axes and B % bsz == 0 else None
    m_sz = mesh.shape["model"] if "model" in names else 1
    shard_kv = m_sz > 1 and KV % m_sz == 0
    shard_seq = m_sz > 1 and not shard_kv and T % m_sz == 0
    t_local = T // m_sz if shard_seq else T

    def body(q_s, k_s, v_s, len_s):
        off = (jax.lax.axis_index("model") * t_local) if shard_seq \
            else jnp.int32(0)
        off = jnp.broadcast_to(off, (len_s.shape[0],))
        kv_l = k_s.shape[2]
        g_l = q_s.shape[2] // kv_l
        sds = (jax.ShapeDtypeStruct((q_s.shape[0], 1, kv_l, g_l, dh),
                                    jnp.float32),
               jax.ShapeDtypeStruct((q_s.shape[0], kv_l, g_l, 1),
                                    jnp.float32),
               jax.ShapeDtypeStruct((q_s.shape[0], kv_l, g_l, 1),
                                    jnp.float32))
        acc, m, l = jax.pure_callback(
            functools.partial(_decode_partials_host, window), sds,
            q_s, k_s, v_s, len_s, off, vmap_method="sequential")
        if shard_seq:
            m_glob = jax.lax.pmax(m, "model")
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_glob, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l = jax.lax.psum(l * corr, "model")
            acc = jax.lax.psum(
                acc * corr[:, None, :, :, :].reshape(
                    acc.shape[0], 1, kv_l, g_l, 1), "model")
        out = acc / jnp.maximum(l[:, None, :, :, :], 1e-30)  # [B,1,kv,g,dh]
        return out.reshape(q_s.shape[0], 1, kv_l * g_l, dh).astype(
            q_s.dtype)

    h_spec = "model" if shard_kv else None
    seq_spec = "model" if shard_seq else None
    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, h_spec, None),
                  P(bspec, seq_spec, h_spec, None),
                  P(bspec, seq_spec, h_spec, None),
                  P(bspec)),
        out_specs=P(bspec, None, h_spec, None),
        check_vma=False)(q, k_cache, v_cache,
                         jnp.broadcast_to(jnp.reshape(cache_len, (-1,)),
                                          (B,)))
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-step attention against a cache.

    q: [B, 1, H, dh]; caches: [B, T, KV, dh]; cache_len: [] or [B] valid
    length (entries >= cache_len are masked).  Direct einsum — the score
    tensor is [B, KV, G, 1, T], small enough at decode time.  Under
    ``flash_attention_mode`` dispatches the flash-decoding accounting path.
    """
    from ..parallel import sharding as _shctx
    if _shctx.flash_mesh() is not None:
        return fused_decode_attention_acct(
            q, k_cache, v_cache, cache_len, window=window,
            mesh=_shctx.flash_mesh())
    B, _, H, dh = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(B, 1, KV, G, dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qr, k_cache,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    idx = jnp.arange(T)
    valid = idx[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window > 0:
        valid &= idx[None, :] >= (jnp.reshape(cache_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, d_ff: Optional[int] = None,
             layers: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)

    def mk(k, i, o):
        if layers is None:
            return dense_init(k, i, o, dt)
        subs = jax.random.split(k, layers)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dt))(subs)

    lead = ("layers",) if layers is not None else ()
    if cfg.act == "silu":  # SwiGLU
        p = {"wi_gate": mk(ks[0], d, f), "wi_up": mk(ks[1], d, f),
             "wo": mk(ks[2], f, d)}
        ax = {"wi_gate": lead + ("embed", "ffn"),
              "wi_up": lead + ("embed", "ffn"), "wo": lead + ("ffn", "embed")}
    else:
        p = {"wi": mk(ks[0], d, f), "wo": mk(ks[2], f, d)}
        ax = {"wi": lead + ("embed", "ffn"), "wo": lead + ("ffn", "embed")}
    return p, ax


def apply_mlp(cfg, p, x):
    if cfg.act == "silu":
        g = x @ p["wi_gate"].astype(x.dtype)
        u = x @ p["wi_up"].astype(x.dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embeddings(cfg, key):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"tok": embed_init(k1, cfg.vocab_size, cfg.d_model, dt)}
    ax = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dt,
                                  scale=cfg.d_model ** -0.5)
        ax["unembed"] = ("embed", "vocab")
    return p, ax


def embed_tokens(p, tokens, dtype):
    return p["tok"].astype(dtype)[tokens]


def unembed(cfg, p, x):
    if cfg.tie_embeddings:
        return x @ p["tok"].astype(x.dtype).T
    return x @ p["unembed"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in float32.  logits [..., V], labels [...] int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(cfg, x, embed_params, labels, mask=None,
                          chunk: int = 256):
    """CE from final *hidden states* with sequence-chunked unembedding.

    The full [B, S, V] logits tensor dominates training-step temp memory at
    production vocab sizes (e.g. kimi-k2: 1M tokens x 163840 vocab in f32 =
    ~640 GB global).  Instead the unembed matmul + logsumexp run per sequence
    chunk under ``jax.checkpoint`` — backward recomputes each chunk's logits,
    so peak live logits shrink by S/chunk at the cost of one extra unembed
    matmul (<2% of step FLOPs for L >= 24).

    x: [B, S, D] (already final-normed); labels: [B, S]; mask: [B, S] or
    None.  Returns mean NLL (masked mean when mask given).
    """
    b, s, d = x.shape
    if s <= chunk or s % chunk != 0:
        logits = unembed(cfg, embed_params, x)
        return softmax_cross_entropy(logits, labels, mask)
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)         # [n, B, c, D]
    ls = labels.reshape(b, n, chunk).swapaxes(0, 1)
    ms = (mask.reshape(b, n, chunk).swapaxes(0, 1) if mask is not None
          else jnp.ones((n, b, chunk), jnp.float32))

    @jax.checkpoint
    def one(carry, inp):
        xi, li, mi = inp
        logits = unembed(cfg, embed_params, xi).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mi
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mi)), None

    (tot, cnt), _ = jax.lax.scan(one, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
