"""Observability: tracing spans, metrics registry, report serialization.

DESIGN.md §14.  Pure host-side Python with zero jax dependencies —
``obs`` sits below ``runtime/`` in the layer map and everything above
may import it.  Disabled observability is free by construction: engines
default to the :data:`NULL_TRACER` / :data:`NULL_METRICS` singletons,
whose methods are allocation-free no-ops
(``benchmarks/obs_overhead.py`` gates this).
"""

from .metrics import (BYTES_BUCKETS, LATENCY_BUCKETS_S, NULL_METRICS,
                      OCCUPANCY_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, NullMetrics)
from .report import ReportBase, to_jsonable
from .trace import (NULL_TRACER, MonotonicClock, NullTracer, TickClock,
                    Tracer, validate_chrome_trace)

__all__ = [
    "BYTES_BUCKETS",
    "LATENCY_BUCKETS_S",
    "NULL_METRICS",
    "NULL_TRACER",
    "OCCUPANCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MonotonicClock",
    "NullMetrics",
    "NullTracer",
    "ReportBase",
    "TickClock",
    "Tracer",
    "to_jsonable",
    "validate_chrome_trace",
]
