"""Shared report serialization: one ``to_dict()``/``to_json()`` for all
engine reports.

Every engine ships a report dataclass (``EngineReport``,
``AdaptiveReport``, ``DecodeReport``, ``FleetReport``,
``SupervisorReport``).  Before DESIGN.md §14 each benchmark rebuilt
those fields into ad-hoc dicts by hand; now the classes mix in
:class:`ReportBase` and every consumer — benchmarks, ``--metrics-out``,
history rows — serializes through the same recursive converter, so
field names in JSON match field names in code by construction.
"""

from __future__ import annotations

import dataclasses
import json


def to_jsonable(obj):
    """Recursively convert dataclasses / numpy scalars / containers into
    plain JSON-serializable Python values.  Tuples become lists; numpy
    scalars and 0-d arrays collapse via ``item()``; mapping keys are
    coerced to ``str``."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: to_jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, (str, bool, int, float)) or obj is None:
        return obj
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return to_jsonable(item())
        except (TypeError, ValueError):
            pass
    return str(obj)


class ReportBase:
    """Mixin giving report dataclasses a uniform serialization surface."""

    def to_dict(self) -> dict:
        return to_jsonable(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)
