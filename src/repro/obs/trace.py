"""Tracing: nested spans + instant events -> Chrome trace-event JSON.

The observability substrate of DESIGN.md §14.  A :class:`Tracer` records
duration spans (``ph="B"``/``"E"`` pairs) and instant events (``ph="i"``)
into a thread-safe in-process buffer and exports them as Chrome
trace-event JSON — the format Perfetto and ``chrome://tracing`` load
directly.  Timestamps come from an injectable clock so tests can produce
byte-stable traces (:class:`TickClock`) while production uses the wall
clock (:class:`MonotonicClock`).

Disabled tracing must be *free*: :data:`NULL_TRACER` is a module-level
singleton whose ``span()`` returns one preallocated no-op context
manager — no dict lookup, no allocation, no branch on a flag — so every
engine can take ``tracer=NULL_TRACER`` as its default and pay nothing
when observability is off (gated by ``benchmarks/obs_overhead.py``).
"""

from __future__ import annotations

import json
import threading
import time


class MonotonicClock:
    """Wall clock: ``time.monotonic`` seconds (the production default)."""

    def __call__(self) -> float:
        return time.monotonic()


class TickClock:
    """Deterministic clock: starts at ``start`` and advances by a fixed
    ``tick`` on every read.  Traces stamped with it are byte-stable
    across runs — the test contract for trace golden files."""

    def __init__(self, start: float = 0.0, tick: float = 1e-3):
        self._now = float(start)
        self._tick = float(tick)

    def __call__(self) -> float:
        now = self._now
        self._now += self._tick
        return now


class _NullSpan:
    """Reusable no-op context manager handed out by :class:`NullTracer`."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled-tracing fast path: every method is a constant-time
    no-op returning preallocated objects.  ``enabled`` lets callers skip
    building expensive span *arguments* (string formatting, nbytes
    sums) when tracing is off."""

    enabled = False

    def span(self, name, tid=0, **args):
        return _NULL_SPAN

    def instant(self, name, tid=0, **args):
        return None

    @property
    def events(self):
        return ()


NULL_TRACER = NullTracer()


class _Span:
    """Context manager emitting a balanced B/E pair around a block."""

    __slots__ = ("_tracer", "_name", "_tid", "_args")

    def __init__(self, tracer, name, tid, args):
        self._tracer = tracer
        self._name = name
        self._tid = tid
        self._args = args

    def __enter__(self):
        self._tracer._emit("B", self._name, self._tid, self._args)
        return self

    def __exit__(self, *exc):
        self._tracer._emit("E", self._name, self._tid, None)
        return False


class Tracer:
    """In-process span/event buffer with Chrome trace-event export.

    ``clock`` is any zero-arg callable returning seconds; timestamps are
    stored as integer microseconds (the trace-event unit).  Appends are
    guarded by a lock so engines running threaded stages may share one
    tracer.
    """

    enabled = True

    def __init__(self, clock=None, pid: int = 1):
        self._clock = clock if clock is not None else MonotonicClock()
        self._pid = int(pid)
        self._events: list[dict] = []
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------

    def _emit(self, ph, name, tid, args) -> None:
        ev = {
            "name": name,
            "ph": ph,
            "pid": self._pid,
            "tid": int(tid),
        }
        if args:
            ev["args"] = args
        # clock read under the lock: stamping and appending atomically
        # keeps ts non-decreasing within every lane even when threads
        # share one tracer (and one TickClock)
        with self._lock:
            ev["ts"] = int(round(self._clock() * 1e6))
            self._events.append(ev)

    def span(self, name: str, tid: int = 0, **args) -> _Span:
        """Open a duration span; use as ``with tracer.span("x", k=v):``."""
        return _Span(self, name, tid, args or None)

    def instant(self, name: str, tid: int = 0, **args) -> None:
        """Record a zero-duration event (scope ``t`` = thread)."""
        ev = {
            "name": name,
            "ph": "i",
            "pid": self._pid,
            "tid": int(tid),
            "s": "t",
        }
        if args:
            ev["args"] = args
        with self._lock:
            ev["ts"] = int(round(self._clock() * 1e6))
            self._events.append(ev)

    # -- export -------------------------------------------------------

    @property
    def events(self) -> tuple:
        with self._lock:
            return tuple(self._events)

    def to_chrome_trace(self) -> dict:
        """The JSON-object form: Perfetto's preferred envelope."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_chrome_trace(), f, indent=1, sort_keys=True)
            f.write("\n")


def validate_chrome_trace(obj) -> list:
    """Schema-check a Chrome trace-event object; returns a list of
    problems (empty == valid).  Checked: the ``traceEvents`` envelope,
    required keys per event, non-decreasing ``ts`` within each
    ``(pid, tid)`` lane, and balanced/properly-nested B/E spans.  This
    is the checker CI's trace-smoke step runs via
    ``tools/trace_summary.py --validate``."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    last_ts: dict = {}
    stacks: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing required key {key!r}")
        ph = ev.get("ph")
        if ph not in ("B", "E", "i", "X", "C", "M"):
            problems.append(f"event {i}: unknown phase {ph!r}")
        ts, lane = ev.get("ts"), (ev.get("pid"), ev.get("tid"))
        if isinstance(ts, (int, float)):
            if lane in last_ts and ts < last_ts[lane]:
                problems.append(
                    f"event {i}: ts {ts} decreases in lane {lane}")
            last_ts[lane] = ts
        elif ts is not None:
            problems.append(f"event {i}: ts must be a number")
        if ph == "B":
            stacks.setdefault(lane, []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.setdefault(lane, [])
            if not stack:
                problems.append(f"event {i}: E without matching B "
                                f"in lane {lane}")
            else:
                stack.pop()
    for lane, stack in stacks.items():
        if stack:
            problems.append(
                f"lane {lane}: {len(stack)} unclosed span(s): {stack}")
    return problems
