"""Metrics: counters, gauges, fixed-bucket histograms -> one JSON snapshot.

The second half of the DESIGN.md §14 observability substrate.  A
:class:`MetricsRegistry` hands out named instruments keyed by
``(name, sorted label items)`` — engines label by ``engine``, QoS
``qos``, and plan hash ``plan`` — and exports everything as a single
JSON-serializable snapshot (``--metrics-out`` in ``launch/serve.py``).

Histograms are fixed-bucket (cumulative-style counts per upper edge
plus overflow, running sum and count) so observation cost is one
bisect + two adds, and snapshots from different runs line up
bucket-for-bucket.  :data:`NULL_METRICS` mirrors :data:`~repro.obs.trace.NULL_TRACER`:
the disabled path returns preallocated no-op instruments so
instrumented engines pay nothing by default.
"""

from __future__ import annotations

import bisect
import json
import threading

# shared bucket ladders (upper edges); seconds / bytes / dimensionless.
# Latency edges span 10 us .. 100 s in half-decade steps — wide enough
# for both the decode engine's per-token ITL and compile wall times.
LATENCY_BUCKETS_S = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
                     1e-1, 3e-1, 1.0, 3.0, 10.0, 30.0, 100.0)
BYTES_BUCKETS = (64, 256, 1024, 4096, 16384, 65536, 262144, 1048576,
                 4194304, 16777216, 67108864)
OCCUPANCY_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Fixed upper-edge buckets + overflow; tracks sum and count."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets=LATENCY_BUCKETS_S):
        edges = tuple(float(b) for b in buckets)
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError("histogram buckets must be strictly "
                             f"increasing, got {buckets!r}")
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)  # [-inf..e0], .., overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class _NullInstrument:
    """One object serving as no-op counter, gauge, and histogram."""

    __slots__ = ()
    value = 0
    sum = 0.0
    count = 0
    mean = 0.0

    def inc(self, n=1):
        return None

    def set(self, v):
        return None

    def observe(self, v):
        return None


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    """Disabled-metrics fast path; mirror of ``NULL_TRACER``."""

    enabled = False

    def counter(self, name, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name, buckets=None, **labels):
        return _NULL_INSTRUMENT

    def snapshot(self):
        return {}


NULL_METRICS = NullMetrics()


class MetricsRegistry:
    """Get-or-create instruments keyed ``(name, sorted labels)``.

    Label values are coerced to ``str`` so plan hashes, ints, and enums
    all key consistently; a name must keep one instrument kind (asking
    for ``counter("x")`` after ``gauge("x")`` raises).
    """

    enabled = True

    def __init__(self):
        self._instruments: dict = {}
        self._kinds: dict = {}
        self._lock = threading.Lock()

    def _get(self, kind, name, labels, factory):
        key = (name, tuple(sorted(
            (k, str(v)) for k, v in labels.items())))
        with self._lock:
            if self._kinds.setdefault(name, kind) != kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{self._kinds[name]}, not {kind}")
            inst = self._instruments.get(key)
            if inst is None:
                inst = self._instruments[key] = factory()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        edges = LATENCY_BUCKETS_S if buckets is None else buckets
        return self._get("histogram", name, labels,
                         lambda: Histogram(edges))

    # -- export -------------------------------------------------------

    def snapshot(self) -> dict:
        """One JSON-serializable dict: ``{name: [{labels, ...}, ...]}``
        with per-kind payloads (counter value / gauge value / histogram
        buckets+counts+sum+count)."""
        with self._lock:
            items = sorted(self._instruments.items())
        out: dict = {}
        for (name, labels), inst in items:
            row: dict = {"labels": dict(labels)}
            if isinstance(inst, Counter):
                row["value"] = inst.value
            elif isinstance(inst, Gauge):
                row["value"] = inst.value
            else:
                row.update(buckets=list(inst.buckets),
                           counts=list(inst.counts),
                           sum=inst.sum, count=inst.count)
            out.setdefault(name, {"kind": self._kinds[name],
                                  "series": []})["series"].append(row)
        return out

    def write(self, path) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")
