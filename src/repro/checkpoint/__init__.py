"""msgpack+zstd pytree checkpoints with async writer and manifest."""

from .store import (CheckpointManager, CorruptCheckpointError,  # noqa: F401
                    available_steps, latest_step, load_tree, save_tree)
