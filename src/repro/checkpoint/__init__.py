"""msgpack+zstd pytree checkpoints with async writer and manifest."""

from .store import (CheckpointManager, available_steps, latest_step,  # noqa: F401
                    load_tree, save_tree)
