"""Pytree checkpointing: msgpack + zstd, integrity manifest, async writer.

Format (one directory per step, ``step_<N>/``):

  tree.msgpack.zst   — flattened pytree: list of (path, dtype, shape, raw
                       little-endian bytes) records, msgpack-framed then
                       zstd-compressed (``zstandard`` is a *soft* dependency:
                       without it, saves fall back to uncompressed payloads
                       and the manifest records ``compression: none``)
  manifest.json      — step, leaf count, total bytes, per-file sha256,
                       user metadata (data step, mesh shape, ...)

Restores are shard-aware: pass ``shardings`` (a pytree of NamedSharding)
and each leaf is ``device_put`` onto its target sharding at load — the
elastic-restart path reshards a checkpoint onto a *different* mesh this way
(runtime/fault_tolerance.py).

The async writer serializes on the caller thread (arrays must be snapshotted
before the step mutates them) but compresses + writes on a background
thread, so the training loop only blocks on ``wait()`` or at the next save.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # soft dependency: checkpoints fall back to uncompressed without it
    import zstandard as zstd
except ModuleNotFoundError:  # pragma: no cover - depends on environment
    zstd = None

_TREE_FILE = "tree.msgpack.zst"
_MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"^step_(\d+)$")


class CorruptCheckpointError(IOError):
    """A checkpoint's bytes do not match its manifest checksums.

    Subclasses ``IOError`` so ``CheckpointManager.restore_latest`` keeps
    treating a corrupt step as "fall back to the previous one" without
    callers having to know about this type."""


def _require_zstd(action: str):
    if zstd is None:
        raise ModuleNotFoundError(
            f"cannot {action}: the optional dependency 'zstandard' is not "
            "installed. Install it (pip install zstandard) or save with "
            "compress=False.")
    return zstd


def _path_str(path) -> str:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def _serialize_tree(tree: Any) -> bytes:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    records = []
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        records.append({
            "path": _path_str(path),
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        })
    return msgpack.packb({"version": 1, "leaves": records})


def _deserialize_records(raw: bytes) -> Dict[str, np.ndarray]:
    obj = msgpack.unpackb(raw)
    out = {}
    for rec in obj["leaves"]:
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        out[rec["path"]] = arr.reshape(rec["shape"])
    return out


def save_tree(tree: Any, directory: str, step: int,
              metadata: Optional[Dict[str, Any]] = None,
              compress: Optional[bool] = None) -> str:
    """Synchronous checkpoint write; returns the step directory.

    ``compress=None`` (default) uses zstd when available and falls back to
    uncompressed otherwise; ``compress=True`` demands zstd and raises a
    clear ``ModuleNotFoundError`` when the module is missing.
    """
    if compress is None:
        compress = zstd is not None
    step_dir = os.path.join(directory, f"step_{step}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    payload = _serialize_tree(tree)
    if compress:
        z = _require_zstd("write a zstd-compressed checkpoint")
        blob = z.ZstdCompressor(level=3).compress(payload)
    else:
        blob = payload
    tree_path = os.path.join(tmp_dir, _TREE_FILE)
    with open(tree_path, "wb") as f:
        f.write(blob)
    manifest = {
        "step": step,
        "compression": "zstd" if compress else "none",
        "bytes_raw": len(payload),
        "bytes_compressed": len(blob),
        "sha256": hashlib.sha256(blob).hexdigest(),
        # content checksum over the *uncompressed* payload: catches
        # corruption the on-disk blob sha cannot (e.g. a tampered blob
        # whose manifest sha was rewritten to match, or a decompressor
        # bug), verified after decompression on every load
        "sha256_raw": hashlib.sha256(payload).hexdigest(),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp_dir, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    # atomic publish: a crash mid-write never yields a half checkpoint
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    return step_dir


def _verify(step_dir: str) -> Dict[str, Any]:
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    with open(os.path.join(step_dir, _TREE_FILE), "rb") as f:
        blob = f.read()
    digest = hashlib.sha256(blob).hexdigest()
    if digest != manifest["sha256"]:
        raise CorruptCheckpointError(
            f"checkpoint {step_dir} corrupt: blob sha mismatch")
    return manifest


def load_tree(directory: str, step: int, like: Any,
              shardings: Optional[Any] = None
              ) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` optionally reshards each leaf."""
    step_dir = os.path.join(directory, f"step_{step}")
    manifest = _verify(step_dir)
    with open(os.path.join(step_dir, _TREE_FILE), "rb") as f:
        blob = f.read()
    # manifests before the soft-import change carry no "compression" key;
    # they were always zstd-compressed
    if manifest.get("compression", "zstd") == "zstd":
        raw = _require_zstd(
            f"load the zstd-compressed checkpoint {step_dir}") \
            .ZstdDecompressor().decompress(blob)
    else:
        raw = blob
    # manifests from before the content-checksum change have no
    # "sha256_raw": skip the check rather than fail old checkpoints
    want_raw = manifest.get("sha256_raw")
    if want_raw is not None and \
            hashlib.sha256(raw).hexdigest() != want_raw:
        raise CorruptCheckpointError(
            f"checkpoint {step_dir} corrupt: content sha mismatch")
    records = _deserialize_records(raw)

    flat_like = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (path, leaf), shard in zip(flat_like, flat_shard):
        key = _path_str(path)
        if key not in records:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = records[key]
        want = jnp.dtype(leaf.dtype)
        np_arr = arr.astype(want) if arr.dtype != want else arr
        if tuple(np_arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {np_arr.shape} != {leaf.shape}")
        if shard is not None:
            leaves.append(jax.device_put(np_arr, shard))
        else:
            leaves.append(jnp.asarray(np_arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, _MANIFEST)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


# ---------------------------------------------------------------------------
# Manager: retention + async writes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointManager:
    """Periodic/async checkpointing with bounded retention."""

    directory: str
    keep: int = 3
    save_interval: int = 100

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- sync API ----
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict[str, Any]] = None) -> str:
        path = save_tree(tree, self.directory, step, metadata)
        self._retain()
        return path

    # ---- async API ----
    def save_async(self, step: int, tree: Any,
                   metadata: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host (blocking) then compress+write in background."""
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                save_tree(host_tree, self.directory, step, metadata)
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._worker = threading.Thread(target=work, daemon=True)
        self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore ----
    def restore_latest(self, like: Any, shardings: Optional[Any] = None):
        """(tree, manifest) from the newest intact checkpoint, else None."""
        self.wait()
        for step in reversed(available_steps(self.directory)):
            try:
                return load_tree(self.directory, step, like, shardings)
            except (IOError, KeyError, ValueError):
                continue  # corrupt/partial: fall back to the previous one
        return None

    def _retain(self):
        steps = available_steps(self.directory)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
