"""Jit'd public wrappers around the Pallas kernels.

Handles: CPU fallback (interpret mode), shape padding to block multiples,
>2-D activations (leading dims are flattened into M), and a convenience
``QuantizedLinear`` record the serving engine stores per weight matrix.

The leading-dim flattening is the serving batch contract (DESIGN.md §7):
``[B, S, K]`` — B requests packed by the batched engine — and ``[S, K]``
hit the identical kernel with rows computed independently, so batching
requests never changes a request's output bits (tests/test_kernels.py::
test_batch_rows_independent).

Trace economics (DESIGN.md §10): on the Pallas path the row count M is
padded up to the geometric ``bucketing.row_bucket`` ladder *before* the
jitted core, so the core's trace cache keys on the bucketed shape — any
two row counts in one bucket share a single trace/compile instead of one
per distinct M.  Zero-padding rows is invisible: each output row depends
only on its own input row, and the pad is sliced off on the way out.
The reference fallback (misaligned K/N — non-production weights) stays
unpadded: there the padding would only buy wasted matmul rows.

On TPU these dispatch the compiled Pallas kernels; on this CPU container the
same kernel bodies run under ``interpret=True`` (numerics identical, speed
irrelevant — tests assert allclose vs ref.py).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import qmm as _qmm
from . import quantize as _quantize
from . import ref as _ref
from .bucketing import row_bucket
from .pallas_env import use_interpret




def _pick_block(dim: int, target: int, quantum: int) -> int:
    """Largest multiple of ``quantum`` <= target that divides ``dim``."""
    b = min(target, dim)
    b -= b % quantum
    while b > quantum and dim % b != 0:
        b -= quantum
    return max(b, quantum) if dim % quantum == 0 else dim


def _bucket_rows(xm: jax.Array):
    """Zero-pad a [M, K] activation's M up to its row bucket.

    Returns (xm [M_bucket, K], true row count).  Done *outside* the jitted
    cores so their trace caches key on the bucketed shape.
    """
    m0 = xm.shape[0]
    mp = row_bucket(m0)
    if mp != m0:
        xm = jnp.pad(xm, ((0, mp - m0), (0, 0)))
    return xm, m0


# the off-fast-path reference matmuls, jitted per exact shape (no row
# bucketing: padding would only waste reference-path compute, and
# misaligned K/N means a non-production weight anyway)
_qmm_ref_jit = jax.jit(_ref.qmm_ref)
_qmm_int4_ref_jit = jax.jit(_ref.qmm_int4_ref)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _qmm_core(xm: jax.Array, codes: jax.Array, scales: jax.Array,
              *, block_m: int, block_n: int, block_k: int,
              interpret: bool) -> jax.Array:
    """Pallas int8 core on a row-bucketed, block-aligned [M, K]."""
    k = xm.shape[-1]
    group = k // scales.shape[0]
    bk = _pick_block(k, block_k, max(group, 128))
    bn = _pick_block(codes.shape[1], block_n, 128)
    return _qmm.qmm(xm, codes, scales, block_m=min(block_m, xm.shape[0]),
                    block_n=bn, block_k=bk, interpret=interpret)


def quantized_matmul(x: jax.Array, codes: jax.Array, scales: jax.Array,
                     *, block_m: int = 256, block_n: int = 256,
                     block_k: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """x [..., K] @ dequant(codes [K, N], scales [K//G, N]) -> [..., N]."""
    interpret = use_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = codes.shape[1]
    xm = x.reshape(-1, k)
    # K/N must be block multiples for the production weights (all assigned
    # configs are 128-aligned); fall back to the reference path when not.
    if k % 128 != 0 or n % 128 != 0 or k % (k // scales.shape[0]) != 0:
        return _qmm_ref_jit(xm, codes, scales).reshape(*lead, n)
    xm, m0 = _bucket_rows(xm)
    # snap block_m to a 128-multiple divisor of the bucketed M so any
    # caller-chosen block size stays legal after row bucketing
    out = _qmm_core(xm, codes, scales,
                    block_m=_pick_block(xm.shape[0], block_m, 128),
                    block_n=block_n, block_k=block_k, interpret=interpret)
    return out[:m0].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def _qmm_int4_core(xm: jax.Array, packed: jax.Array, scales: jax.Array,
                   *, block_m: int, block_n: int, block_k: int,
                   interpret: bool) -> jax.Array:
    """Pallas packed-int4 core on a row-bucketed, block-aligned [M, K]."""
    k = xm.shape[-1]
    group = k // scales.shape[0]
    bk = _pick_block(k, block_k, max(group, 256))
    bn = _pick_block(packed.shape[1], block_n, 128)
    return _qmm.qmm_int4(xm, packed, scales,
                         block_m=min(block_m, xm.shape[0]),
                         block_n=bn, block_k=bk, interpret=interpret)


def quantized_matmul_int4(x: jax.Array, packed: jax.Array,
                          scales: jax.Array, *, block_m: int = 256,
                          block_n: int = 256, block_k: int = 512,
                          interpret: bool | None = None) -> jax.Array:
    """x [..., K] @ dequant(packed [K/2, N], scales) -> [..., N]."""
    interpret = use_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = packed.shape[1]
    xm = x.reshape(-1, k)
    if k % 256 != 0 or n % 128 != 0:
        return _qmm_int4_ref_jit(xm, packed, scales).reshape(*lead, n)
    xm, m0 = _bucket_rows(xm)
    out = _qmm_int4_core(xm, packed, scales,
                         block_m=_pick_block(xm.shape[0], block_m, 128),
                         block_n=block_n, block_k=block_k,
                         interpret=interpret)
    return out[:m0].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("group_size", "bits",
                                             "interpret"))
def group_quantize(w: jax.Array, *, group_size: int = 128, bits: int = 8,
                   interpret: bool | None = None):
    """Fused quantizer; falls back to the jnp reference off the fast path.

    Fast path: K tiles into ``group_size`` groups and N is 128-aligned —
    the fused Pallas quantizer.  Off it, the reference quantizer runs with
    the largest group layout the shape admits:

    * ``k < group_size`` (or any k that still tiles into ``min(g, k)``) —
      one group spanning min(g, k) rows;
    * ``k`` not tileable at all — per-element groups (group_size 1), the
      degenerate layout where every code hits a quantization level exactly.
    """
    interpret = use_interpret() if interpret is None else interpret
    k, n = w.shape
    if k % group_size == 0 and n % 128 == 0:
        return _quantize.group_quantize(w, group_size=group_size, bits=bits,
                                        block_n=_pick_block(n, 512, 128),
                                        interpret=interpret)
    g = min(group_size, k)
    if k % g == 0:
        return _ref.group_quantize_ref(w, group_size=g, bits=bits)
    return _ref.group_quantize_ref(w, 1, bits=bits)


# ---------------------------------------------------------------------------
# Serving-side weight record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    """One HBM-resident quantized weight matrix (int8 or packed int4).

    ``bits`` is the *quantization* bit-width (1..8); the storage
    container follows from it — codes of <= 4 bits are nibble-packed two
    per byte (the int4 kernel dequantizes any code in [-7, 7]), wider
    codes are int8-resident.  This is the per-layer knob the
    mixed-precision serving plans turn (DESIGN.md §8).
    """

    codes: jax.Array            # int8 [K, N] or packed [K/2, N]
    scales: jax.Array           # f32 [K//G, N]
    bits: int                   # quantization bits, 1..8
    k: int                      # logical contraction dim

    def __matmul__(self, other):
        raise TypeError("use .apply(x)")

    def apply(self, x: jax.Array) -> jax.Array:
        if self.bits <= 4:
            return quantized_matmul_int4(x, self.codes, self.scales)
        return quantized_matmul(x, self.codes, self.scales)

    def nbytes(self) -> int:
        import numpy as np
        return (int(np.prod(self.codes.shape)) * self.codes.dtype.itemsize
                + int(np.prod(self.scales.shape)) * 4)


jax.tree_util.register_pytree_node(
    QuantizedLinear,
    lambda q: ((q.codes, q.scales), (q.bits, q.k)),
    lambda aux, ch: QuantizedLinear(ch[0], ch[1], aux[0], aux[1]),
)


def quantize_linear(w: jax.Array, *, bits: int = 8,
                    group_size: int = 128) -> QuantizedLinear:
    """Quantize one [K, N] weight for HBM residency.

    bits <= 4 quantizes at ``bits``-bit levels then packs two codes per
    byte along K (served by the int4 kernel); 5..8 stays int8-resident.
    """
    if not 1 <= bits <= 8:
        raise ValueError(f"kernel residency needs bits in 1..8, got {bits}")
    k = w.shape[0]
    codes, scales = group_quantize(w, group_size=group_size, bits=bits)
    if bits <= 4:
        return QuantizedLinear(codes=_ref.pack_int4_ref(codes),
                               scales=scales, bits=bits, k=k)
    return QuantizedLinear(codes=codes, scales=scales, bits=bits, k=k)
