"""Pallas TPU kernels for the quantization hot path.

  qmm.py       — quantized-weight matmul (int8 / packed-int4 HBM-resident
                 weights, per-group scales, in-VMEM dequant before the MXU)
  quantize.py  — fused absmax group quantizer
  ops.py       — jit'd wrappers (+ CPU interpret fallback, padding,
                 QuantizedLinear record)
  ref.py       — pure-jnp oracles the tests allclose against
"""

from .ops import (QuantizedLinear, group_quantize, quantize_linear,  # noqa: F401
                  quantized_matmul, quantized_matmul_int4)
