"""Pallas TPU kernels for the quantization hot path.

  qmm.py       — quantized-weight matmul (int8 / packed-int4 HBM-resident
                 weights, per-group scales, in-VMEM dequant before the MXU)
  quantize.py  — fused absmax group quantizer (+ the plain-jnp KV-cache
                 quantizer the decode engine traces in-graph)
  decode_attn.py — fused dequant-attend flash-decoding kernel over
                 int8-held KV codes (DESIGN.md §13)
  ops.py       — jit'd wrappers (+ CPU interpret fallback, padding,
                 QuantizedLinear record)
  ref.py       — pure-jnp oracles the tests allclose against
  pallas_env.py — the REPRO_PALLAS_INTERPRET resolver every kernel's
                 ``interpret=None`` default routes through

Batch contract (DESIGN.md §3, §7): activations may carry any number of
leading dimensions — ``[S, K]``, ``[B, S, K]``, deeper stacks — which the
ops.py wrappers flatten into the kernel's M axis and restore on the way
out.  Rows are computed independently, so the batched serving engine
(``runtime/serve_engine.py``) packs many requests into one kernel dispatch
with per-request results bitwise identical to single-request serving.
"""

from .ops import (QuantizedLinear, group_quantize, quantize_linear,  # noqa: F401
                  quantized_matmul, quantized_matmul_int4)
