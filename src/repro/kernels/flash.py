"""Pallas TPU kernel: fused causal/GQA flash attention (forward).

The roofline baseline (EXPERIMENTS.md §Roofline) shows every big cell
memory-bound, dominated by the blockwise-attention score/probability blocks
crossing HBM at fusion boundaries (~2048x-replicated [512,512] f32 tiles per
layer).  The fix is the canonical one: keep the whole online-softmax
recurrence in VMEM.  HBM traffic collapses to q+k+v+out (+lse), which is
what the §Perf "flash" variant accounts.

Layout: q [B, H, S, dh], k/v [B, KV, T, dh], H = KV * G (GQA: the k/v index
map folds the group so KV tiles are fetched once per group — the HBM saving
GQA exists for).  Grid (B*H, nq, nk), kv innermost; m/l/acc scratch persists
across the kv axis and flushes at nk-1 — same accumulation pattern as
qmm.py.  Causal masking skips fully-masked kv tiles via ``pl.when``.

VMEM at defaults (bq=bk=512, dh<=128): q 256K, k/v 512K, acc 256K, scores
2x1MB -> ~3.5 MiB of 16 MiB; dh=256 still fits.

The backward pass stays on the blockwise-XLA path: this paper's hot path is
*inference* (co-inference serving; prefill + decode), and the serving step
never differentiates.  ``flash_attention`` is therefore wrapped in a
``custom_vjp`` whose bwd recomputes with the blockwise reference — training
keeps working, at baseline traffic (documented in DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import use_interpret

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      n_k: int, bq: int, bk: int, causal: bool,
                      window: int, scale: float):
    i = pl.program_id(1)      # q block
    j = pl.program_id(2)      # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bk
    # causal: skip kv tiles strictly above the diagonal band
    run = True
    if causal:
        run = k_start <= q_start + bq - 1

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # [bq, dh]
        k = k_ref[0].astype(jnp.float32)              # [bk, dh]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q [B, H, S, dh]; k, v [B, KV, T, dh]; H = KV * G.  Returns [B,H,S,dh].

    S and T must be multiples of the block sizes (callers pad; all assigned
    shape cells are 128-aligned).
    """
    b, h, s, dh = q.shape
    _, kv, t, _ = k.shape
    assert h % kv == 0, (h, kv)
    g = h // kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    n_q, n_k = s // bq, t // bk
    scale = dh ** -0.5

    kernel = functools.partial(
        _flash_fwd_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal,
        window=window, scale=scale)
    qr = q.reshape(b * h, s, dh)
    kr = k.reshape(b * kv, t, dh)
    vr = v.reshape(b * kv, t, dh)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
            # GQA fold: query head bh -> kv head bh//g (per batch)
            pl.BlockSpec((1, bk, dh),
                         lambda bh, i, j, g=g, h=h, kv=kv:
                         ((bh // h) * kv + (bh % h) // g, j, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda bh, i, j, g=g, h=h, kv=kv:
                         ((bh // h) * kv + (bh % h) // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, dh)


# ---------------------------------------------------------------------------
# differentiable wrapper (bwd = blockwise-XLA recompute; see module docstring)
# ---------------------------------------------------------------------------

def _ref_attention(q, k, v, causal, window):
    """Oracle in the kernel's [B, H, S, dh] layout (GQA expanded)."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    ke = jnp.repeat(k, h // kv, axis=1)
    ve = jnp.repeat(v, h // kv, axis=1)
    sc = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                    ke.astype(jnp.float32)) * dh ** -0.5
    t = ke.shape[2]
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    sc = jnp.where(mask[None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      ve.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """Fused attention: Pallas on TPU, interpret elsewhere (tests)."""
    interpret = use_interpret() if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def _fa_fwd(q, k, v, causal, window, interpret):
    out = flash_attention(q, k, v, causal, window, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_attention(q_, k_, v_, causal,
                                                       window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
