"""Runtime flag for Pallas interpret mode (DESIGN.md §13).

Every Pallas kernel in ``kernels/`` needs the same decision at dispatch
time: run the compiled TPU kernel, or execute the identical kernel body
under ``interpret=True`` (pure-jax evaluation — numerics identical,
speed irrelevant) because no TPU is attached.  Before PR 7 each wrapper
re-derived it from ``jax.default_backend()``; :func:`use_interpret`
centralizes the rule and adds an environment override so CI, containers,
and debugging sessions can force either mode without touching call
sites:

    REPRO_PALLAS_INTERPRET=1      always interpret (CI sets this)
    REPRO_PALLAS_INTERPRET=0      always compile (TPU required)
    REPRO_PALLAS_INTERPRET=auto   interpret iff the backend is not TPU
                                  (the default when unset)

Kernel wrappers keep an explicit ``interpret=`` parameter; ``None``
defers to this resolver.
"""

from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def use_interpret() -> bool:
    """Should Pallas kernels run in interpret mode right now?

    Resolution order: the ``REPRO_PALLAS_INTERPRET`` environment
    variable when set to an explicit boolean, otherwise (``auto`` /
    unset) interpret exactly when the active jax backend is not a TPU.
    Raises ``ValueError`` on an unrecognized value — a silently ignored
    typo here would send CI onto a nonexistent TPU path.
    """
    val = os.environ.get(ENV_VAR, "auto").strip().lower()
    if val in _TRUE:
        return True
    if val in _FALSE:
        return False
    if val not in ("", "auto"):
        raise ValueError(
            f"{ENV_VAR}={val!r}: expected one of 1/0/true/false/auto")
    return jax.default_backend() != "tpu"
