"""Pallas TPU kernel: quantized-weight matmul with in-VMEM dequantization.

The paper's knob — agent-side weight bit-width b̂ — becomes, on TPU, an HBM
*bandwidth* knob: weights stay resident in HBM as int8 (or packed int4) and
are dequantized tile-by-tile in VMEM right before the MXU contraction.  For
the HBM-bound decode shapes this moves the memory roofline term by 2x (int8)
or 4x (int4) vs bf16 weights (see EXPERIMENTS.md §Perf).

Tiling (all MXU-aligned, multiples of 128 on M/N/K):

  grid = (M/bm, N/bn, K/bk)    K innermost -> sequential accumulation
  x tile      [bm, bk]  VMEM   (f32/bf16 activations)
  codes tile  [bk, bn]  VMEM   int8   (or [bk/2, bn] packed int4)
  scales tile [bk/G, bn] VMEM  f32    per-(group, out-channel), G | bk
  acc scratch [bm, bn]  VMEM   f32    (zeroed at k==0, flushed at k==K-1)

VMEM working set at defaults (bm=bn=256, bk=512, G=128):
  x 256*512*4 = 512 KiB, codes 512*256 = 128 KiB, scales 4*256*4 = 4 KiB,
  acc 256*256*4 = 256 KiB  ->  ~0.9 MiB of ~16 MiB VMEM.  Double-buffered
  inputs stay well under budget.

The kernel body is dtype-polymorphic; on this CPU container it is validated
with ``interpret=True`` against ``ref.qmm_ref`` (see tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import use_interpret


# ---------------------------------------------------------------------------
# int8 codes
# ---------------------------------------------------------------------------

def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int,
                group_size: int):
    """One (i, j, k) grid step: acc += x_tile @ dequant(w_tile)."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = w_ref[...]                                    # [bk, bn] int8
    scales = s_ref[...]                                   # [bk//G, bn] f32
    bk, bn = codes.shape
    # dequantize: expand scales along the group axis inside VMEM via a
    # grouped reshape + broadcast multiply — a layout-only expansion the
    # compiler folds into the multiply, where jnp.repeat lowers to a
    # VMEM gather
    w = (codes.astype(jnp.float32).reshape(bk // group_size, group_size, bn)
         * scales[:, None, :]).reshape(bk, bn)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def qmm(x: jax.Array, codes: jax.Array, scales: jax.Array, *,
        block_m: int = 256, block_n: int = 256, block_k: int = 512,
        interpret: "bool | None" = None) -> jax.Array:
    """x [M, K] @ dequant(codes [K, N], scales [K//G, N]) -> [M, N].

    Requires bm | M, bn | N, bk | K and G | bk (callers pad via ops.py).
    """
    interpret = use_interpret() if interpret is None else interpret
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (k, k2)
    n_groups = scales.shape[0]
    assert k % n_groups == 0
    group_size = k // n_groups
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"unpadded shapes m={m} n={n} k={k} vs blocks "
        f"{block_m}/{block_n}/{block_k}")
    assert block_k % group_size == 0, (block_k, group_size)
    n_k = k // block_k

    kernel = functools.partial(_qmm_kernel, n_k=n_k, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales)


# ---------------------------------------------------------------------------
# packed int4 codes (two per byte along K)
# ---------------------------------------------------------------------------

def _qmm_int4_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int,
                     group_size: int):
    """Same contraction, but w_ref holds [bk/2, bn] packed int4 bytes that
    are unpacked (sign-extended) in VMEM before the dequant-matmul."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...].astype(jnp.int32)                 # [bk/2, bn]
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk2, bn = packed.shape
    bk = 2 * bk2
    codes = jnp.stack([lo, hi], axis=1).reshape(bk, bn)       # [bk, bn]
    scales = s_ref[...]
    # same gather-free scale expansion as the int8 body above
    w = (codes.astype(jnp.float32).reshape(bk // group_size, group_size, bn)
         * scales[:, None, :]).reshape(bk, bn)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def qmm_int4(x: jax.Array, packed: jax.Array, scales: jax.Array, *,
             block_m: int = 256, block_n: int = 256, block_k: int = 512,
             interpret: "bool | None" = None) -> jax.Array:
    """x [M, K] @ dequant(packed [K/2, N] int4x2, scales [K//G, N])."""
    interpret = use_interpret() if interpret is None else interpret
    m, k = x.shape
    k2, n = packed.shape
    assert k == 2 * k2, (k, k2)
    n_groups = scales.shape[0]
    group_size = k // n_groups
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % 2 == 0 and block_k % group_size == 0
    n_k = k // block_k

    kernel = functools.partial(_qmm_int4_kernel, n_k=n_k,
                               group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // group_size, block_n),
                         lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales)
