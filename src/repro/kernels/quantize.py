"""Pallas TPU kernel: fused group quantizer (absmax -> scale -> round -> clip).

One pass over the weight matrix produces int8 codes + per-(group, column)
scales without materializing any f32 intermediate in HBM.  This is the
kernel the serving engine runs once at model-load time (and the QAT path
runs per-step on the agent partition), so weights go HBM-resident in low
precision immediately.

Tiling: grid = (K/G, N/bn); each step owns one [G, bn] group tile in VMEM,
reduces absmax over the group axis, writes [G, bn] int8 codes and [1, bn]
f32 scales.  G is the quantization group size (default 128 — one MXU lane
tile), bn defaults to 512 -> ~320 KiB VMEM per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_env import use_interpret


def _group_quant_kernel(w_ref, codes_ref, scale_ref, *, levels: int):
    w = w_ref[...].astype(jnp.float32)                     # [G, bn]
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)      # [1, bn]
    scale = jnp.where(amax > 0, amax / levels, 1.0)
    q = jnp.clip(jnp.round(w / scale), -levels, levels)
    codes_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def group_quantize(w: jax.Array, *, group_size: int = 128, bits: int = 8,
                   block_n: int = 512, interpret: "bool | None" = None):
    """w [K, N] float -> (codes int8 [K, N], scales f32 [K//G, N]).

    Symmetric uniform quantization, matching
    ``repro.core.quantization.quantize`` at per-group granularity and
    ``ref.group_quantize_ref`` exactly.
    """
    interpret = use_interpret() if interpret is None else interpret
    k, n = w.shape
    assert k % group_size == 0, (k, group_size)
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    levels = 2 ** (bits - 1) - 1

    kernel = functools.partial(_group_quant_kernel, levels=levels)
    return pl.pallas_call(
        kernel,
        grid=(k // group_size, n // block_n),
        in_specs=[pl.BlockSpec((group_size, block_n),
                               lambda g, j: (g, j))],
        out_specs=[
            pl.BlockSpec((group_size, block_n), lambda g, j: (g, j)),
            pl.BlockSpec((1, block_n), lambda g, j: (g, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k // group_size, n), jnp.float32),
        ],
        interpret=interpret,
    )(w)


# ---------------------------------------------------------------------------
# KV-cache quantization (decode serving, DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# The decode engine stores each cache entry as int8-held codes + one f32
# scale per head vector: absmax over the trailing head_dim axis, the same
# scale/round/clip rule as ``_group_quant_kernel`` (so the weight and
# cache quantizers share arithmetic).  These are plain jnp functions, not
# pallas_call kernels: they are traced *into* the AOT-compiled decode
# step, where XLA fuses the dequantize into the attention reads — a
# separate kernel launch per step would cost more than it saves at
# decode's [B, 1] arithmetic intensity.

def kv_levels(bits: int) -> int:
    """Symmetric code magnitude at ``bits`` (7 for int4, 127 for int8)."""
    return 2 ** (bits - 1) - 1


def kv_quantize(x: jax.Array, bits: int):
    """x [..., head_dim] float -> (codes int8 [...], scales f32 [... minus last]).

    Absmax granularity is one scale per head vector (the trailing axis),
    i.e. per (layer, row, position, kv_head) for a [L, B, T, KV, dh]
    cache block.  Zero vectors quantize to scale 1.0 / codes 0, so
    padded cache positions round-trip harmlessly.
    """
    levels = kv_levels(bits)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)                   # [...]
    scale = jnp.where(amax > 0, amax / levels, 1.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -levels, levels)
    return q.astype(jnp.int8), scale


def kv_dequantize(codes: jax.Array, scales: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse map: codes [..., dh] int8, scales [...] -> float [..., dh]."""
    return (codes.astype(jnp.float32) * scales[..., None]).astype(dtype)


def kv_cache_bytes(shape, bits: int, *, scale_bytes: int = 4) -> int:
    """Stored size of a quantized [..., head_dim] cache block.

    Codes are billed at the realizable container (int4 nibble-packed for
    <= 4 bits, int8 for 5..8 — ``core.quantization.wire_bytes``), plus
    one f32 scale per head vector.  A >= 16-bit cache is stored raw
    (2-byte entries, no scales).
    """
    from repro.core.quantization import wire_bytes
    n = 1
    for d in shape:
        n *= int(d)
    if bits >= 16:
        return 2 * n
    n_vec = n // int(shape[-1])
    return wire_bytes(n, bits) + scale_bytes * n_vec
