"""Pallas TPU kernel: fused group quantizer (absmax -> scale -> round -> clip).

One pass over the weight matrix produces int8 codes + per-(group, column)
scales without materializing any f32 intermediate in HBM.  This is the
kernel the serving engine runs once at model-load time (and the QAT path
runs per-step on the agent partition), so weights go HBM-resident in low
precision immediately.

Tiling: grid = (K/G, N/bn); each step owns one [G, bn] group tile in VMEM,
reduces absmax over the group axis, writes [G, bn] int8 codes and [1, bn]
f32 scales.  G is the quantization group size (default 128 — one MXU lane
tile), bn defaults to 512 -> ~320 KiB VMEM per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _group_quant_kernel(w_ref, codes_ref, scale_ref, *, levels: int):
    w = w_ref[...].astype(jnp.float32)                     # [G, bn]
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)      # [1, bn]
    scale = jnp.where(amax > 0, amax / levels, 1.0)
    q = jnp.clip(jnp.round(w / scale), -levels, levels)
    codes_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def group_quantize(w: jax.Array, *, group_size: int = 128, bits: int = 8,
                   block_n: int = 512, interpret: bool = False):
    """w [K, N] float -> (codes int8 [K, N], scales f32 [K//G, N]).

    Symmetric uniform quantization, matching
    ``repro.core.quantization.quantize`` at per-group granularity and
    ``ref.group_quantize_ref`` exactly.
    """
    k, n = w.shape
    assert k % group_size == 0, (k, group_size)
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    levels = 2 ** (bits - 1) - 1

    kernel = functools.partial(_group_quant_kernel, levels=levels)
    return pl.pallas_call(
        kernel,
        grid=(k // group_size, n // block_n),
        in_specs=[pl.BlockSpec((group_size, block_n),
                               lambda g, j: (g, j))],
        out_specs=[
            pl.BlockSpec((group_size, block_n), lambda g, j: (g, j)),
            pl.BlockSpec((1, block_n), lambda g, j: (g, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k // group_size, n), jnp.float32),
        ],
        interpret=interpret,
    )(w)
