"""Geometric shape-bucket ladders shared by the kernel wrappers and the
compiled serving fast path (DESIGN.md §10).

Every distinct input shape costs one XLA trace + compile.  Serving traffic
produces an unbounded variety of (batch, seq) shapes, so both the engine
and the quantized-matmul wrappers round shapes *up* to a small geometric
ladder before dispatch: the number of compiled variants is then bounded by
the ladder length, and warm traffic never recompiles.  Right-padding is
behavior-invisible for every consumer here (row-independent matmuls,
causal attention, per-row masked transport — see DESIGN.md §10 for the
bitwise argument).

Two ladders live here so the engine and the kernels stay aligned:

* ``seq_bucket`` — sequence-length ladder ``base * 2^k`` (default base 16)
  used by the serving engines to pad S.
* ``row_bucket`` — kernel M-axis ladder ``128 * 2^k`` (MXU-aligned) used
  by ``ops.quantized_matmul`` to pad the flattened row count.  With the
  default bases and a power-of-two batch quantum, every engine bucket
  maps onto exactly one kernel row bucket.
"""

from __future__ import annotations

from typing import Tuple

DEFAULT_SEQ_BASE = 16
ROW_BASE = 128


def next_geometric(n: int, base: int, ratio: int = 2) -> int:
    """Smallest ``base * ratio^k`` (k >= 0) that is >= ``n``."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if base < 1 or ratio < 2:
        raise ValueError(f"need base >= 1, ratio >= 2, got {base}/{ratio}")
    b = base
    while b < n:
        b *= ratio
    return b


def seq_bucket(s: int, base: int = DEFAULT_SEQ_BASE, ratio: int = 2) -> int:
    """The sequence-length bucket serving pads ``s`` up to."""
    return next_geometric(s, base, ratio)


def seq_ladder(max_s: int, base: int = DEFAULT_SEQ_BASE,
               ratio: int = 2) -> Tuple[int, ...]:
    """Every bucket up to (and including) the one covering ``max_s`` —
    what ``warmup()`` precompiles."""
    out, b = [], base
    top = next_geometric(max_s, base, ratio)
    while b <= top:
        out.append(b)
        b *= ratio
    return tuple(out)


def row_bucket(m: int) -> int:
    """Kernel M-axis bucket: ``128 * 2^k`` (always MXU-block aligned).

    ``ops.quantized_matmul`` pads its flattened row count to this ladder
    *outside* its jit boundary, so any two row counts in one bucket share
    a single trace/compile of the kernel core.
    """
    return next_geometric(m, ROW_BASE)
