"""Pallas TPU kernel: flash-decoding attention over quantized KV codes
(DESIGN.md §13).

The decode hot path reads the whole KV cache every token.  Before PR 7
the traced decode step materialized a dequantized copy of the full
``[L, B, T, KV, dh]`` cache in HBM (``kv_dequantize`` then the einsum of
``layers.decode_attention``) — doubling the cache traffic the b_kv
codesign exists to shrink.  This kernel reads the int8-held codes
directly and dequantizes per-tile in VMEM (the ``qmm.py`` in-VMEM
dequant pattern: ``codes.astype(f32) * scales`` broadcast, gather-free),
so HBM sees only the quantized bytes.

Layout: one query vector per sequence (decode), GQA-folded.  Grid is
``(B * KV, T / bt)`` — one program per (row, kv-head) owning the
``[G, dh]`` query group, kv tiles innermost.  The online-softmax
``m/l/acc`` scratch persists across the tile axis and flushes at the
last tile (``flash.py``'s accumulation pattern).  Cache positions at or
beyond ``cache_len`` are masked; a *fully* masked tile is an exact
no-op on (m, l, acc) — ``max`` over all-NEG_INF scores leaves m, the
correction factor is exp(0) = 1, and the probability tile is exact
zeros — which is what makes cache-bucket padding attention-invisible
bit-for-bit (property-tested in ``tests/test_properties.py``).

The raw b_kv >= 16 container uses the same kernel with all-ones scales:
``x * 1.0`` is exact, so one kernel body serves every rung.

``_tile_update`` holds the per-tile arithmetic and is shared *verbatim*
by the kernel body and the pure-jnp reference
(:func:`quantized_decode_attention_ref`), so kernel-vs-reference parity
is bitwise by construction (``tests/test_decode_kernel.py``).  Off-TPU
the kernel runs under interpret mode (``pallas_env.use_interpret``),
which is how ``DecodeEngine`` and ``greedy_decode_reference`` share it
inside their AOT-compiled step functions on CPU CI.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import use_interpret

NEG_INF = -1e30


def _tile_update(q, k_codes, v_codes, k_scales, v_scales, t_start,
                 cache_len, m, l, acc, *, window: int, scale: float):
    """One kv tile of the online-softmax recurrence, dequant included.

    q [G, dh] f32; k/v codes [bt, dh] (int8 or float); scales [bt] f32;
    m/l [G, 1], acc [G, dh] f32 running state.  Returns the updated
    (m, l, acc).  Shared by the Pallas kernel body (on VMEM refs) and
    the jnp reference (on array slices): identical ops, identical bits.
    """
    bt = k_codes.shape[0]
    g = q.shape[0]
    k = k_codes.astype(jnp.float32) * k_scales[:, None]     # in-VMEM dequant
    v = v_codes.astype(jnp.float32) * v_scales[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = t_start + jax.lax.broadcasted_iota(jnp.int32, (g, bt), 1)
    valid = kpos < cache_len
    if window > 0:
        valid &= kpos >= cache_len - window
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m - m_new)
    l = l * corr + jnp.sum(p, axis=1, keepdims=True)
    acc = acc * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return m_new, l, acc


# Interpret-mode pallas evaluates the kernel body as a jitted
# sub-computation per grid step; the reference must run each tile through
# jit the same way, or XLA's within-tile fusion (fma contraction in the
# l/acc updates) drifts the accumulators by a few ULPs once a second tile
# feeds a nonzero carry.  Single jit cache entry per (window, scale).
_tile_update_jit = jax.jit(_tile_update, static_argnames=("window", "scale"))


def _qdecode_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, n_t: int, bt: int,
                    window: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m, l, acc = _tile_update(
        q_ref[0].astype(jnp.float32), k_ref[0], v_ref[0], ks_ref[0],
        vs_ref[0], j * bt, len_ref[0, 0], m_ref[...], l_ref[...],
        acc_ref[...], window=window, scale=scale)
    m_ref[...] = m
    l_ref[...] = l
    acc_ref[...] = acc

    @pl.when(j == n_t - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _fold_heads(q, k_codes, v_codes, k_scales, v_scales, cache_len):
    """[B, ...] layouts -> the kernel's GQA-folded [B*KV, ...] layouts."""
    b, _, h, dh = q.shape
    t, kv = k_codes.shape[1], k_codes.shape[2]
    g = h // kv
    qr = q.reshape(b, kv, g, dh).reshape(b * kv, g, dh)
    kr = k_codes.transpose(0, 2, 1, 3).reshape(b * kv, t, dh)
    vr = v_codes.transpose(0, 2, 1, 3).reshape(b * kv, t, dh)
    ksr = k_scales.transpose(0, 2, 1).reshape(b * kv, t)
    vsr = v_scales.transpose(0, 2, 1).reshape(b * kv, t)
    lens = jnp.broadcast_to(jnp.reshape(cache_len, (-1, 1)), (b, kv))
    lens = lens.astype(jnp.int32).reshape(b * kv, 1)
    return qr, kr, vr, ksr, vsr, lens


def quantized_decode_attention(q, k_codes, v_codes, k_scales, v_scales,
                               cache_len, *, window: int = 0,
                               block_t: int = 128,
                               interpret: "bool | None" = None):
    """Single-step attention straight over a quantized cache.

    q [B, 1, H, dh]; codes [B, T, KV, dh] (int8 codes, or the raw float
    container for b_kv >= 16); scales [B, T, KV] f32 (ones for raw);
    cache_len [] or [B].  Returns [B, 1, H, dh] in q.dtype — the
    ``layers.decode_attention`` contract, minus the dequantized-cache
    intermediate.  T must be a multiple of the tile size
    ``min(block_t, T)`` (cache buckets are 16·2^k, so it always is).
    """
    interpret = use_interpret() if interpret is None else interpret
    b, _, h, dh = q.shape
    t, kv = k_codes.shape[1], k_codes.shape[2]
    g = h // kv
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    n_t = t // bt
    qr, kr, vr, ksr, vsr, lens = _fold_heads(
        q, k_codes, v_codes, k_scales, v_scales, cache_len)

    kernel = functools.partial(_qdecode_kernel, n_t=n_t, bt=bt,
                               window=window, scale=dh ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, n_t),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, bt, dh), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, bt, dh), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, bt), lambda bh, j: (bh, j)),
            pl.BlockSpec((1, bt), lambda bh, j: (bh, j)),
            pl.BlockSpec((1, 1), lambda bh, j: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda bh, j: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, ksr, vsr, lens)
    return out.reshape(b, 1, h, dh)


def quantized_decode_attention_ref(q, k_codes, v_codes, k_scales, v_scales,
                                   cache_len, *, window: int = 0,
                                   block_t: int = 128):
    """Pure-jnp oracle running the kernel's exact tile schedule.

    Python loops over (row·kv-head) programs and kv tiles, each tile
    evaluated through the *same* :func:`_tile_update` the kernel body
    calls, jitted per tile exactly as interpret mode executes the kernel
    body — so reference and kernel run the identical compiled tile
    computation and match bitwise (``tests/test_decode_kernel.py``
    asserts it per b_kv rung).
    """
    b, _, h, dh = q.shape
    t = k_codes.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    qr, kr, vr, ksr, vsr, lens = _fold_heads(
        q, k_codes, v_codes, k_scales, v_scales, cache_len)
    scale = dh ** -0.5
    g = qr.shape[1]
    rows = []
    for bh in range(qr.shape[0]):
        m = jnp.full((g, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((g, 1), jnp.float32)
        acc = jnp.zeros((g, dh), jnp.float32)
        for j in range(t // bt):
            sl = slice(j * bt, (j + 1) * bt)
            m, l, acc = _tile_update_jit(
                qr[bh].astype(jnp.float32), kr[bh, sl], vr[bh, sl],
                ksr[bh, sl], vsr[bh, sl], j * bt, lens[bh, 0], m, l, acc,
                window=window, scale=scale)
        rows.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
    return jnp.stack(rows).reshape(b, 1, h, dh)
