"""Pure-jnp oracles for the Pallas kernels (the allclose targets in tests).

Shapes / conventions shared with qmm.py and quantize.py:

  x       [M, K]            activations (f32 or bf16)
  codes   [K, N]  int8      quantized weights (int4 values live in [-7, 7])
  scales  [K // G, N] f32   per-(group, out-channel) scales, group size G
                            along the contraction axis
  out     [M, N]            x @ (codes * scales)

``group_quantize_ref`` is the oracle for the fused quantizer kernel:
symmetric absmax scaling per (group, column), matching
``repro.core.quantization`` with scheme='uniform', granularity='per-group'.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequantize_ref(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """[K, N] int8 codes + [K//G, N] scales -> [K, N] f32 weights."""
    k = codes.shape[0]
    g = k // scales.shape[0]
    s_full = jnp.repeat(scales, g, axis=0)
    return codes.astype(jnp.float32) * s_full


def qmm_ref(x: jnp.ndarray, codes: jnp.ndarray,
            scales: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the quantized matmul: dequantize then matmul in f32."""
    w = dequantize_ref(codes, scales)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def group_quantize_ref(w: jnp.ndarray, group_size: int, bits: int = 8):
    """Oracle for the fused group quantizer.

    w: [K, N] float.  Returns (codes int8 [K, N], scales f32 [K//G, N]).
    Symmetric: scale = absmax / (2^(bits-1) - 1), codes = round(w / scale).
    """
    k, n = w.shape
    assert k % group_size == 0, (k, group_size)
    levels = 2 ** (bits - 1) - 1
    wg = w.reshape(k // group_size, group_size, n).astype(jnp.float32)
    amax = jnp.max(jnp.abs(wg), axis=1)                      # [K//G, N]
    scales = jnp.where(amax > 0, amax / levels, 1.0)
    codes = jnp.clip(jnp.round(wg / scales[:, None, :]), -levels, levels)
    return codes.reshape(k, n).astype(jnp.int8), scales


def unpack_int4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """[K//2, N] packed (two 4-bit codes per byte along K) -> [K, N] int8.

    Layout: byte b at row r holds code[2r] in the low nibble, code[2r+1] in
    the high nibble, two's complement.
    """
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.int8)
    hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.int8)
    k2, n = packed.shape
    out = jnp.stack([lo, hi], axis=1)           # [K//2, 2, N]
    return out.reshape(2 * k2, n)


def pack_int4_ref(codes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`unpack_int4_ref` — [K, N] int8 in [-7,7] ->
    [K//2, N] packed bytes."""
    k, n = codes.shape
    assert k % 2 == 0
    c = codes.reshape(k // 2, 2, n)
    lo = c[:, 0].astype(jnp.int32) & 0x0F
    hi = (c[:, 1].astype(jnp.int32) & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def qmm_int4_ref(x: jnp.ndarray, packed: jnp.ndarray,
                 scales: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the int4-packed matmul (unpack along K, then qmm)."""
    codes = unpack_int4_ref(packed)
    return qmm_ref(x, codes, scales)
