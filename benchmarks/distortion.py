"""Paper Fig. 3: model-output distortion vs parameter-distortion bound
across bit-widths, for uniform and PoT-log quantization.

Models: FCDNN-16 (the paper's autoencoder, trained on an MNIST-like synthetic
reconstruction task), BLIP-2 proxy and GIT proxy (reduced decoupled
vision+LM stacks).  For each bit-width we report

  measured   ||f(x,W) - f(x,W_hat)||_1          (output distortion)
  bound      Prop 3.1 chain bound (FCDNN) or H-weighted Taylor surrogate
             (transformers, Remark 3.2)

and assert the paper's two claims: the bound upper-bounds the measurement,
and the gap tightens as bit-width grows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.distortion import (estimate_grad_norm_H, fc_chain_bound,
                                   measured_output_distortion,
                                   param_distortion, taylor_surrogate_bound)
from repro.core.quantization import QuantConfig, quantize_dequantize
from repro.models.fcdnn import apply_fcdnn, init_fcdnn, mse_loss
from repro.models.registry import build_model

from .common import ascii_plot, banner, table

BITS = (2, 3, 4, 5, 6, 8, 10)


def _train_fcdnn(dims, steps=120, seed=0):
    ws = init_fcdnn(jax.random.PRNGKey(seed), dims)
    key = jax.random.PRNGKey(seed + 1)
    x = jax.random.uniform(key, (256, dims[0]))
    x = x / jnp.sum(jnp.abs(x), axis=-1, keepdims=True)  # Assumption 1
    loss_grad = jax.jit(jax.value_and_grad(mse_loss))
    for _ in range(steps):
        _, g = loss_grad(ws, x)
        ws = [w - 0.05 * gw for w, gw in zip(ws, g)]
    return ws, x


def _quantize_list(ws, bits, scheme):
    cfg = QuantConfig(bits=bits, scheme=scheme, granularity="per-tensor")
    return [quantize_dequantize(w, cfg) for w in ws]


def fcdnn_sweep(scheme: str):
    dims = [64, 64, 128, 256, 512, 256, 128, 64, 32,
            64, 128, 256, 512, 256, 128, 64, 64]  # 16 hidden layers
    ws, x = _train_fcdnn(dims)
    rows = []
    for bits in BITS:
        ws_hat = _quantize_list(ws, bits, scheme)
        measured = float(jnp.max(jnp.sum(jnp.abs(
            apply_fcdnn(ws, x) - apply_fcdnn(ws_hat, x)), axis=-1)))
        bound = float(fc_chain_bound(ws, ws_hat))
        rows.append((bits, measured, bound))
    return rows


def transformer_sweep(arch: str, scheme: str):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    n_vis = 16
    batch = {"tokens": toks}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (4, n_vis, cfg.d_model)) * 0.1

    def apply_fn(p, b):
        return model.forward(p, b)[0]

    # H estimated once on the unquantized model (data-driven, as the paper)
    def apply_flat(p, x):
        return apply_fn(p, batch)[:1]

    H = None
    rows = []
    for bits in BITS:
        qcfg = QuantConfig(bits=bits, scheme=scheme,
                           granularity="per-tensor")
        from repro.core.quantization import fake_quantize_tree
        p_hat = fake_quantize_tree(params, qcfg)
        y = apply_fn(params, batch)
        y_hat = apply_fn(p_hat, batch)
        measured = float(jnp.sum(jnp.abs(y - y_hat)) / y.shape[0])
        pd = float(param_distortion(params, p_hat))
        rows.append((bits, measured, pd))
    # empirical H: max over the sweep of measured/param-distortion; the
    # paper "estimates the model-dependent coefficient in a data-driven
    # manner as an empirical upper-bound constant"
    H = max(m / max(p, 1e-12) for _, m, p in rows)
    rows = [(b, m, H * p) for b, m, p in rows]
    return rows, H


def _report(name, rows):
    ok_bound = all(m <= b * (1 + 1e-5) for _, m, b in rows)
    gaps = [b / max(m, 1e-12) for _, m, b in rows]
    tightens = gaps[-1] <= gaps[0] * 1.5
    table(["bits", "output distortion", "param bound", "bound/measured"],
          [[b, f"{m:.4g}", f"{bd:.4g}", f"{bd / max(m, 1e-12):.2f}"]
           for b, m, bd in rows])
    print(f"  bound holds everywhere: {ok_bound}; "
          f"gap at b=2: {gaps[0]:.1f}x -> b={rows[-1][0]}: {gaps[-1]:.1f}x")
    ascii_plot({"measured": [m for _, m, _ in rows],
                "bound": [bd for _, _, bd in rows]},
               [float(b) for b, _, _ in rows], logy=True,
               xlabel="bit-width", ylabel="L1 distortion")
    return ok_bound


def run() -> dict:
    out = {}
    for scheme in ("uniform", "pot-log"):
        banner(f"Fig. 3 — FCDNN-16, {scheme} quantization "
               "(Prop 3.1 chain bound)")
        rows = fcdnn_sweep(scheme)
        out[f"fcdnn/{scheme}"] = _report("fcdnn", rows)
        for arch in ("blip2-proxy", "git-proxy"):
            banner(f"Fig. 3 — {arch}, {scheme} (Taylor surrogate, eq. 17)")
            rows, H = transformer_sweep(arch, scheme)
            print(f"  empirical H = {H:.3g}")
            out[f"{arch}/{scheme}"] = _report(arch, rows)
    return out


if __name__ == "__main__":
    run()
