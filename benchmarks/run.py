"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig3,...]

Sections (paper artifact -> module):
    fig2    weight-magnitude exponential fit        weight_stats.py
    fig3    output vs parameter distortion          distortion.py
    fig4    distortion-rate bounds vs BA            rd_bounds.py
    fig5-8  CIDEr vs (T0, E0), 4 schemes            codesign_sweep.py
    table1  coarse frequency profiles               testbed_profiles.py
    kernels quantized-matmul TPU economics          kernel_bench.py
    serve   batched co-inference throughput         serve_throughput.py
            (also writes BENCH_serve.json at the repo root: req/s,
             batch size, bit-width, measured distortion — the
             machine-readable perf record diffed across PRs)
    mixed   per-layer bit allocation vs uniform     mixed_precision_sweep.py
    adaptive static/oracle/adaptive serving on a     adaptive_serve.py
            dynamic link/thermal/battery trace
            (also writes BENCH_adaptive.json at the repo root)
    fastpath eager vs AOT-compiled serving wall      fastpath.py
            clock + compile-count bound
            (also writes BENCH_fastpath.json at the repo root; raises
             on acceptance or throughput regression)
    fleet   joint vs equal-split shared-server       fleet.py
            allocation across heterogeneous agents
            (also writes BENCH_fleet.json at the repo root; raises if
             joint stops beating equal-split or the single-agent fleet
             loses bitwise identity)
    decode  continuous-batching vs FIFO-barrier      decode.py
            greedy decode over a quantized KV cache
            (also writes BENCH_decode.json at the repo root; raises if
             continuous admission stops beating the barrier, decode
             parity breaks, or warm traffic compiles)
    obs_overhead decode tok/s traced vs untraced      obs_overhead.py
            (also writes BENCH_obs.json at the repo root; raises if
             enabled tracing costs more than 3%, the disabled no-op
             path is not free, or tracing perturbs a single token)
    chaos   supervised vs bare decode under a seeded  chaos.py
            fault trace (outages, crashes)
            (also writes BENCH_chaos.json at the repo root; raises if
             the supervisor stops beating the bare engine, loses or
             duplicates tokens, recovered streams break bitwise
             parity, or the clean-trace pass-through costs over 3%)
    speculative quantized-draft/verify rounds vs      speculative.py
            fused decode across the (b_draft, k) grid
            (also writes BENCH_spec.json at the repo root; raises if
             speculation stops beating fused decode on modeled tok/s
             at the chosen point, any grid point loses bitwise parity,
             the codesign stops preferring the speculative solution,
             or warm traffic compiles)
"""

from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import subprocess
import sys
import time

from . import (adaptive_serve, chaos, codesign_sweep, decode, distortion,
               fastpath, fleet, kernel_bench, mixed_precision_sweep,
               obs_overhead, rd_bounds, serve_throughput, speculative,
               testbed_profiles, weight_stats)
from .common import banner

SECTIONS = {
    "fig2": ("Fig. 2  weight statistics", weight_stats.run),
    "fig3": ("Fig. 3  distortion approximation", distortion.run),
    "fig4": ("Fig. 4  rate-distortion bounds", rd_bounds.run),
    "fig5-8": ("Figs 5-8  joint co-design sweeps", codesign_sweep.run),
    "table1": ("Table I  coarse frequency profiles", testbed_profiles.run),
    "kernels": ("Kernels  quantized matmul", kernel_bench.run),
    "serve": ("Serving  batched vs sequential throughput",
              serve_throughput.run),
    "mixed": ("Mixed precision  allocated plans vs uniform b̂",
              mixed_precision_sweep.run),
    "adaptive": ("Adaptive serving  static vs oracle vs adaptive on a "
                 "dynamic trace", adaptive_serve.run),
    "fastpath": ("Fast path  eager vs compiled serving wall clock",
                 fastpath.run),
    "fleet": ("Fleet  joint vs equal-split shared-server allocation",
              fleet.run),
    "decode": ("Decode  continuous-batching vs FIFO-barrier over a "
               "quantized KV cache", decode.run),
    "obs_overhead": ("Observability  decode tok/s traced vs untraced "
                     "(3% gate, bitwise parity)", obs_overhead.run),
    "chaos": ("Chaos  supervised vs bare decode under a seeded fault "
              "trace", chaos.run),
    "speculative": ("Speculative  quantized drafts vs fused decode at a "
                    "matched operating point", speculative.run),
}


# the one number per section worth tracking across commits: the first
# of these keys present in the section's result dict lands in
# BENCH_history.jsonl
_METRIC_KEYS = ("speedup", "throughput_ratio", "ratio", "tps",
                "throughput_tps", "acceptance_ok")

# BENCH_history.jsonl row schema: bumped to 2 when the rows gained
# explicit schema_version/units fields and the optional metrics
# snapshot (DESIGN.md §14); v1 rows (no schema_version key) predate it
_HISTORY_SCHEMA_VERSION = 2

# units for each trackable metric, so a history row is interpretable
# without chasing the producing section's source
_METRIC_UNITS = {
    "speedup": "ratio", "throughput_ratio": "ratio", "ratio": "ratio",
    "tps": "tokens/s", "throughput_tps": "tokens/s",
    "acceptance_ok": "bool",
}


def _git_sha() -> "str | None":
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent.parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except OSError:
        return None


def _key_metric(result) -> "tuple[str, object] | None":
    if not isinstance(result, dict):
        return None
    for k in _METRIC_KEYS:
        if k in result:
            return k, result[k]
    # one level down covers sections that nest (e.g. throughput tables)
    for outer, v in result.items():
        if isinstance(v, dict):
            for k in _METRIC_KEYS:
                if k in v:
                    return f"{outer}.{k}", v[k]
    return None


def append_history(section: str, result, seconds: float,
                   path: "pathlib.Path | None" = None) -> None:
    """Append one line per section run to ``BENCH_history.jsonl`` at the
    repo root: section, its key metric, the git SHA, wall seconds.  An
    append-only log (never rewritten, unlike the BENCH_*.json records),
    so perf across the PR stack stays greppable without archaeology."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_history.jsonl"
    metric = _key_metric(result)
    entry = {
        "schema_version": _HISTORY_SCHEMA_VERSION,
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "git_sha": _git_sha(),
        "section": section,
        "metric": metric[0] if metric else None,
        "value": metric[1] if metric else None,
        "units": _METRIC_UNITS.get(
            metric[0].rsplit(".", 1)[-1]) if metric else None,
        "seconds": round(seconds, 3),
    }
    # sections that serve through instrumented engines may attach a
    # MetricsRegistry snapshot under "metrics" (DESIGN.md §14) — carry
    # it onto the history row so counter/histogram series stay greppable
    # across the PR stack alongside the headline number
    if isinstance(result, dict) and isinstance(result.get("metrics"),
                                               dict):
        entry["metrics"] = result["metrics"]
    with path.open("a", encoding="utf-8") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of sections (default: all)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip appending to BENCH_history.jsonl")
    args = ap.parse_args(argv)
    picks = args.only.split(",") if args.only else list(SECTIONS)

    t0 = time.monotonic()
    failures = []
    for key in picks:
        title, fn = SECTIONS[key]
        banner(f"[{key}] {title}")
        t_sec = time.monotonic()
        try:
            result = fn()
        except Exception as e:  # noqa: BLE001 - keep the harness going
            failures.append((key, repr(e)))
            print(f"!! section {key} failed: {e!r}")
        else:
            if not args.no_history:
                append_history(key, result, time.monotonic() - t_sec)
    dt = time.monotonic() - t0
    print(f"\n{'=' * 72}\nbenchmarks done in {dt / 60:.1f} min; "
          f"{len(picks) - len(failures)}/{len(picks)} sections ok")
    for key, err in failures:
        print(f"  FAILED {key}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
