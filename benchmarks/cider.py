"""CIDEr score (paper eq. 37): consensus-based n-gram TF-IDF cosine.

Exact implementation of Vedantam et al. 2015 on integer token sequences:
g_n(s) is the TF-IDF-weighted n-gram count vector (IDF over the reference
corpus), CIDEr_n the mean cosine against the m references, and the overall
score averages n = 1..4 (x10 per convention).
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Sequence, Tuple

Ngram = Tuple[int, ...]


def _ngrams(seq: Sequence[int], n: int) -> Counter:
    return Counter(tuple(seq[i:i + n]) for i in range(len(seq) - n + 1))


def _idf_tables(all_refs: List[List[List[int]]], max_n: int
                ) -> List[Dict[Ngram, float]]:
    """IDF per n over reference *images* (document = one image's ref set)."""
    n_docs = len(all_refs)
    tables: List[Dict[Ngram, float]] = []
    for n in range(1, max_n + 1):
        df: Counter = Counter()
        for refs in all_refs:
            seen = set()
            for ref in refs:
                seen |= set(_ngrams(ref, n).keys())
            df.update(seen)
        tables.append({g: math.log(max(n_docs, 1) / d)
                       for g, d in df.items()})
    return tables


def _tfidf(counts: Counter, idf: Dict[Ngram, float]) -> Dict[Ngram, float]:
    total = sum(counts.values()) or 1
    return {g: (c / total) * idf.get(g, 0.0) for g, c in counts.items()}


def _cosine(a: Dict[Ngram, float], b: Dict[Ngram, float]) -> float:
    dot = sum(v * b.get(g, 0.0) for g, v in a.items())
    na = math.sqrt(sum(v * v for v in a.values()))
    nb = math.sqrt(sum(v * v for v in b.values()))
    if na == 0 or nb == 0:
        return 0.0
    return dot / (na * nb)


def cider(candidates: List[List[int]], references: List[List[List[int]]],
          max_n: int = 4) -> float:
    """Corpus CIDEr: mean over samples of mean over n of eq. (37), x10."""
    assert len(candidates) == len(references)
    idf = _idf_tables(references, max_n)
    total = 0.0
    for cand, refs in zip(candidates, references):
        per_n = []
        for n in range(1, max_n + 1):
            gc = _tfidf(_ngrams(cand, n), idf[n - 1])
            sims = [_cosine(gc, _tfidf(_ngrams(r, n), idf[n - 1]))
                    for r in refs]
            per_n.append(sum(sims) / max(len(sims), 1))
        total += sum(per_n) / max_n
    return 10.0 * total / max(len(candidates), 1)
