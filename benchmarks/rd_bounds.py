"""Paper Fig. 4: distortion-rate bounds D^L / D^U vs Blahut-Arimoto D(R).

Sweeps the rate axis for a lambda fitted from real model weights, verifies
D^L <= D(R) <= D^U in the valid window, and reports where the upper bound
becomes tight (the paper: "larger than 2 bits").
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.rate_distortion import (blahut_arimoto_distortion_rate,
                                        distortion_lower_bound,
                                        distortion_upper_bound,
                                        exponential_mle)
from repro.models.registry import build_model

from .common import ascii_plot, banner, table
from .weight_stats import magnitudes


def run() -> dict:
    banner("Fig. 4 — distortion-rate function: bounds vs Blahut-Arimoto")
    params = build_model(get_smoke("stablelm-3b")).init(
        jax.random.PRNGKey(0))
    import jax.numpy as jnp
    lam = float(exponential_mle(jnp.asarray(magnitudes(params))))
    print(f"lambda fitted from stablelm-3b smoke weights: {lam:.2f}")

    res = blahut_arimoto_distortion_rate(lam, n_source=256, n_repro=256,
                                         n_iters=250)
    mask = (res.rates > 0.3) & (res.rates < 4.0)
    rates = res.rates[mask]
    ba = res.distortions[mask]
    dl = np.array([float(distortion_lower_bound(r, lam)) for r in rates])
    du = np.array([float(distortion_upper_bound(r, lam)) for r in rates])

    order = np.argsort(rates)
    rates, ba, dl, du = rates[order], ba[order], dl[order], du[order]

    inside = np.mean((ba >= dl * 0.9) & (ba <= du * 1.1))
    tight_rate = None
    for r, b, u in zip(rates, ba, du):
        if u <= 1.6 * max(b, 1e-12):
            tight_rate = r
            break

    table(["rate (bits)", "D^L", "D(R) [BA]", "D^U", "D^U/D(R)"],
          [[f"{r:.2f}", f"{l:.5f}", f"{b:.5f}", f"{u:.5f}",
            f"{u / max(b, 1e-12):.2f}"]
           for r, l, b, u in zip(rates[::3], dl[::3], ba[::3], du[::3])])
    ascii_plot({"D^L": list(dl), "BA D(R)": list(ba), "D^U": list(du)},
               list(rates), logy=True, xlabel="rate (bits/param)",
               ylabel="distortion")
    print(f"\nBA inside [0.9 D^L, 1.1 D^U]: {inside:.0%} of sweep points")
    if tight_rate is not None:
        print(f"D^U within 1.6x of D(R) from rate ~{tight_rate:.2f} bits "
              "(paper: 'increasingly tight beyond ~2 bits')")
    else:
        print("D^U/D(R) stays above 1.6x across this sweep window "
              "(tightness sets in just past it; see table)")
    return {"lambda": lam, "frac_inside": float(inside),
            "tight_rate": tight_rate}


if __name__ == "__main__":
    run()
