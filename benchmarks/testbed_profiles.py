"""Paper Table I: coarse frequency profiles (low / medium / high).

The paper's testbed (Jetson AGX Orin) cannot set f continuously, so it
evaluates three discrete profiles and shows: under a *delay* constraint the
high profile wins (more headroom -> larger b̂), under an *energy* constraint
the low profile wins (f² energy penalty forces aggressive quantization at
high f).  We reproduce that structure with the same machinery: per profile,
the largest feasible b̂ given the constraint, mapped to real CIDEr of the
trained proxy captioner at that b̂.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.cost_model import SystemParams, total_delay, total_energy
from repro.configs import blip2_proxy, git_proxy

from .codesign_sweep import QualityOracle, _sysparams
from .common import banner, table

PROFILES = {"low": 0.6e9, "medium": 1.2e9, "high": 2.0e9}


def best_bits_fixed_f(p: SystemParams, f: float, t0: float, e0: float
                      ) -> Optional[int]:
    """Largest b̂ feasible at device frequency f (server f~ optimized)."""
    for b_hat in range(16, 0, -1):
        # server frequency: cheapest that still meets the deadline
        t_a = b_hat * p.n_flop_agent / (p.b_full * f * p.c_agent)
        slack = t0 - t_a
        if slack <= 0:
            continue
        fs_min = p.n_flop_server / (p.c_server * slack)
        fs = min(max(fs_min, 1e6), p.f_server_max)
        t = float(total_delay(b_hat, f, fs, p))
        e = float(total_energy(b_hat, f, fs, p))
        if t <= t0 * (1 + 1e-9) and e <= e0 * (1 + 1e-9):
            return b_hat
    return None


def run_model(arch: str, n_flop_total: float) -> Dict:
    oracle = QualityOracle(arch, "uniform")
    cfg = oracle.cfg
    p = _sysparams(n_flop_total, cfg.split_layer / cfg.n_layers)

    delay_grid = [1.15, 1.25, 1.40]       # energy-sufficient (E0 = 50 J)
    energy_grid = [0.30, 0.45, 0.70]      # delay-sufficient  (T0 = 10 s)

    banner(f"Table I — {arch}: coarse profiles, delay-limited "
           "(energy-sufficient)")
    rows = []
    for name, f in PROFILES.items():
        row = [name]
        for t0 in delay_grid:
            b = best_bits_fixed_f(p, f, t0, e0=50.0)
            row.append(f"{oracle.score(b):.1f} (b̂={b})" if b else "inf.")
        rows.append(row)
    table(["profile"] + [f"T0={t}s" for t in delay_grid], rows)

    banner(f"Table I — {arch}: coarse profiles, energy-limited "
           "(delay-sufficient)")
    rows_e = []
    for name, f in PROFILES.items():
        row = [name]
        for e0 in energy_grid:
            b = best_bits_fixed_f(p, f, t0=10.0, e0=e0)
            row.append(f"{oracle.score(b):.1f} (b̂={b})" if b else "inf.")
        rows_e.append(row)
    table(["profile"] + [f"E0={e}J" for e in energy_grid], rows_e)

    # the paper's qualitative claims
    def score_at(rows, prof_idx, col):
        cell = rows[prof_idx][col]
        return -math.inf if cell == "inf." else float(cell.split(" ")[0])

    hi_wins_delay = all(
        score_at(rows, 2, c) >= score_at(rows, 0, c) - 1e-9
        for c in (1, 2, 3))
    lo_wins_energy = all(
        score_at(rows_e, 0, c) >= score_at(rows_e, 2, c) - 1e-9
        for c in (1, 2, 3))
    print(f"\n  delay-limited: high-frequency profile >= low: "
          f"{hi_wins_delay}")
    print(f"  energy-limited: low-frequency profile >= high: "
          f"{lo_wins_energy}")
    return {"hi_wins_delay": hi_wins_delay,
            "lo_wins_energy": lo_wins_energy}


def run() -> dict:
    out = {}
    for arch, flops in (("blip2-proxy", blip2_proxy.N_FLOP_FIRST_TOKEN),
                        ("git-proxy", git_proxy.N_FLOP_FIRST_TOKEN)):
        out[arch] = run_model(arch, flops)
    return out


if __name__ == "__main__":
    run()
