"""Speculative co-inference: quantized agent drafts, server verifies
(DESIGN.md §16).

Measures, on the ``qwen2_0_5b`` smoke config:

  1. the (b_draft × k) operating grid: one ragged request stream is
     decoded through ``SpeculativeDecodeEngine`` at every draft
     bit-width b_draft ∈ {2, 4, 8} × lookahead k ∈ {2, 4, 8}, all
     pinned at the SAME forward operating point (b̂, f, f̃, b_kv) the
     decode codesign picks — speculation is purely a *scheduling*
     change over identical arithmetic, exactly how ``decode.py``
     isolates admission policy — so the modeled-throughput ratio is
     deterministic.  Per point: modeled tok/s, wall tok/s, measured
     acceptance and accepted-prefix length.  Acceptance: the
     throughput-chosen grid point strictly beats the fused-decode
     baseline on modeled tok/s, and measured acceptance is monotone in
     b_draft at every k (the §16 estimator's core shape).
  2. the codesign extension: ``solve_speculative`` must return a
     strictly lower distortion bound per expected delivered token than
     ``solve_decode`` under the same (T0, E0) budgets — the paper-level
     claim the (b_draft, k, f) joint variables exist to deliver.  Its
     pick maximizes bound-amortization (large k), the throughput pick
     minimizes round latency (small k); BENCH_spec.json records both.
  3. bitwise parity: every delivered stream at every grid point must
     equal the non-batched sequential reference token for token —
     drafting never changes the bits (the house invariant, extended).
  4. the compile-count bound: after ``warmup()``, ragged traffic never
     compiles again, and total variants stay within prefill pairs ×
     n_kv + spec-round rungs × n_kv — strictly inside the ladder ×
     {draft, verify} budget (the fused round is ONE executable per
     rung, not two).

Wall tok/s is reported per grid point and regression-floored against
the committed record, but the speculative-vs-decode gate holds the
MODELED ratio: the harness realizes drafts as fake-quantized forwards
(same FLOPs as the target — quantized arithmetic is not faster under
the interpret backend), so executed work per delivered token is
structurally ≥ plain decode's 1 + k/τ steps; the wall win needs
hardware where b_draft arithmetic is actually cheaper, which is
exactly what the virtual clock models (``cost_model.draft_delay``).

Besides the printed tables, ``run()`` writes machine-readable
``BENCH_spec.json`` at the repo root and RAISES if the acceptance
criteria fail or the speculative/decode throughput ratio regresses by
more than ``REGRESSION_TOLERANCE`` against the committed record (CI
runs this section on every PR, mirroring ``decode.py``).

Run:  PYTHONPATH=src python -m benchmarks.run --only speculative
  or  PYTHONPATH=src python benchmarks/speculative.py
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.kernels.bucketing import seq_ladder
from repro.models.registry import build_model
from repro.obs import NULL_METRICS, MetricsRegistry
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           SpeculativeDecodeEngine,
                           greedy_decode_reference)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 24                 # max prompt length
MAX_NEW = 24             # max generation budget (longer than decode.py:
MIN_NEW = 8              # the draft/verify economics live in the decode
MAX_BATCH = 4            # phase, so the stream must spend time there)
N_REQUESTS = 20
DRAFT_GRID = (2, 4, 8)
LOOKAHEAD_GRID = (2, 4, 8)
# the speculative/decode modeled ratio is virtual-clock deterministic;
# the slack only absorbs intentional cost-model re-tuning
REGRESSION_TOLERANCE = 0.9
# wall tok/s is measured, so its floor absorbs machine jitter
WALL_TOLERANCE = 0.5
CLASSES = [
    QosClass("realtime", t0=1.2, e0=1.0),
    QosClass("interactive", t0=3.5, e0=2.0),
]


def make_sysp(cfg) -> SystemParams:
    """Smoke-scale FLOPs plus a KV-cost term sized so b_kv is a real
    decision.  The cache stream gets 2x ``decode.py``'s bandwidth: a
    speculative round moves k+1 cache streams per ~τ delivered tokens
    where plain decode moves one per token, so the single-stream choke
    would drown the draft/verify trade-off this sweep is about."""
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=2.0 * kv_full, kv_power_w=2.0)


def traffic(cfg, seed: int = 7):
    """One ragged high-rate stream, generation-heavy: budgets in
    [MIN_NEW, MAX_NEW] keep requests in the decode phase long enough
    for accepted prefixes to matter."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        out.append((toks.astype(np.int32),
                    CLASSES[i % len(CLASSES)].name,
                    int(rng.integers(MIN_NEW, MAX_NEW + 1)),
                    0.01 * i))
    return out


def drain(eng, cfg):
    """Submit the canonical stream and drain; wall timed around the
    drain only (warmup/compiles excluded: steady-state throughput)."""
    prompts = {}
    for toks, qos, n_new, t in traffic(cfg):
        rid = eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)
        prompts[rid] = toks
    t0 = time.perf_counter()
    responses = eng.drain()
    wall_s = time.perf_counter() - t0
    return eng.report(), responses, prompts, wall_s


def spec_engine(model, params, sysp, points, b_draft, k, cache,
                metrics=NULL_METRICS, lam=None, lam_kv=None):
    """A speculative engine pinned at the decode codesign's forward
    operating point per class, drafting at (b_draft, k)."""
    eng = SpeculativeDecodeEngine(
        model, params, sysp, classes=CLASSES, max_batch=MAX_BATCH,
        max_new_tokens=MAX_NEW, compile_cache=cache, metrics=metrics,
        draft_bits=b_draft, lookahead=k, lam=lam, lam_kv=lam_kv)
    for q in CLASSES:
        b_hat, b_kv, f, f_server = points[q.name]
        eng.set_operating_point(q.name, b_hat, b_kv, b_draft=b_draft,
                                k=k, f=f, f_server=f_server, qos=q)
    eng.warmup(SEQ)
    return eng


def verify_parity(model, eng, responses, prompts, refs, ref_cache):
    """Every delivered stream must equal the sequential reference; the
    reference per (request, qos) is memoized — the pinned target plan
    is identical across the whole grid, so so is the reference."""
    for r in responses:
        key = (r.request_id, r.qos, len(r.tokens), r.b_kv)
        if key not in refs:
            refs[key] = greedy_decode_reference(
                model, eng.class_params(r.qos), prompts[r.request_id],
                len(r.tokens), b_kv=r.b_kv, compile_cache=ref_cache)
        if not np.array_equal(np.asarray(r.tokens), refs[key]):
            return False
    return True


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    print(f"arch={cfg.name} max_batch={MAX_BATCH} prompts<= {SEQ} "
          f"new in [{MIN_NEW}, {MAX_NEW}] ({N_REQUESTS} ragged "
          "requests, smoke scale)")

    # ---- fused-decode baseline: the codesign picks each class's
    # forward operating point; every speculative engine is pinned there
    dec_cache = CompiledForwardCache()
    dec = DecodeEngine(model, params, sysp, classes=CLASSES,
                       max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                       compile_cache=dec_cache)
    dec.warmup(SEQ)
    rep_d, resp_d, prompts, wall_d = drain(dec, cfg)
    points = {name: (c.b_hat, c.b_kv, c.f, c.f_server)
              for name, c in dec._classes.items()}
    for name, (b_hat, b_kv, f, f_server) in points.items():
        print(f"  pinned [{name:12s}] b_hat={b_hat} b_kv={b_kv} "
              f"f={f:.3e} f_server={f_server:.3e}")

    refs, ref_cache = {}, CompiledForwardCache()
    parity_dec = verify_parity(model, dec, resp_d, prompts, refs,
                               ref_cache)

    # ---- the (b_draft, k) grid, all sharing one compile cache: the
    # spec-round executable is keyed on b_kv only (k is a runtime
    # argument, the draft tree is a weights input), so the whole sweep
    # compiles each variant exactly once
    spec_cache = CompiledForwardCache()
    sweep, rows, parity_all = {}, [], True
    for b in DRAFT_GRID:
        for k in LOOKAHEAD_GRID:
            eng = spec_engine(model, params, sysp, points, b, k,
                              spec_cache, lam=dec.lam,
                              lam_kv=dec.lam_kv)
            rep, responses, _, wall_s = drain(eng, cfg)
            st = eng.spec_stats()
            ok = verify_parity(model, eng, responses, prompts, refs,
                               ref_cache)
            parity_all = parity_all and ok
            sweep[f"b{b}_k{k}"] = {
                "b_draft": b, "k": k,
                "tps_model": rep.throughput_tps,
                "tps_wall": rep.tokens_generated / max(wall_s, 1e-9),
                "acceptance": st.acceptance_rate,
                "accepted_len": st.accepted_per_round,
                "tokens_per_round": st.tokens_per_round,
                "rounds": st.rounds,
                "parity": ok,
            }
            rows.append([f"{b}", f"{k}",
                         f"{rep.throughput_tps:.2f}",
                         f"{rep.tokens_generated / max(wall_s, 1e-9):.0f}",
                         f"{st.acceptance_rate:.2f}",
                         f"{st.accepted_per_round:.2f}",
                         f"{st.tokens_per_round:.2f}",
                         f"{st.rounds}",
                         "yes" if ok else "NO"])
    print("\nspeculative grid at the pinned operating point "
          f"(decode baseline: {rep_d.throughput_tps:.2f} tok/s model, "
          f"{rep_d.tokens_generated / max(wall_d, 1e-9):.0f} wall):")
    table(["b_draft", "k", "tok/s model", "tok/s wall", "accept",
           "acc len", "tok/round", "rounds", "parity"], rows)

    # ---- throughput-chosen operating point, re-run on the warm cache:
    # zero compile misses, and the metrics snapshot describes the
    # headline configuration
    chosen_key = max(sweep, key=lambda k: sweep[k]["tps_model"])
    ch = sweep[chosen_key]
    metrics = MetricsRegistry()
    eng = spec_engine(model, params, sysp, points, ch["b_draft"],
                      ch["k"], spec_cache, metrics=metrics,
                      lam=dec.lam, lam_kv=dec.lam_kv)
    rep_s, resp_s, _, wall_s = drain(eng, cfg)
    parity_spec = verify_parity(model, eng, resp_s, prompts, refs,
                                ref_cache)
    wall_tps = rep_s.tokens_generated / max(wall_s, 1e-9)
    speedup = rep_s.throughput_tps / max(rep_d.throughput_tps, 1e-12)
    print(f"\nchosen operating point: b_draft={ch['b_draft']} "
          f"k={ch['k']} -> {rep_s.throughput_tps:.2f} tok/s model "
          f"({speedup:.2f}x fused decode), {wall_tps:.0f} wall, "
          f"acceptance={ch['acceptance']:.2f}")

    # ---- compile-count bound on the warm chosen engine: the sweep saw
    # every variant already, so this run must never compile
    b_kvs = sorted({c[1] for c in points.values()})
    t_rungs = seq_ladder(SEQ + MAX_NEW)
    n_pairs = sum(1 for s in seq_ladder(SEQ) for t in t_rungs if t >= s)
    bound = (n_pairs + len(t_rungs)) * len(b_kvs)
    cc = {
        "warm_misses": rep_s.compile_misses,
        "variants": rep_s.compiled_variants,
        "bound": bound,
        "ladder_bound": (n_pairs + 2 * len(t_rungs)) * len(b_kvs),
        "b_kv_rungs": b_kvs,
    }
    print(f"compile-count bound: {cc['variants']} compiled variants "
          f"(bound {bound} = ({n_pairs} prefill pairs + {len(t_rungs)} "
          f"spec-round buckets) x {len(b_kvs)} b_kv rungs; ladder x "
          f"{{draft, verify}} budget {cc['ladder_bound']}), "
          f"{cc['warm_misses']} misses on the warm chosen engine")

    # ---- the codesign claim: (b_draft, k, f) as joint variables buy a
    # strictly lower distortion bound per expected delivered token
    codesign = {}
    prefers = True
    for q in CLASSES:
        sd = dec.codesign_cache.solve_decode(
            dec.lam, dec.lam_kv, sysp, q, int(sysp.b_full))
        ss = dec.codesign_cache.solve_speculative(
            dec.lam, dec.lam_kv, sysp, q, int(sysp.b_full))
        better = ss is not None and sd is not None \
            and ss.objective < sd.objective
        prefers = prefers and better
        codesign[q.name] = {
            "decode_objective": sd.objective if sd else None,
            "spec_objective": ss.objective if ss else None,
            "b_draft": ss.b_draft if ss else None,
            "k": ss.k if ss else None,
            "alpha": ss.alpha if ss else None,
            "tokens_per_round": ss.tokens_per_round if ss else None,
        }
        if ss and sd:
            print(f"codesign [{q.name:12s}]: bound/token "
                  f"{sd.objective:.4f} -> {ss.objective:.4f} at "
                  f"(b_draft={ss.b_draft}, k={ss.k}, "
                  f"alpha={ss.alpha:.2f})")

    # acceptance must rise with draft fidelity at every lookahead — the
    # monotonicity the §16 estimator is built on, measured
    mono = all(sweep[f"b{a}_k{k}"]["acceptance"]
               <= sweep[f"b{b}_k{k}"]["acceptance"] + 1e-9
               for k in LOOKAHEAD_GRID
               for a, b in zip(DRAFT_GRID, DRAFT_GRID[1:]))

    acceptance = {
        "speculative_beats_fused_decode_tps": speedup > 1.0,
        "speedup": speedup,
        "bitwise_parity_speculative": parity_spec,
        "bitwise_parity_sweep": parity_all,
        "bitwise_parity_decode": parity_dec,
        "codesign_prefers_speculative": prefers,
        "acceptance_monotone_in_draft_bits": mono,
        "no_misses_after_warmup": cc["warm_misses"] == 0,
        "variants_within_bound": cc["variants"] <= cc["bound"],
    }
    ok = all(v for v in acceptance.values() if isinstance(v, bool))
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'} "
          f"(speculative {speedup:.2f}x fused decode modeled, "
          f"{wall_tps:.0f} wall tok/s, acceptance "
          f"{ch['acceptance']:.2f} at the chosen point)")
    for key, v in acceptance.items():
        print(f"  {key}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "max_batch": MAX_BATCH,
        "seq": SEQ, "max_new": MAX_NEW, "requests": N_REQUESTS,
        "speedup": speedup,
        "chosen": {"b_draft": ch["b_draft"], "k": ch["k"],
                   "tps_model": rep_s.throughput_tps,
                   "tps": wall_tps,
                   "acceptance": ch["acceptance"],
                   "accepted_len": ch["accepted_len"]},
        "throughput": {
            "decode": {"tps": rep_d.tokens_generated / max(wall_d, 1e-9),
                       "tps_model": rep_d.throughput_tps,
                       "rounds": rep_d.decode_rounds},
            "speculative": {"tps": wall_tps,
                            "tps_model": rep_s.throughput_tps,
                            "rounds": rep_s.decode_rounds},
        },
        "sweep": sweep,
        "codesign": codesign,
        "operating_points": {n: {"b_hat": p[0], "b_kv": p[1],
                                 "f": p[2], "f_server": p[3]}
                             for n, p in points.items()},
        "classes": [cs.to_dict() for cs in rep_s.classes],
        "compile_count": cc,
        "acceptance": acceptance,
        "metrics": metrics.snapshot(),
    }
    regression = check_regression(speedup, wall_tps)
    if regression:
        print(f"regression vs committed BENCH_spec.json: {regression}")
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok or regression:
        # CI runs this section on every PR; losing the speculative win
        # or draft/verify parity must fail the build
        raise RuntimeError(
            f"speculative acceptance failed: {acceptance} "
            f"regression={regression!r}")
    return results


def _json_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_spec.json"


def check_regression(speedup: float, wall_tps: "float | None" = None):
    """Compare against the committed record; None = fine, else a message.

    The speculative/decode modeled ratio is virtual-clock deterministic,
    so its tolerance only absorbs intentional cost-model re-tuning — a
    drop past it means drafting stopped paying for itself.  The
    wall-clock floor is measured, so its (looser) tolerance absorbs
    machine jitter — a drop past it means the round stopped being one
    fused dispatch (e.g. fell back to per-phase host round-trips)."""
    path = _json_path()
    if not path.exists():
        return None
    try:
        old = json.loads(path.read_text(encoding="utf-8"))
        old_speedup = float(old["speedup"])
    except (KeyError, ValueError):
        return None
    floor = REGRESSION_TOLERANCE * old_speedup
    if speedup < floor:
        return (f"speculative/decode throughput ratio fell to "
                f"{speedup:.3f}x (committed {old_speedup:.3f}x, "
                f"floor {floor:.3f}x)")
    try:
        old_wall = float(old["chosen"]["tps"])
    except (KeyError, TypeError, ValueError):
        return None
    if wall_tps is not None and wall_tps < WALL_TOLERANCE * old_wall:
        return (f"wall-clock speculative throughput fell to "
                f"{wall_tps:.1f} tok/s (committed {old_wall:.1f}, "
                f"floor {WALL_TOLERANCE * old_wall:.1f})")
    return None


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the speculative numbers as ``BENCH_spec.json`` at the repo
    root — the machine-readable perf record diffed across PRs."""
    if path is None:
        path = _json_path()
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
