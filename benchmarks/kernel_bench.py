"""Kernel-level benchmark: the TPU economics of the quantized matmul.

No wall-clock on this CPU container (kernels run under interpret=True for
correctness only) — instead this reports the quantities that *determine* TPU
performance and that the roofline model consumes:

  * HBM weight traffic per matmul at b̂ ∈ {16 (bf16), 8, 4} — the concrete
    realization of the paper's linear-in-b̂ workload on a TPU;
  * VMEM working set per (block_m, block_n, block_k) tile choice vs the
    ~16 MiB budget, MXU alignment check;
  * accuracy: quantized-matmul error vs exact fp32 matmul across bit-widths
    on production shapes (qwen2 / stablelm MLP dims).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import banner, table

VMEM_BUDGET = 16 * 2 ** 20


def weight_traffic():
    banner("Kernel — HBM weight bytes per matmul tile (the b̂ knob on TPU)")
    shapes = [("qwen2 MLP", 896, 4864), ("stablelm MLP", 2560, 6912),
              ("granite attn", 6144, 6144), ("kimi expert", 7168, 2048)]
    rows = []
    for name, k, n in shapes:
        bf16 = k * n * 2
        i8 = k * n + (k // 128) * n * 4
        i4 = k * n // 2 + (k // 128) * n * 4
        rows.append([name, f"{k}x{n}", f"{bf16 / 2**20:.1f}",
                     f"{i8 / 2**20:.1f} ({bf16 / i8:.2f}x)",
                     f"{i4 / 2**20:.1f} ({bf16 / i4:.2f}x)"])
    table(["weight", "KxN", "bf16 MiB", "int8 MiB (gain)",
           "int4 MiB (gain)"], rows)
    print("  -> decode-shape cells are weight-bandwidth-bound; int8/int4 "
        "residency moves the memory roofline term by the same factors "
        "(EXPERIMENTS.md §Perf).")


def vmem_working_set():
    banner("Kernel — VMEM working set per BlockSpec tile")
    rows = []
    for bm, bn, bk, g in [(128, 128, 256, 128), (256, 256, 512, 128),
                          (512, 256, 512, 128), (256, 512, 1024, 256),
                          (512, 512, 1024, 128)]:
        x = bm * bk * 4
        w = bk * bn
        s = (bk // g) * bn * 4
        acc = bm * bn * 4
        tot = x + w + s + acc
        dbuf = tot + x + w + s          # double-buffered inputs
        align = all(v % 128 == 0 for v in (bm, bn, bk))
        rows.append([f"{bm}x{bn}x{bk}", f"{x/2**10:.0f}K", f"{w/2**10:.0f}K",
                     f"{acc/2**10:.0f}K", f"{tot/2**20:.2f}M",
                     f"{dbuf/2**20:.2f}M",
                     "yes" if dbuf < VMEM_BUDGET else "NO",
                     "yes" if align else "NO"])
    table(["bm x bn x bk", "x", "codes", "acc", "1-buf", "2-buf",
           "fits 16M VMEM", "MXU-aligned"], rows)


def accuracy():
    banner("Kernel — quantized matmul error vs exact fp32 (interpret mode)")
    rows = []
    for name, k, n in [("qwen2 MLP", 896, 4864), ("128-aligned", 1024, 1024)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(k))
        x = jax.random.normal(kx, (64, k))
        w = jax.random.normal(kw, (k, n))
        exact = x @ w
        denom = float(jnp.mean(jnp.abs(exact)))
        for bits in (8, 4):
            ql = ops.quantize_linear(w, bits=bits, group_size=128)
            got = ql.apply(x)
            rel = float(jnp.mean(jnp.abs(got - exact))) / denom
            rows.append([name, f"{k}x{n}", bits,
                         f"{ql.nbytes() / 2**20:.2f} MiB", f"{rel:.2%}"])
    table(["shape", "KxN", "bits", "stored", "mean rel err"], rows)


def kernel_vs_ref_spotcheck():
    banner("Kernel — Pallas (interpret) vs jnp oracle spot check")
    rows = []
    for m, k, n, g in [(256, 512, 256, 128), (64, 1024, 384, 256),
                       (1, 896, 4864, 128)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
        x = jax.random.normal(kx, (m, k))
        w = jax.random.normal(kw, (k, n))
        codes, scales = ref.group_quantize_ref(w, g)
        err = float(jnp.max(jnp.abs(
            ops.quantized_matmul(x, codes, scales)
            - ref.qmm_ref(x, codes, scales))))
        rows.append([f"{m}x{k}x{n}", g, f"{err:.2e}"])
    table(["MxKxN", "group", "max |pallas - ref|"], rows)


def run() -> dict:
    weight_traffic()
    vmem_working_set()
    accuracy()
    kernel_vs_ref_spotcheck()
    return {}


if __name__ == "__main__":
    run()
