"""Paper Figs. 5-8: task quality (CIDEr) vs (T0, E0) for four designs —
proposed SCA, PPO, fixed-frequency, feasible-random — on BLIP-2/GIT proxies
under uniform and PoT-log quantization.

End-to-end and real: the proxy captioner is *trained* on the deterministic
caption task, the agent partition is *actually* quantized at each scheme's
chosen b̂, captions are *generated* (greedy, free-running) and scored with
the exact CIDEr formula against the dataset references.  The paper's raw
GFLOP figures parameterize the cost model with an effective FLOPs-per-cycle
calibrated so the QoS region is active (DESIGN.md §7, changed assumption).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs import blip2_proxy, git_proxy
from repro.core import baselines as bl
from repro.core import codesign as cd
from repro.core.cost_model import SystemParams
from repro.core.quantization import QuantConfig
from repro.data import CaptionProxyConfig, CaptionProxyDataset
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime.qat import fake_quantize_agent

from .cider import cider
from .common import ascii_plot, banner, table

CAP_LEN = 8
N_VIS = 4
N_IMAGES = 64
EVAL_BATCH = 48


def _train_captioner(arch: str, steps: int = 250, seed: int = 0):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    ds = CaptionProxyDataset(CaptionProxyConfig(
        vocab_size=cfg.vocab_size, seq_len=CAP_LEN, d_model=cfg.d_model,
        n_vis=N_VIS, batch_size=32, n_images=N_IMAGES))
    params = model.init(jax.random.PRNGKey(seed))
    opt = AdamW(learning_rate=3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, embeds, tokens, labels):
        def loss_fn(p):
            return model.loss(p, {"embeds": embeds, "tokens": tokens,
                                  "labels": labels})
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    for i in range(steps):
        b = ds.batch_at(i)
        params, state, loss = step(params, state,
                                   jnp.asarray(b["embeds"]),
                                   jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))
    return cfg, model, params, ds, float(loss)


def _generate(model, params, embeds, length: int):
    """Greedy free-running generation conditioned on the visual stub."""
    b = embeds.shape[0]
    toks = jnp.zeros((b, 1), jnp.int32)   # BOS = 0
    for _ in range(length):
        logits, _ = model.forward(params, {"embeds": embeds,
                                           "tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
    return np.asarray(toks[:, 1:])


class QualityOracle:
    """CIDEr as a function of the agent bit-width (cached per b̂)."""

    def __init__(self, arch: str, scheme: str):
        self.cfg, self.model, self.params, self.ds, final_loss = \
            _train_captioner(arch)
        self.scheme = scheme
        self._cache: Dict[int, float] = {}
        rng = np.random.default_rng(7)
        self.ids = rng.integers(0, N_IMAGES, size=EVAL_BATCH)
        self.embeds = jnp.asarray(self.ds.vis_basis[self.ids])
        self.refs = [[list(map(int, self.ds.references(
            np.asarray([i]))[0]))] for i in self.ids]
        print(f"  trained {arch}: final loss {final_loss:.3f}, "
              f"clean CIDEr {self.score(16):.1f}")

    def score(self, b_hat: int) -> float:
        b_hat = int(b_hat)
        if b_hat not in self._cache:
            if b_hat >= 16:
                p = self.params
            else:
                qcfg = QuantConfig(bits=b_hat, scheme=self.scheme,
                                   granularity="per-channel")
                p = fake_quantize_agent(self.params,
                                        self.model.logical_axes(),
                                        self.cfg, qcfg, ste=False)
            cands = _generate(self.model, p, self.embeds, CAP_LEN)
            self._cache[b_hat] = cider([list(map(int, c)) for c in cands],
                                       self.refs)
        return self._cache[b_hat]


def _sysparams(n_flop_total: float, split_frac: float) -> SystemParams:
    """Paper GFLOPs with FLOPs/cycle calibrated so t_a(b=16, f_max) = 1 s
    and t_s(f~_max) = 0.15 s — the region where (T0, E0) actually bind."""
    n_a = n_flop_total * split_frac
    n_s = n_flop_total * (1.0 - split_frac)
    return SystemParams(
        n_flop_agent=n_a, n_flop_server=n_s,
        c_agent=n_a / (2.0e9 * 1.0), c_server=n_s / (1.0e10 * 0.15))


def sweep(arch: str, scheme: str, n_flop_total: float):
    oracle = QualityOracle(arch, scheme)
    lam = 30.0
    cfg = oracle.cfg
    p = _sysparams(n_flop_total, cfg.split_layer / cfg.n_layers)

    t0_grid = [1.10, 1.15, 1.20, 1.30, 1.50, 2.00]
    e0_fixed = 2.0
    e0_grid = [0.70, 0.85, 1.00, 1.50, 2.00, 3.00]
    t0_fixed = 1.30

    def run_schemes(t0, e0):
        out = {}
        s = cd.solve_sca(lam, p, t0, e0)
        out["proposed"] = s
        out["ppo"] = bl.solve_ppo(lam, p, t0, e0, iters=120, seed=0)
        out["fixed-freq"] = bl.solve_fixed_frequency(lam, p, t0, e0)
        rnd = bl.solve_feasible_random(lam, p, t0, e0, trials=100)
        if rnd:
            # the paper reports the feasible trials themselves; the median
            # trial is the representative "random but feasible" design
            rnd.sort(key=lambda r: r.b_hat)
            out["random"] = rnd[len(rnd) // 2]
        else:
            out["random"] = None
        return out

    results = {"vs_t0": {}, "vs_e0": {}}
    for axis, grid, fixed in (("vs_t0", t0_grid, e0_fixed),
                              ("vs_e0", e0_grid, t0_fixed)):
        series: Dict[str, List[Optional[float]]] = {}
        bits: Dict[str, List] = {}
        for g in grid:
            t0, e0 = (g, fixed) if axis == "vs_t0" else (fixed, g)
            for name, sol in run_schemes(t0, e0).items():
                q = oracle.score(sol.b_hat) if sol else None
                series.setdefault(name, []).append(q)
                bits.setdefault(name, []).append(
                    sol.b_hat if sol else "-")
        results[axis] = {"grid": grid, "series": series, "bits": bits}

        label = "T0 (s)" if axis == "vs_t0" else "E0 (J)"
        banner(f"Figs 5-8 — {arch} / {scheme}: CIDEr vs {label} "
               f"({'E0' if axis == 'vs_t0' else 'T0'}={fixed})")
        hdr = [label] + [f"{n} (b̂)" for n in series]
        rows = []
        for i, g in enumerate(grid):
            row = [g]
            for name in series:
                q = series[name][i]
                row.append(f"{q:.1f} ({bits[name][i]})"
                           if q is not None else "infeasible")
            rows.append(row)
        table(hdr, rows)
        ascii_plot({k: [x if x is not None else float("nan") for x in v]
                    for k, v in series.items()},
                   [float(g) for g in grid], xlabel=label, ylabel="CIDEr")

        # paper claim: proposed >= every baseline at every grid point
        wins = 0
        total = 0
        for i in range(len(grid)):
            qp = series["proposed"][i]
            if qp is None:
                continue
            for name in ("ppo", "fixed-freq", "random"):
                qb = series[name][i]
                if qb is not None:
                    total += 1
                    wins += qp >= qb - 1e-9
        print(f"  proposed >= baseline at {wins}/{total} comparisons")
        results[axis]["wins"] = (wins, total)
    return results


def run() -> dict:
    out = {}
    for arch, flops in (("blip2-proxy", blip2_proxy.N_FLOP_FIRST_TOKEN),
                        ("git-proxy", git_proxy.N_FLOP_FIRST_TOKEN)):
        for scheme in ("uniform", "pot-log"):
            out[f"{arch}/{scheme}"] = sweep(arch, scheme, flops)
    return out


if __name__ == "__main__":
    run()
