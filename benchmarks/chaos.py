"""Chaos-hardening gate: supervised serving under injected faults
(DESIGN.md §15).

One seeded fault trace (link outage + server preemption, calibrated to
the engine's own decode-round cost so faults actually land mid-stream)
drives the same request stream through the ``ServingSupervisor`` twice
— supervised, and as the bare unsupervised baseline — plus once on a
fault-free trace.  Four acceptance gates, all RAISED on failure:

  1. *Goodput.*  Supervised goodput (delivered tokens per virtual
     second) beats the unsupervised baseline on the faulty trace — the
     defenses must pay for their own overhead.
  2. *No token is lost or forged.*  The supervised run reports zero
     lost and zero duplicated tokens across every injected fault.
  3. *Crash recovery is exact.*  At least one decode stream is
     interrupted by a server crash, resumed from its snapshot, and
     every delivered stream is bitwise identical to the uninterrupted
     ``greedy_decode_reference`` run.
  4. *Clean is free.*  On a fault-free trace the supervised engine's
     tokens are bitwise identical to the bare engine's and the wall
     clock stays within ``OVERHEAD_TOLERANCE`` (the §14 obs budget),
     best-of-``REPEATS``.

Results land in ``BENCH_chaos.json`` and, via ``benchmarks/run.py``,
on the BENCH_history.jsonl row.

Run:  PYTHONPATH=src python -m benchmarks.run --only chaos
  or  PYTHONPATH=src python benchmarks/chaos.py
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.env import ChaosTrace, LinkOutage, ServerPreemption
from repro.models.registry import build_model
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           ServingSupervisor, greedy_decode_reference)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 16
MAX_NEW = 8
MAX_BATCH = 4
N_REQUESTS = 10
REPEATS = 3              # best-of for the clean-overhead gate
OVERHEAD_TOLERANCE = 0.03
CHAOS_SEED = 5
CLASSES = [
    QosClass("realtime", t0=1.2, e0=1.0),
    QosClass("interactive", t0=3.5, e0=2.0),
]


def make_sysp(cfg) -> SystemParams:
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=kv_full, kv_power_w=2.0)


def make_engine(model, params, sysp, cache) -> DecodeEngine:
    return DecodeEngine(model, params, sysp, classes=CLASSES,
                        max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                        compile_cache=cache)


def traffic(cfg, spacing_s: float, seed: int = 11):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        out.append((toks.astype(np.int32), CLASSES[i % len(CLASSES)].name,
                    int(rng.integers(2, MAX_NEW + 1)), spacing_s * i))
    return out


def serve_once(model, params, sysp, cache, stream, chaos, supervised):
    """One full drain through a fresh supervised engine; returns
    (wall_s, {request index: tokens}, ResilienceReport)."""
    eng = make_engine(model, params, sysp, cache)
    sup = ServingSupervisor(eng, chaos=chaos, supervised=supervised,
                            seed=CHAOS_SEED)
    rids = {}
    for i, (toks, qos, n_new, t) in enumerate(stream):
        rids[sup.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)] = i
    t0 = time.perf_counter()
    responses = sup.drain()
    wall_s = time.perf_counter() - t0
    tokens = {rids[r.request_id]: np.asarray(r.tokens) for r in responses}
    return wall_s, tokens, sup.report()


def bare_drain(model, params, sysp, cache, stream):
    """The unwrapped engine (no supervisor object at all) — the clean
    gate's identity baseline."""
    eng = make_engine(model, params, sysp, cache)
    rids = {}
    for i, (toks, qos, n_new, t) in enumerate(stream):
        rids[eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)] = i
    t0 = time.perf_counter()
    responses = eng.drain()
    wall_s = time.perf_counter() - t0
    return wall_s, {rids[r.request_id]: np.asarray(r.tokens)
                    for r in responses}


def bitwise(a: dict, b: dict) -> bool:
    return a.keys() == b.keys() and \
        all(np.array_equal(a[k], b[k]) for k in a)


def bitwise_delivered(delivered: dict, ref: dict) -> bool:
    """Every stream that WAS delivered matches the uninterrupted
    reference exactly (shed requests deliver nothing, so they have
    nothing to match)."""
    return all(np.array_equal(delivered[k], ref[k]) for k in delivered)


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    cache = CompiledForwardCache()   # shared: every mode runs warm
    make_engine(model, params, sysp, cache).warmup(SEQ)

    # calibrate the fault timescale to the engine's own decode round so
    # crashes land *between tokens of in-flight streams*, not in the
    # idle gaps — otherwise the recovery gate would be vacuous
    t_round = make_engine(model, params, sysp, cache) \
        .decode_round_cost(CLASSES[0].name, 32)[0]
    stream = traffic(cfg, spacing_s=10 * t_round)
    chaos = ChaosTrace(
        dt_s=t_round, horizon_s=4000 * t_round, seed=CHAOS_SEED,
        link_outage=LinkOutage(p_fail=0.05, p_recover=0.30),
        preemption=ServerPreemption(mtbf_s=10 * t_round,
                                    mttr_s=10 * t_round))
    print(f"arch={cfg.name} requests={N_REQUESTS} new<= {MAX_NEW} "
          f"t_round={t_round * 1e6:.1f}us chaos seed={CHAOS_SEED} "
          f"outage={chaos.outage_fraction() * 100:.1f}% of trace")

    # uninterrupted reference per request: the sequential decode the
    # batched engine is bitwise-pinned to (PR-6), run to completion
    ref = {}
    probe = make_engine(model, params, sysp, cache)
    for i, (toks, qos, n_new, _) in enumerate(stream):
        ref[i] = np.asarray(greedy_decode_reference(
            model, probe.class_params(qos), toks, n_new,
            b_kv=probe.solution_for(qos).b_kv, compile_cache=cache))

    # --- faulty trace: supervised vs bare ------------------------------
    _, tok_sup, rep_sup = serve_once(model, params, sysp, cache, stream,
                                     chaos, supervised=True)
    _, tok_bare, rep_bare = serve_once(model, params, sysp, cache, stream,
                                       chaos, supervised=False)
    recovered_exact = bitwise_delivered(tok_sup, ref)
    # bare often delivers *nothing* under this trace; clamp so the JSON
    # stays strict (no Infinity literal) and history plots stay finite
    goodput_ratio = (rep_sup.goodput / rep_bare.goodput
                     if rep_bare.goodput > 0 else 1e6)
    table(["mode", "delivered", "failed", "shed", "recoveries",
           "lost/dup", "goodput tok/s"],
          [["supervised", rep_sup.delivered, rep_sup.failed, rep_sup.shed,
            rep_sup.recoveries,
            f"{rep_sup.tokens_lost}/{rep_sup.tokens_duplicated}",
            f"{rep_sup.goodput:.2f}"],
           ["bare", rep_bare.delivered, rep_bare.failed, rep_bare.shed,
            rep_bare.recoveries,
            f"{rep_bare.tokens_lost}/{rep_bare.tokens_duplicated}",
            f"{rep_bare.goodput:.2f}"]])
    print(f"faulty trace: faults={rep_sup.faults_seen} "
          f"retries={rep_sup.retries} recoveries={rep_sup.recoveries} "
          f"goodput ratio={goodput_ratio:.2f}x "
          f"recovered-bitwise={recovered_exact}")

    # --- clean trace: identity + overhead, best-of-REPEATS -------------
    walls = {"bare": [], "supervised": []}
    tok_clean_bare = tok_clean_sup = None
    rep_clean = None
    for _ in range(REPEATS):
        w, toks = bare_drain(model, params, sysp, cache, stream)
        walls["bare"].append(w)
        tok_clean_bare = toks
        w, toks, rep_clean = serve_once(model, params, sysp, cache,
                                        stream, None, supervised=True)
        walls["supervised"].append(w)
        tok_clean_sup = toks
    best = {k: min(v) for k, v in walls.items()}
    overhead = best["supervised"] / best["bare"] - 1.0
    clean_bitwise = bitwise(tok_clean_sup, tok_clean_bare)
    print(f"clean trace: pass-through={rep_clean.clean} "
          f"bitwise={clean_bitwise} overhead={overhead * 100:+.2f}% "
          f"(tolerance {OVERHEAD_TOLERANCE * 100:.0f}%)")

    acceptance = {
        # (a) the defenses pay for themselves on the faulty trace
        "supervised_goodput_beats_bare": goodput_ratio > 1.0,
        # (b) nothing lost, nothing forged, nothing shed silently
        "zero_tokens_lost": rep_sup.tokens_lost == 0,
        "zero_tokens_duplicated": rep_sup.tokens_duplicated == 0
        and rep_bare.tokens_duplicated == 0,
        # every request is either delivered or deliberately shed (its
        # deadline had already passed) — never silently failed
        "all_requests_accounted":
            rep_sup.delivered + rep_sup.shed == N_REQUESTS
            and rep_sup.failed == 0,
        # (c) crashes actually happened and recovery is exact
        "crashes_interrupted_streams": rep_sup.recoveries > 0,
        "recovered_bitwise_identical": recovered_exact,
        "bare_actually_loses_work": rep_bare.failed > 0,
        # (d) the house invariant: clean trace = bare engine
        "clean_trace_bitwise_identical": clean_bitwise,
        "clean_trace_is_passthrough": bool(rep_clean.clean),
        "clean_overhead_within_tolerance": overhead <= OVERHEAD_TOLERANCE,
    }
    ok = all(acceptance.values())
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "requests": N_REQUESTS,
        "chaos_seed": CHAOS_SEED,
        "outage_fraction": chaos.outage_fraction(),
        # the tracked ratio: supervised / bare goodput under faults
        "ratio": goodput_ratio,
        "clean_overhead_frac": overhead,
        "overhead_tolerance": OVERHEAD_TOLERANCE,
        "supervised": rep_sup.to_dict(),
        "bare": rep_bare.to_dict(),
        "acceptance": acceptance,
    }
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok:
        # CI turns a resilience regression into a red build: lost
        # tokens, inexact recovery, or a supervisor tax on clean runs
        raise RuntimeError(f"chaos acceptance failed: {acceptance}")
    return results


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the resilience numbers as ``BENCH_chaos.json`` at the repo
    root — the machine-readable chaos record diffed across PRs."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_chaos.json"
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
