"""Continuous-batching decode over a device-resident quantized KV cache
(DESIGN.md §12, §13).

Measures, on the ``qwen2_0_5b`` smoke config:

  1. continuous vs FIFO-barrier admission through ``DecodeEngine`` on a
     ragged high-rate request stream (staggered arrivals, per-request
     generation budgets).  Both runs share the same compiled step
     functions — admission is purely a scheduling policy — so the
     modeled-throughput ratio is deterministic.  Acceptance: continuous
     strictly beats the barrier on generated tokens/s.  The headline
     tok/s is wall-clock (the §13 fused multi-token chunks are a
     real-time win); the virtual-clock numbers stay as ``*_model``.
     The host<->device transfer volume per token is reported before
     (host-resident cache, modeled) vs after (device-resident,
     measured counters).
  2. bitwise greedy-decode parity: every continuous-batched response
     must equal, token for token, the non-batched sequential reference
     (``greedy_decode_reference``) decoding the same prompt alone under
     the same (plan, b_kv) operating point.
  3. the decode compile-count bound: after ``warmup()``, ragged traffic
     must never compile again, and total compiled variants stay within
     (prefill buckets + step buckets) x distinct b_kv rungs.

Besides the printed tables, ``run()`` writes machine-readable
``BENCH_decode.json`` at the repo root and RAISES if the acceptance
criteria fail or the continuous/barrier throughput ratio regresses by
more than ``REGRESSION_TOLERANCE`` against the committed record (CI
runs this section on every PR, mirroring ``fastpath.py``).

Run:  PYTHONPATH=src python -m benchmarks.run --only decode
  or  PYTHONPATH=src python benchmarks/decode.py
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.kernels.bucketing import seq_bucket, seq_ladder
from repro.models.registry import build_model
from repro.obs import NULL_METRICS, MetricsRegistry
from repro.runtime import (CompiledForwardCache, DecodeEngine, QosClass,
                           greedy_decode_reference)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 24                 # max prompt length
MAX_NEW = 12             # max generation budget
MAX_BATCH = 4
N_REQUESTS = 20
# the modeled throughput ratio is virtual-clock deterministic; the slack
# only absorbs intentional re-tuning of the cost model
REGRESSION_TOLERANCE = 0.9
# the headline tok/s is WALL-CLOCK (§13 device residency is a real-time
# win, not a modeled one), so its floor absorbs machine jitter
WALL_TOLERANCE = 0.5
CLASSES = [
    QosClass("realtime", t0=1.2, e0=1.0),
    QosClass("interactive", t0=3.5, e0=2.0),
]


def make_sysp(cfg) -> SystemParams:
    """Smoke-scale FLOPs plus a KV-cost term sized to this model's cache
    so the codesign's b_kv rung is a real decision (a full-precision
    cache read costs 0.5 s / 1 J per step against the class budgets)."""
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=kv_full, kv_power_w=2.0)


def traffic(cfg, seed: int = 7):
    """One ragged high-rate stream: arrivals every 10 modeled ms (far
    below the per-round service time), prompt lengths and generation
    budgets both ragged so retirements interleave."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        out.append((toks.astype(np.int32),
                    CLASSES[i % len(CLASSES)].name,
                    int(rng.integers(2, MAX_NEW + 1)),
                    0.01 * i))
    return out


def serve(admission: str, model, params, sysp,
          compile_cache: CompiledForwardCache, metrics=NULL_METRICS):
    eng = DecodeEngine(model, params, sysp, classes=CLASSES,
                       max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                       admission=admission, compile_cache=compile_cache,
                       metrics=metrics)
    warm = eng.warmup(SEQ)
    prompts = {}
    for toks, qos, n_new, t in traffic(model.cfg):
        rid = eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)
        prompts[rid] = toks
    t0 = time.perf_counter()        # warmup excluded: steady-state only
    responses = eng.drain()
    wall_s = time.perf_counter() - t0
    return eng, eng.report(), responses, prompts, warm, wall_s


def verify_parity(model, eng, responses, prompts,
                  compile_cache) -> bool:
    """Every batched response must equal the non-batched sequential
    reference token for token (DESIGN.md §12)."""
    for r in responses:
        ref = greedy_decode_reference(
            model, eng.class_params(r.qos), prompts[r.request_id],
            len(r.tokens), b_kv=r.b_kv, compile_cache=compile_cache)
        if not np.array_equal(np.asarray(r.tokens), ref):
            return False
    return True


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    shared = CompiledForwardCache()  # both policies share the step fns
    # the sequential reference compiles width-1 step graphs; keep them
    # out of the engine cache so the bound below counts engine variants
    ref_cache = CompiledForwardCache()
    print(f"arch={cfg.name} max_batch={MAX_BATCH} prompts<= {SEQ} "
          f"new<= {MAX_NEW} ({N_REQUESTS} ragged requests, smoke scale)")

    # instrument the continuous run only, so the snapshot attached to
    # BENCH_history.jsonl (DESIGN.md §14) describes the headline policy
    metrics = MetricsRegistry()
    reports, rows, parity, warm_by, wall_by = {}, [], {}, {}, {}
    for admission in ("barrier", "continuous"):
        eng, rep, responses, prompts, warm, wall_s = serve(
            admission, model, params, sysp, shared,
            metrics=metrics if admission == "continuous" else NULL_METRICS)
        reports[admission] = rep
        warm_by[admission] = warm
        wall_by[admission] = wall_s
        parity[admission] = verify_parity(model, eng, responses, prompts,
                                          ref_cache)
        rows.append([admission, rep.decode_rounds,
                     f"{rep.tokens_generated / max(wall_s, 1e-9):.1f}",
                     f"{rep.throughput_tps:.2f}",
                     f"{rep.throughput_rps:.2f}",
                     f"{rep.total_delay_s:.2f}s",
                     "yes" if parity[admission] else "NO"])
    print("\nadmission policy on the same stream "
          "(wall = measured, model = virtual clock):")
    table(["policy", "steps", "tok/s wall", "tok/s model", "req/s model",
           "makespan", "parity"], rows)
    for cs in reports["continuous"].classes:
        print(f"  [{cs.qos:12s}] b_hat={cs.b_hat} b_kv={cs.b_kv} "
              f"ttft={cs.ttft_mean_s * 1e3:7.1f}ms "
              f"itl={cs.itl_mean_s * 1e3:6.1f}ms "
              f"p50={cs.itl_p50_s * 1e3:6.1f}ms "
              f"p95={cs.itl_p95_s * 1e3:6.1f}ms")

    # compile-count bound on the continuous engine: the shared cache saw
    # warmup once; everything after must hit.  Prefill executables are
    # keyed on (prompt bucket, cache bucket) pairs (the fused slot
    # scatter puts the cache shape in the graph); decode chunks on cache
    # buckets alone.
    rep = reports["continuous"]
    b_kvs = sorted({cs.b_kv for cs in rep.classes})
    t_rungs = seq_ladder(SEQ + MAX_NEW)
    n_pairs = sum(1 for s in seq_ladder(SEQ) for t in t_rungs if t >= s)
    bound = (n_pairs + len(t_rungs)) * len(b_kvs)
    cc = {
        "warmup_compiles": warm_by["barrier"],
        "warm_misses": rep.compile_misses,  # continuous ran second
        "variants": reports["continuous"].compiled_variants,
        "bound": bound,
        "b_kv_rungs": b_kvs,
    }
    print(f"\ncompile-count bound: {cc['variants']} compiled variants "
          f"(bound {bound} = ({n_pairs} prefill pairs + {len(t_rungs)} "
          f"chunk buckets) x {len(b_kvs)} b_kv rungs), "
          f"{cc['warm_misses']} misses on the second (warm) engine")

    # host<->device traffic per generated token: the PR-6 host-resident
    # engine shipped the whole slot block's codes+scales BOTH ways every
    # round (modeled below at the worst-case cache bucket); the device-
    # resident engine ships tokens and scalars only (measured).
    t_max = seq_bucket(SEQ + MAX_NEW)
    blk = cfg.n_layers * MAX_BATCH * t_max * cfg.n_kv_heads
    per_round = 2 * (2 * blk * cfg.head_dim + 2 * blk * 4) \
        + 2 * MAX_BATCH * 4 + MAX_BATCH * 4
    before_bpt = per_round / MAX_BATCH
    after_bpt = (rep.h2d_bytes + rep.d2h_bytes) \
        / max(rep.tokens_generated, 1)
    print(f"transfer per token: {before_bpt:,.0f} B host-resident "
          f"(modeled) -> {after_bpt:,.0f} B device-resident (measured, "
          f"{rep.h2d_bytes:,d} h2d + {rep.d2h_bytes:,d} d2h)")

    wall_tps = rep.tokens_generated / max(wall_by["continuous"], 1e-9)
    speedup = reports["continuous"].throughput_tps \
        / max(reports["barrier"].throughput_tps, 1e-12)
    kv_ratio = rep.kv_bytes / rep.kv_bytes_full if rep.kv_bytes_full \
        else 1.0
    acceptance = {
        "continuous_beats_barrier_tps": speedup > 1.0,
        "speedup": speedup,
        "bitwise_parity_continuous": parity["continuous"],
        "bitwise_parity_barrier": parity["barrier"],
        "no_misses_after_warmup": cc["warm_misses"] == 0,
        "variants_within_bound": cc["variants"] <= cc["bound"],
        "kv_cache_compressed": kv_ratio < 1.0,
        "transfer_bytes_collapsed": after_bpt < 0.01 * before_bpt,
    }
    ok = all(v for v in acceptance.values() if isinstance(v, bool))
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'} "
          f"({wall_tps:.1f} wall tok/s, continuous {speedup:.2f}x "
          f"barrier modeled, kv cache {kv_ratio:.2f}x of full precision)")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "max_batch": MAX_BATCH,
        "seq": SEQ, "max_new": MAX_NEW, "requests": N_REQUESTS,
        "speedup": speedup,
        "kv_cache_ratio": kv_ratio,
        # headline tps is measured wall-clock (§13); the virtual-clock
        # numbers live on as *_model for the policy comparison
        "throughput": {k: {"tps": r.tokens_generated
                           / max(wall_by[k], 1e-9),
                           "tps_model": r.throughput_tps,
                           "rps_model": r.throughput_rps,
                           "rounds": r.decode_rounds}
                       for k, r in reports.items()},
        "transfer": {"bytes_per_token_host_resident_model": before_bpt,
                     "bytes_per_token_device_resident": after_bpt,
                     "h2d_bytes": rep.h2d_bytes,
                     "d2h_bytes": rep.d2h_bytes},
        # the per-class report dataclass serializes itself (DESIGN.md
        # §14) — a superset of the hand-picked keys this used to list
        "classes": [cs.to_dict() for cs in rep.classes],
        "compile_count": cc,
        "acceptance": acceptance,
        "metrics": metrics.snapshot(),
    }
    regression = check_regression(speedup, wall_tps)
    if regression:
        print(f"regression vs committed BENCH_decode.json: {regression}")
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok or regression:
        # CI runs this section on every PR; losing the continuous-
        # batching win or decode parity must fail the build
        raise RuntimeError(
            f"decode acceptance failed: {acceptance} "
            f"regression={regression!r}")
    return results


def _json_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_decode.json"


def check_regression(speedup: float, wall_tps: "float | None" = None):
    """Compare against the committed record; None = fine, else a message.

    Two floors: the continuous/barrier *modeled* ratio is virtual-clock
    deterministic, so its tolerance only absorbs intentional cost-model
    re-tuning — a drop past it means the continuous scheduler stopped
    refilling slots mid-flight.  The wall-clock tok/s floor is measured,
    so its (looser) tolerance absorbs machine jitter — a drop past it
    means the decode path fell off the fused device-resident executables
    (e.g. back to per-token host round-trips)."""
    path = _json_path()
    if not path.exists():
        return None
    try:
        old = json.loads(path.read_text(encoding="utf-8"))
        old_speedup = float(old["speedup"])
    except (KeyError, ValueError):
        return None
    floor = REGRESSION_TOLERANCE * old_speedup
    if speedup < floor:
        return (f"continuous/barrier throughput ratio fell to "
                f"{speedup:.3f}x (committed {old_speedup:.3f}x, "
                f"floor {floor:.3f}x)")
    try:
        old_wall = float(old["throughput"]["continuous"]["tps"])
    except (KeyError, TypeError, ValueError):
        return None
    if wall_tps is not None and wall_tps < WALL_TOLERANCE * old_wall:
        return (f"wall-clock decode throughput fell to {wall_tps:.1f} "
                f"tok/s (committed {old_wall:.1f}, floor "
                f"{WALL_TOLERANCE * old_wall:.1f})")
    return None


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the decode numbers as ``BENCH_decode.json`` at the repo root
    — the machine-readable perf record diffed across PRs."""
    if path is None:
        path = _json_path()
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
