"""Compiled fast-path serving: eager vs AOT-compiled wall clock
(DESIGN.md §10).

Measures, on the ``qwen2_0_5b`` smoke config at the PR-1 sweep points
(SEQ=32, batch sizes 1..16):

  1. eager vs compiled requests/s through ``CoInferenceEngine`` on the
     kernel path at b̂ = 8 — the eager path dispatches the agent scans,
     transport, and server stage op-by-op from Python; the compiled path
     runs one bucket-padded AOT executable.  Acceptance: >= 2x at batch 8,
     with per-request logits bitwise identical to the sequential eager
     engine.
  2. the compile-count bound: a shape-varied workload (>= 8 distinct
     (batch, seq) shapes) through ``BatchedCoInferenceEngine`` after
     ``warmup()`` must compile at most len(bucket ladder) x active plans
     forward variants and never miss on warm traffic.

Besides the printed tables, ``run()`` writes machine-readable
``BENCH_fastpath.json`` at the repo root and RAISES if the acceptance
criteria fail or the measured speedup regresses by more than
``REGRESSION_TOLERANCE`` against the committed record (CI runs this
section on every PR, mirroring ``adaptive_serve.py``).

Run:  PYTHONPATH=src python -m benchmarks.run --only fastpath
  or  PYTHONPATH=src python benchmarks/fastpath.py
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.kernels.bucketing import seq_ladder
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CoInferenceEngine,
                           QosClass)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 32
B_HAT = 8
SIZES = (1, 2, 4, 8, 16)
N_REQUESTS = 16
# wall clock on shared CI runners is noisy; the speedup may regress by at
# most this factor against the committed BENCH_fastpath.json before the
# build fails (the >= 2x acceptance floor always applies)
REGRESSION_TOLERANCE = 0.5
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
CLASSES = [
    QosClass("realtime", t0=1.10, e0=0.9),
    QosClass("interactive", t0=1.30, e0=1.5),
    QosClass("batch", t0=2.50, e0=4.0),
]


def _tokens(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n, SEQ)).astype(np.int32)


def _time_engine(eng: CoInferenceEngine, toks: np.ndarray, batch: int,
                 repeats: int = 3) -> float:
    """Best-of wall-clock seconds to serve all rows in ``batch``-chunks."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for lo in range(0, toks.shape[0], batch):
            logits, _ = eng.serve_batch(
                {"tokens": jnp.asarray(toks[lo:lo + batch])})
        logits.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_eager_vs_compiled(model, params) -> List[dict]:
    eager = CoInferenceEngine(model, params, SYSP, path="kernel")
    eager.configure(B_HAT)
    comp = CoInferenceEngine(model, params, SYSP, path="kernel",
                             compiled=True)
    comp.configure(B_HAT)
    toks = _tokens(model.cfg, N_REQUESTS)
    # warm both paths for every shape the sweep dispatches
    for b in set(SIZES):
        eager.serve_batch({"tokens": jnp.asarray(toks[:b])})
        comp.serve_batch({"tokens": jnp.asarray(toks[:b])})
    rows = []
    for b in SIZES:
        t_e = _time_engine(eager, toks, b)
        t_c = _time_engine(comp, toks, b)
        rows.append({
            "batch": b,
            "eager_rps": N_REQUESTS / t_e,
            "compiled_rps": N_REQUESTS / t_c,
            "speedup": t_e / t_c,
        })
    return rows


def verify_bitwise(model, params) -> bool:
    """Every compiled per-request logit tensor must equal the sequential
    eager engine's, across ragged lengths and both kernel containers."""
    cfg = model.cfg
    seq = CoInferenceEngine(model, params, SYSP, path="kernel",
                            cache_weights=True)
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4, path="kernel",
                                   compiled=True)
    rng = np.random.default_rng(7)
    sent = {}
    for i in range(12):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 4, 2 * SEQ)))
        sent[eng.submit(toks, CLASSES[i % 3].name)] = (toks,
                                                       CLASSES[i % 3].name)
    for r in eng.drain():
        toks, qos = sent[r.request_id]
        sol = eng.solution_for(qos)
        seq.configure(sol.b_hat, sol.f, sol.f_server)
        want, _ = seq.serve_batch(
            {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        if not np.array_equal(np.asarray(r.logits), np.asarray(want[0])):
            return False
    return True


def compile_count_bound(model, params, max_seq: int = 64) -> dict:
    """Serve >= 8 distinct (batch, seq) shapes; the compile cache must
    stay within len(bucket ladder) x active plans and never miss after
    warmup."""
    cfg = model.cfg
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4, path="kernel",
                                   compiled=True)
    warm = eng.warmup(max_seq)
    cc = eng.engine.compile_cache
    miss0 = cc.misses
    # per class, one full batch around each length scale plus a ragged
    # tail batch -> well over 8 distinct raw (batch, seq) shapes
    rng = np.random.default_rng(11)
    shapes = set()
    for ci, c in enumerate(CLASSES):
        for group, top in ((4, 12 + ci), (4, 30 + ci), (2, 55 + ci)):
            for j in range(group):
                eng.submit(rng.integers(0, cfg.vocab_size, size=top - j),
                           c.name)
    while eng.pending():
        rs = eng.step()
        shapes.add((len(rs), max(len(r.logits) for r in rs)))
    ladder = seq_ladder(max_seq, base=eng.engine.seq_bucket_base)
    return {
        "distinct_shapes": len(shapes),
        "warmup_compiles": warm,
        "warm_misses": cc.misses - miss0,
        "variants": len(cc),
        "bound": len(ladder) * len(CLASSES),
        "ladder": list(ladder),
        "hits": cc.hits,
    }


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} seq={SEQ} b_hat={B_HAT} kernel path "
          f"(smoke scale; CPU interpret kernels)")

    rows = sweep_eager_vs_compiled(model, params)
    print("\neager vs compiled wall clock (one engine, fixed b_hat):")
    table(["batch", "eager req/s", "compiled req/s", "speedup"],
          [[r["batch"], f"{r['eager_rps']:.1f}",
            f"{r['compiled_rps']:.1f}", f"{r['speedup']:.2f}x"]
           for r in rows])
    at8 = next(r for r in rows if r["batch"] == 8)

    bitwise = verify_bitwise(model, params)
    cc = compile_count_bound(model, params)
    print(f"\ncompile-count bound: {cc['distinct_shapes']} distinct "
          f"(batch, seq) shapes served -> {cc['variants']} compiled "
          f"variants (bound {cc['bound']} = {len(cc['ladder'])} buckets "
          f"x {len(CLASSES)} plans), {cc['warm_misses']} misses after "
          f"warmup")

    acceptance = {
        "speedup_at_8_geq_2x": at8["speedup"] >= 2.0,
        "speedup_at_8": at8["speedup"],
        "bitwise_identical_to_sequential_eager": bitwise,
        "served_geq_8_distinct_shapes": cc["distinct_shapes"] >= 8,
        "variants_within_bound": cc["variants"] <= cc["bound"],
        "no_misses_after_warmup": cc["warm_misses"] == 0,
    }
    ok = all(v for v in acceptance.values() if isinstance(v, bool))
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "seq": SEQ, "b_hat": B_HAT,
        "sweep": rows,
        "compile_count": cc,
        "acceptance": acceptance,
    }
    regression = check_regression(at8["speedup"])
    if regression:
        print(f"regression vs committed BENCH_fastpath.json: {regression}")
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok or regression:
        # CI runs this section on every PR; a fast-path regression must
        # fail the build, not just print (benchmarks/run.py converts the
        # raise into a failed section and a nonzero exit)
        raise RuntimeError(
            f"fastpath acceptance failed: {acceptance} "
            f"regression={regression!r}")
    return results


def _json_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent.parent \
        / "BENCH_fastpath.json"


def check_regression(speedup_at_8: float):
    """Compare against the committed record; None = fine, else a message.

    Tolerant (``REGRESSION_TOLERANCE``) because wall clock on shared
    runners is noisy — this guards against the fast path silently falling
    back to eager dispatch, not against scheduler jitter."""
    path = _json_path()
    if not path.exists():
        return None
    try:
        committed = json.loads(path.read_text(encoding="utf-8"))
        old = next(r["speedup"] for r in committed["sweep"]
                   if r["batch"] == 8)
    except (KeyError, StopIteration, ValueError):
        return None
    floor = REGRESSION_TOLERANCE * old
    if speedup_at_8 < floor:
        return (f"speedup at batch 8 fell to {speedup_at_8:.2f}x "
                f"(committed {old:.2f}x, floor {floor:.2f}x)")
    return None


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the fast-path numbers as ``BENCH_fastpath.json`` at the repo
    root — the machine-readable perf record diffed across PRs."""
    if path is None:
        path = _json_path()
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
