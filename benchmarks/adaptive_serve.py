"""Trace-driven adaptive serving: static vs oracle-per-step vs adaptive
(DESIGN.md §9).

One seeded dynamic environment — Markov-chain Wi-Fi uplink, the Table I
coarse frequency profiles of ``testbed_profiles.py`` replayed as a
time-varying f_max cap, and a battery running below its reserve — drives
three policies over the *identical* request stream through
``AdaptiveCoInferenceEngine``:

  static   — (P1) solved once under the initial state, never replanned;
             the environment still bills it (frequency caps clip f).
  oracle   — re-solved on every exact per-step state change: the
             clairvoyant upper bound.
  adaptive — quantized-state drift detection + QoS-miss monitoring with
             hysteresis, the deployable middle.

Scored on measured output distortion (vs a full-precision engine),
deadline-violation rate, modeled energy, and replan count.  The
acceptance criteria of ISSUE 3:

  * adaptive strictly fewer deadline violations than static;
  * adaptive distortion within 10% of oracle;
  * replan count bounded by batches/hysteresis;
  * on a constant trace the adaptive engine is bitwise identical to
    ``BatchedCoInferenceEngine``.

All timescales are calibrated to the *smoke* model's realized workload
(DESIGN.md §7 cost-model calibration): the engine bills batches at the
model's actual FLOPs, so QoS budgets and environment dwell times live at
that scale — the structure (linear-in-b̂ delay, cubic-in-f energy,
transport off the top of both budgets) is scale-free.

Besides the printed tables, ``run()`` writes machine-readable
``BENCH_adaptive.json`` at the repo root, the adaptive-serving perf
record diffed across PRs.

Run:  PYTHONPATH=src python -m benchmarks.run --only adaptive
  or  PYTHONPATH=src python benchmarks/adaptive_serve.py
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.env import Battery, Environment, MarkovLink, TraceReplay
from repro.models.registry import build_model
from repro.runtime import (AdaptiveCoInferenceEngine,
                           BatchedCoInferenceEngine, CoInferenceEngine,
                           QosClass)

try:
    from .common import table
    from .testbed_profiles import PROFILES
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table
    # testbed_profiles uses package-relative imports; in script mode fall
    # back to its literal Table I map (asserted equal under the package)
    PROFILES = {"low": 0.6e9, "medium": 1.2e9, "high": 2.0e9}

ARCH = "qwen2-0.5b"
SEQ = 32
MAX_BATCH = 4
N_REQUESTS = 30
HYSTERESIS = 2

# QoS classes at the smoke model's realized per-request workload scale
# (see module docstring): "interactive" is tight — under throttled f or
# a faded link some windows are genuinely infeasible and must degrade —
# "bulk" is loose
CLASSES = [
    QosClass("interactive", t0=4.0e-5, e0=2.0e-3),
    QosClass("bulk", t0=1.2e-4, e0=6.0e-3),
]
MIX = ("interactive", "interactive", "bulk")

# Markov Wi-Fi uplink (bytes/s), scaled so transport is commensurate
# with the smoke compute delay: good ~10 us, fair ~26 us, bad ~103 us
# per nominal request at b_emb=8
LINK_RATES = (2.0e8, 8.0e7, 2.0e7)
LINK_TRANSITION = ((0.92, 0.06, 0.02),
                   (0.10, 0.82, 0.08),
                   (0.06, 0.24, 0.70))


def smoke_sysparams(model) -> SystemParams:
    """Base SystemParams billed at the smoke model's actual FLOPs for
    one nominal SEQ-token request, with the uplink terms enabled.  (P1)
    plans against this per-request workload; batches bill their real
    token count, so a backed-up queue packing multiple requests really
    does run past the single-request plan — slow policies pay for it."""
    eng = CoInferenceEngine(model, model.init(jax.random.PRNGKey(9)),
                            SystemParams(n_flop_agent=1.0,
                                         n_flop_server=1.0))
    n_a, n_s = eng.flop_split(SEQ)
    d = model.cfg.d_model
    return SystemParams(
        n_flop_agent=n_a, n_flop_server=n_s,
        emb_bytes_full=float(SEQ * d * 2),  # f16 boundary activation
        link_bps=LINK_RATES[0],
        tx_power_w=0.25)


def build_environment(seed: int = 0, horizon_s: float = 0.04) -> Environment:
    """Markov link + Table I profile replay as the f_max cap + battery."""
    schedule = ("high", "low", "high", "low")
    dwell = horizon_s / len(schedule)
    return Environment(
        seed=seed, dt_s=1.0e-3, horizon_s=horizon_s,
        link=MarkovLink(rates_bps=LINK_RATES, transition=LINK_TRANSITION),
        f_cap=TraceReplay(values=[PROFILES[n] for n in schedule],
                          dwell_s=dwell),
        battery=Battery(capacity_j=0.6, drain_w=3.0, soc0=0.4))


def request_stream(cfg, n: int = N_REQUESTS, seed: int = 5,
                   gap_mean_s: float = 1.0e-3) -> List[tuple]:
    """(tokens, qos, arrival_s) — one stream shared by every policy."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        out.append((toks, MIX[i % len(MIX)], t))
        t += float(rng.exponential(gap_mean_s))
    return out


def run_policy(policy: str, model, params, sysp: SystemParams,
               env: Environment, stream, refs) -> Dict:
    eng = AdaptiveCoInferenceEngine(
        model, params, sysp, classes=CLASSES, max_batch=MAX_BATCH,
        environment=env, policy=policy, hysteresis_steps=HYSTERESIS)
    sent = {}
    for toks, qos, arr in stream:
        sent[eng.submit(toks, qos, arrival_s=arr)] = toks
    responses = eng.drain()
    dist = sum(float(jnp.sum(jnp.abs(r.logits - refs[r.request_id])))
               for r in responses) / len(responses)
    rep, arep = eng.report(), eng.adaptive_report()
    # the controller report serializes itself (DESIGN.md §14); only the
    # benchmark-side scores and the engine-report slices are hand-added
    row = arep.to_dict()
    row.update({
        "distortion": dist,
        "energy_j": rep.total_energy_j,
        "batches": rep.batches_served,
        "p1_solves": rep.codesign_misses,
    })
    return row


def verify_constant_trace_bitwise(model, params, sysp, stream) -> bool:
    """Identity environment ⇒ the adaptive engine must reproduce the
    static batched engine bit for bit."""
    env = Environment(dt_s=1.0e-3, horizon_s=0.04, seed=0)
    a = AdaptiveCoInferenceEngine(model, params, sysp, classes=CLASSES,
                                  max_batch=MAX_BATCH, environment=env)
    b = BatchedCoInferenceEngine(model, params, sysp, classes=CLASSES,
                                 max_batch=MAX_BATCH)
    for eng in (a, b):
        for toks, qos, arr in stream:
            eng.submit(toks, qos, arrival_s=arr)
    ra, rb = a.drain(), b.drain()
    if len(ra) != len(rb) or a.adaptive_report().plan_switches:
        return False
    return all(x.stats == y.stats
               and np.array_equal(np.asarray(x.logits), np.asarray(y.logits))
               for x, y in zip(ra, rb))


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = smoke_sysparams(model)
    env = build_environment()
    stream = request_stream(cfg)
    print(f"arch={cfg.name} requests={len(stream)} max_batch={MAX_BATCH} "
          f"hysteresis={HYSTERESIS} env: {env.n_steps} steps x "
          f"{env.dt_s * 1e3:.1f}ms (markov wifi + Table I profile replay "
          f"+ battery)")

    # full-precision references, once per request (shared across policies)
    clean = CoInferenceEngine(model, params, sysp, b_emb=16)
    clean.configure(16)
    refs = {}
    for rid, (toks, _, _) in enumerate(stream):
        out, _ = clean.serve_batch(
            {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        refs[rid] = out[0]

    rows = [run_policy(p, model, params, sysp, env, stream, refs)
            for p in ("static", "oracle", "adaptive")]
    by = {r["policy"]: r for r in rows}
    table(["policy", "violation rate", "distortion", "energy (J)",
           "replans", "switches", "degraded", "weight sets"],
          [[r["policy"], f"{r['deadline_violation_rate']:.3f}",
            f"{r['distortion']:.1f}", f"{r['energy_j']:.3e}",
            r["replans"], r["plan_switches"], r["degraded_batches"],
            r["weight_variants"]] for r in rows])

    replan_bound = by["adaptive"]["batches"] // HYSTERESIS
    bitwise = verify_constant_trace_bitwise(model, params, sysp, stream)
    acceptance = {
        "adaptive_beats_static_violations":
            by["adaptive"]["deadline_violations"]
            < by["static"]["deadline_violations"],
        "adaptive_distortion_within_10pct_of_oracle":
            by["adaptive"]["distortion"]
            <= 1.10 * by["oracle"]["distortion"],
        "replans_bounded_by_hysteresis":
            by["adaptive"]["replans"] <= replan_bound,
        "replan_bound": replan_bound,
        "constant_trace_bitwise": bitwise,
    }
    ok = all(v for k, v in acceptance.items() if isinstance(v, bool))
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "seq": SEQ, "max_batch": MAX_BATCH,
        "n_requests": len(stream), "hysteresis_steps": HYSTERESIS,
        "classes": [{"name": c.name, "t0": c.t0, "e0": c.e0}
                    for c in CLASSES],
        "policies": by,
        "acceptance": acceptance,
    }
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok:
        # CI runs this section on every PR (extras job); a regression of
        # the ISSUE 3 acceptance criteria must fail the build, not just
        # print — benchmarks/run.py converts the raise into a failed
        # section and a nonzero exit
        raise RuntimeError(f"adaptive-serving acceptance failed: "
                           f"{acceptance}")
    return results


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the adaptive-serving numbers as ``BENCH_adaptive.json`` at
    the repo root — the machine-readable record diffed across PRs."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_adaptive.json"
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
