"""Shared benchmark utilities (ASCII plots, table printing, timers)."""

from __future__ import annotations

import time
from typing import Dict, Iterable, List, Sequence


def banner(title: str) -> None:
    print("\n" + "=" * 72)
    print(f"== {title}")
    print("=" * 72)


def table(headers: Sequence[str], rows: Iterable[Sequence]) -> None:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    fmt = "  ".join("{:<%d}" % w for w in widths)
    print(fmt.format(*headers))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*r))


def ascii_plot(series: Dict[str, List[float]], xs: List[float],
               width: int = 64, height: int = 16, logy: bool = False,
               xlabel: str = "", ylabel: str = "") -> None:
    """Multi-series scatter in ASCII (markdown-friendly, no matplotlib)."""
    import math
    marks = "ox+*#@%&"
    all_y = [y for ys in series.values() for y in ys
             if y is not None and math.isfinite(y)]
    if not all_y:
        print("(no data)")
        return
    f = (lambda v: math.log10(max(v, 1e-30))) if logy else (lambda v: v)
    ymin, ymax = min(map(f, all_y)), max(map(f, all_y))
    if ymax == ymin:
        ymax = ymin + 1
    xmin, xmax = min(xs), max(xs)
    if xmax == xmin:
        xmax = xmin + 1
    grid = [[" "] * width for _ in range(height)]
    for si, (name, ys) in enumerate(series.items()):
        m = marks[si % len(marks)]
        for x, y in zip(xs, ys):
            if y is None or not math.isfinite(y):
                continue
            col = int((x - xmin) / (xmax - xmin) * (width - 1))
            row = int((f(y) - ymin) / (ymax - ymin) * (height - 1))
            grid[height - 1 - row][col] = m
    tag = " (log y)" if logy else ""
    print(f"    {ylabel}{tag}")
    for r in grid:
        print("  | " + "".join(r))
    print("  +" + "-" * (width + 1))
    print(f"    {xmin:g} ... {xmax:g}  {xlabel}")
    for si, name in enumerate(series):
        print(f"    [{marks[si % len(marks)]}] {name}")


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0
