"""Fleet serving: joint water-filling allocation vs equal split over one
shared edge server (DESIGN.md §11).

Three heterogeneous agents — one deadline-tight drone and two slack
monitors, over two different smoke architectures — share the server.
Under an equal split the tight agent's slice forces it down to a coarse
bit-width; the joint allocator shrinks the slack agents to their
thresholds (they stay at b̂ = 16 regardless) and spends the freed share
on the tight agent, which climbs to a finer b̂ at the *same* per-agent
(T0, E0) budgets.  Both allocations then serve identical per-agent
request streams through :class:`FleetCoInferenceEngine` and are scored
on measured output distortion against full-precision references.

Acceptance (ISSUE 5, raised on regression so CI fails):

  * joint beats equal-split on the aggregate distortion *bound*
    (Σ w_i · objective_i) at matched budgets;
  * joint beats equal-split on aggregate *measured* distortion;
  * a single-agent fleet is bitwise identical to
    ``BatchedCoInferenceEngine`` (stats and logits).

Besides the printed tables, ``run()`` writes machine-readable
``BENCH_fleet.json`` at the repo root, the fleet-serving perf record
diffed across PRs.

Run:  PYTHONPATH=src python -m benchmarks.run --only fleet
  or  PYTHONPATH=src python benchmarks/fleet.py
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CoInferenceEngine,
                           FleetAgentSpec, FleetCoInferenceEngine, QosClass)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

SEQ = 16
MAX_BATCH = 2
REQUESTS_PER_AGENT = 6

# the calibrated decision-scale workload of DESIGN.md §7: server delay
# (0.15 s / share at f̃_max) is a real fraction of the tight deadline, so
# the share split genuinely moves the feasible bit-widths
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)

# (name, arch, T0, E0, weight): "drone" is deadline-tight — at an equal
# 1/3 slice its minimum server time caps it at b̂ = 5; the monitors are
# slack enough to hold b̂ = 16 down to a ~0.08 slice
AGENTS = [
    ("drone", "qwen2-0.5b", 0.8, 8.0, 1.0),
    ("monitor-a", "stablelm-3b", 3.0, 4.0, 1.0),
    ("monitor-b", "qwen2-0.5b", 3.0, 4.0, 1.0),
]


def build_specs() -> List[FleetAgentSpec]:
    models: Dict[str, tuple] = {}
    specs = []
    for name, arch, t0, e0, weight in AGENTS:
        if arch not in models:
            cfg = get_smoke(arch)
            model = build_model(cfg)
            models[arch] = (model, model.init(jax.random.PRNGKey(0)))
        model, params = models[arch]
        specs.append(FleetAgentSpec(
            name=name, model=model, params=params, sysp=SYSP,
            qos=QosClass(name, t0=t0, e0=e0), weight=weight))
    return specs


def request_streams(specs, n: int = REQUESTS_PER_AGENT, seed: int = 3
                    ) -> Dict[str, list]:
    """Per-agent token streams, identical across both allocators."""
    rng = np.random.default_rng(seed)
    return {
        s.name: [rng.integers(0, s.model.cfg.vocab_size,
                              size=int(rng.integers(SEQ // 2, SEQ + 1)))
                 for _ in range(n)]
        for s in specs}


def reference_logits(specs, streams) -> Dict[str, list]:
    """Full-precision logits per request (b̂ = b_emb = 16)."""
    refs: Dict[str, list] = {}
    clean: Dict[int, CoInferenceEngine] = {}
    for s in specs:
        key = id(s.model)
        if key not in clean:
            eng = CoInferenceEngine(s.model, s.params, SYSP, b_emb=16)
            eng.configure(16)
            clean[key] = eng
        eng = clean[key]
        refs[s.name] = []
        for toks in streams[s.name]:
            out, _ = eng.serve_batch(
                {"tokens": jnp.asarray(toks, jnp.int32)[None]})
            refs[s.name].append(out[0])
    return refs


def run_allocator(allocator: str, specs, streams, refs) -> dict:
    fleet = FleetCoInferenceEngine(specs, allocator=allocator,
                                   max_batch=MAX_BATCH)
    for s in specs:
        for i, toks in enumerate(streams[s.name]):
            fleet.submit(s.name, toks, arrival_s=0.0)
    responses = fleet.drain()
    rep = fleet.report()

    per_agent = []
    agg_dist = 0.0
    for s, pa in zip(specs, rep.per_agent):
        by_id = {r.request_id: r for r in responses[s.name]}
        dist = sum(float(jnp.sum(jnp.abs(by_id[i].logits
                                         - refs[s.name][i])))
                   for i in range(len(streams[s.name])))
        dist /= len(streams[s.name])
        agg_dist += s.weight * dist
        # per-agent stats serialize themselves (DESIGN.md §14); only the
        # benchmark-side distortion score is hand-added
        row = pa.to_dict()
        row["distortion"] = dist
        per_agent.append(row)
    return {
        "allocator": allocator,
        "aggregate_bound": rep.aggregate_bound,
        "aggregate_distortion": agg_dist,
        "deadline_violations": rep.deadline_violations,
        "energy_j": rep.total_energy_j,
        "p1_solves": rep.codesign_misses,
        "per_agent": per_agent,
    }


def verify_single_agent_bitwise(specs, streams) -> bool:
    """A one-agent fleet must reproduce ``BatchedCoInferenceEngine``
    bit for bit (share exactly 1.0 ⇒ identical SystemParams)."""
    s = specs[0]
    fleet = FleetCoInferenceEngine([s], allocator="joint",
                                   max_batch=MAX_BATCH)
    solo = BatchedCoInferenceEngine(s.model, s.params, s.sysp,
                                    classes=[s.qos], max_batch=MAX_BATCH)
    for toks in streams[s.name]:
        fleet.submit(s.name, toks)
        solo.submit(toks, s.qos.name)
    ra, rb = fleet.drain()[s.name], solo.drain()
    if len(ra) != len(rb):
        return False
    return all(x.stats == y.stats
               and np.array_equal(np.asarray(x.logits),
                                  np.asarray(y.logits))
               for x, y in zip(ra, rb))


def run() -> dict:
    specs = build_specs()
    streams = request_streams(specs)
    print(f"fleet: {len(specs)} agents over one edge server "
          f"(f̃_max shared), {REQUESTS_PER_AGENT} requests/agent, "
          f"max_batch={MAX_BATCH}")
    refs = reference_logits(specs, streams)

    rows = [run_allocator(a, specs, streams, refs)
            for a in ("equal", "joint")]
    by = {r["allocator"]: r for r in rows}

    for r in rows:
        print(f"\nallocator={r['allocator']}: aggregate bound "
              f"{r['aggregate_bound']:.4e}, aggregate distortion "
              f"{r['aggregate_distortion']:.2f}, "
              f"{r['p1_solves']} (P1) solves")
        table(["agent", "share", "b_hat", "bound", "distortion",
               "violations"],
              [[p["name"], f"{p['share']:.3f}", p["b_hat"],
                f"{p['bound']:.3e}", f"{p['distortion']:.2f}",
                p["deadline_violations"]] for p in r["per_agent"]])

    bitwise = verify_single_agent_bitwise(specs, streams)
    acceptance = {
        "joint_beats_equal_bound":
            by["joint"]["aggregate_bound"] < by["equal"]["aggregate_bound"],
        "joint_beats_equal_distortion":
            by["joint"]["aggregate_distortion"]
            < by["equal"]["aggregate_distortion"],
        "single_agent_bitwise": bitwise,
    }
    ok = all(acceptance.values())
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "seq": SEQ, "max_batch": MAX_BATCH,
        "requests_per_agent": REQUESTS_PER_AGENT,
        "agents": [{"name": n, "arch": a, "t0": t, "e0": e, "weight": w}
                   for n, a, t, e, w in AGENTS],
        "allocators": by,
        "acceptance": acceptance,
    }
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok:
        # CI runs this section in the extras job; a regression of the
        # ISSUE 5 acceptance criteria must fail the build, not just
        # print — benchmarks/run.py converts the raise into a failed
        # section and a nonzero exit
        raise RuntimeError(f"fleet-serving acceptance failed: {acceptance}")
    return results


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the fleet numbers as ``BENCH_fleet.json`` at the repo root —
    the machine-readable record diffed across PRs."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_fleet.json"
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
