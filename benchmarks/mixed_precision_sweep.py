"""Mixed-precision vs uniform quantization at matched (T0, E0) budgets
(DESIGN.md §8).

For a sweep of delay/energy budgets on a qwen2-0.5b smoke model (split
widened so the agent partition has several layers to allocate over),
compare:

  * **uniform**  — the largest feasible uniform b̂ (what ``solve_oracle``
    assigns; the repo's behavior before mixed precision);
  * **allocated** — the per-layer plan of
    ``core.mixed_precision.allocate_bits``, which spends the *same*
    total bit budget where the chain-bound sensitivities A^(l) and
    per-layer rates λ^(l) say it buys the most distortion reduction.

Both operating points are feasible under the same (T0, E0) — the
allocation's mean bit-width never exceeds the feasibility frontier the
uniform b̂ is the floor of — so any distortion difference is pure
allocation, not extra budget headroom in delay or energy.

Two columns matter:

  * the model-side bound Σ_l A^(l)·D^U(b_l − 1; λ_l) (what the allocator
    minimizes), and
  * the *measured* output distortion ‖f(x, W) − f(x, Ŵ)‖₁ through the
    actual quantized forward (``measured_output_distortion``), which
    must show the same ordering for the bound to be a useful proxy.

Run:  PYTHONPATH=src python -m benchmarks.run --only mixed
  or  PYTHONPATH=src python benchmarks/mixed_precision_sweep.py
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import mixed_precision as mp
from repro.core.cost_model import SystemParams
from repro.core.distortion import measured_output_distortion
from repro.core.quantization import QuantConfig
from repro.models.registry import build_model
from repro.runtime.qat import fake_quantize_agent

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SPLIT = 3                      # widen the smoke split: 3 agent layers of 4
SEQ = 24
BATCH = 4
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
# budgets spanning tight -> loose; uniform b̂ lands on different widths
BUDGETS = [(1.12, 0.92), (1.18, 1.05), (1.30, 1.50), (1.60, 2.50)]


def _measured(model, params, axes, cfg, qcfg, x) -> float:
    """Output distortion of the whole forward with the agent partition
    fake-quantized by ``qcfg`` (a QuantConfig or a QuantPlan)."""
    params_hat = fake_quantize_agent(params, axes, cfg, qcfg, ste=False)

    def apply_fn(p, toks):
        return model.forward(p, {"tokens": toks})[0]

    return float(measured_output_distortion(apply_fn, params, params_hat, x))


def sweep(model, params, stats: mp.LayerStats, x) -> List[dict]:
    cfg = model.cfg
    axes = model.logical_axes()
    rows = []
    for t0, e0 in BUDGETS:
        sol = mp.allocate_bits(stats, SYSP, t0, e0, b_max=16)
        if sol is None:
            rows.append({"t0": t0, "e0": e0, "infeasible": True})
            continue
        ucfg = QuantConfig(bits=sol.uniform_b, granularity="per-channel")
        plan = mp.plan_from_bits(sol.bits)
        d_uni = _measured(model, params, axes, cfg, ucfg, x)
        d_mix = _measured(model, params, axes, cfg, plan, x)
        rows.append({
            "t0": t0, "e0": e0, "infeasible": False,
            "uniform_b": sol.uniform_b, "bits": sol.bits,
            "mean_bits": sol.mean_bits,
            "bound_uniform": sol.uniform_objective,
            "bound_mixed": sol.objective,
            "measured_uniform": d_uni, "measured_mixed": d_mix,
        })
    return rows


def run() -> List[dict]:
    cfg = dataclasses.replace(get_smoke(ARCH), split_layer=SPLIT)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stats = mp.decoder_layer_stats(params, SPLIT)
    print(f"arch={cfg.name} split={SPLIT}/{cfg.n_layers} "
          f"lambda^(l)={[f'{v:.1f}' for v in stats.lam]} "
          f"A^(l)={[f'{v:.3g}' for v in stats.sens]}")

    x = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)
    rows = sweep(model, params, stats, x)

    table(["T0 (s)", "E0 (J)", "uniform b", "allocated bits", "mean",
           "bound uni", "bound mix", "meas uni", "meas mix"],
          [[r["t0"], r["e0"], r["uniform_b"],
            "/".join(map(str, r["bits"])), f"{r['mean_bits']:.2f}",
            f"{r['bound_uniform']:.3e}", f"{r['bound_mixed']:.3e}",
            f"{r['measured_uniform']:.1f}", f"{r['measured_mixed']:.1f}"]
           for r in rows if not r["infeasible"]])

    feas = [r for r in rows if not r["infeasible"]]
    bound_ok = all(r["bound_mixed"] <= r["bound_uniform"] * (1 + 1e-9)
                   for r in feas)
    bound_strict = any(r["bound_mixed"] < r["bound_uniform"] * (1 - 1e-6)
                       for r in feas)
    meas_ok = sum(r["measured_mixed"] <= r["measured_uniform"]
                  for r in feas)
    print(f"bound: allocated <= uniform on {len(feas)}/{len(feas)} budgets "
          f"({'strictly better on >=1' if bound_strict else 'never strict'})"
          f" -> {'PASS' if bound_ok and bound_strict else 'FAIL'}")
    print(f"measured output distortion: allocated <= uniform on "
          f"{meas_ok}/{len(feas)} budgets -> "
          f"{'PASS' if meas_ok == len(feas) else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
