"""Paper Fig. 2: exponential fit of weight magnitudes across model families.

The paper fits Exponential(lam) to |w| of ResNet-152 / VideoMAE / BERT /
BLIP-2 / GIT / GPT-3 checkpoints.  Offline we fit the same statistic on our
model zoo (trained-from-scratch reduced configs + random-init full-family
blocks) and report the MLE lam together with a Kolmogorov-Smirnov distance
to the fitted exponential — the quantitative version of the paper's visual
histogram match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.rate_distortion import exponential_mle
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime import TrainConfig, Trainer

from .common import banner, table

ARCHS = ("stablelm-3b", "qwen2-0.5b", "xlstm-350m", "kimi-k2-1t-a32b",
         "seamless-m4t-large-v2", "jamba-1.5-large-398b")


def ks_distance_exponential(sample: np.ndarray, lam: float) -> float:
    """sup_x |F_emp(x) - F_exp(x)| with F_exp(x) = 1 - exp(-lam x)."""
    xs = np.sort(sample)
    emp = np.arange(1, len(xs) + 1) / len(xs)
    model = 1.0 - np.exp(-lam * xs)
    return float(np.max(np.abs(emp - model)))


def magnitudes(params, max_n: int = 200_000) -> np.ndarray:
    rng = np.random.default_rng(0)
    chunks = []
    for leaf in jax.tree_util.tree_leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            chunks.append(np.abs(np.asarray(leaf, np.float32)).ravel())
    mags = np.concatenate(chunks)
    if len(mags) > max_n:
        mags = rng.choice(mags, max_n, replace=False)
    return mags[mags > 0]


def _trained_params(arch: str, steps: int = 30):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    tr = Trainer(model, AdamW(learning_rate=3e-3), make_host_mesh(),
                 TrainConfig(log_every=1000))
    loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)))
    try:
        (params, _, _), _ = tr.fit(loader, steps)
        return params, True
    except Exception:
        return build_model(cfg).init(jax.random.PRNGKey(0)), False


def run() -> dict:
    banner("Fig. 2 — weight-magnitude distribution: Exponential(lam) fit")
    rows, out = [], {}
    for arch in ARCHS:
        params, trained = _trained_params(arch)
        mags = magnitudes(params)
        lam = float(exponential_mle(jnp.asarray(mags)))
        ks = ks_distance_exponential(mags, lam)
        frac_small = float((mags < 1.0 / lam).mean())  # exp predicts 0.632
        rows.append([arch, "trained" if trained else "init",
                     f"{lam:.1f}", f"{ks:.3f}", f"{frac_small:.3f}"])
        out[arch] = {"lambda": lam, "ks": ks}
    table(["model", "weights", "lambda_hat", "KS_dist",
           "P(|w|<1/lam) [exp: 0.632]"], rows)
    print("\nSmall KS distance + mass-below-mean near 0.632 => the "
          "exponential magnitude prior of paper eq. (3) holds on this zoo.")
    return out


if __name__ == "__main__":
    run()
