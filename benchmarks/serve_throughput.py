"""Serving throughput: batched vs sequential co-inference (DESIGN.md §7).

Three sweeps on the ``qwen2_0_5b`` config (smoke-scaled so the sweep runs
on CPU; the engine code is identical at full scale):

  1. batch size  — wall-clock requests/s of one fused forward of R requests
                   vs R single-request forwards, plus bitwise verification
                   that batching never changes a request's logits.  The
                   acceptance bar is >= 2x at R = 8.
  2. bit-width   — the same comparison across agent bit-widths (kernel path
                   where int8/int4-resident weights apply, fake elsewhere).
  3. QoS mix     — the full BatchedCoInferenceEngine queue under different
                   traffic mixes: batch occupancy, modeled queue wait,
                   amortized delay/energy per class, and codesign cache
                   hit/miss counts ((P1) solved once per class, not once
                   per request).

Wall-clock numbers measure host dispatch + compute of the smoke model and
are the point of batching on this CPU container; the modeled delay/energy
columns come from the paper's cost model (eqs. 4-9) and are what the
co-design optimizes.

Besides the printed tables, ``run()`` writes the machine-readable
``BENCH_serve.json`` at the repo root (requests/s per batch size,
bit-width sweep with measured output distortion, QoS-mix stats) so the
serving-perf trajectory is tracked across PRs instead of only printed.

Run:  PYTHONPATH=src python -m benchmarks.run --only serve
  or  PYTHONPATH=src python benchmarks/serve_throughput.py
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CodesignCache,
                           CoInferenceEngine, QosClass)

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 32
SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
CLASSES = [
    QosClass("realtime", t0=1.10, e0=0.9),
    QosClass("interactive", t0=1.30, e0=1.5),
    QosClass("batch", t0=2.50, e0=4.0),
]
MIXES = {
    "uniform": ("realtime", "interactive", "batch"),
    "rt-heavy": ("realtime", "realtime", "realtime", "interactive"),
    "batch-only": ("batch",),
}


def _tokens(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=(n, SEQ)).astype(np.int32)


def _time_sequential(eng: CoInferenceEngine, toks: np.ndarray,
                     repeats: int = 3) -> float:
    """Best-of wall-clock seconds to serve each row as its own request."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for i in range(toks.shape[0]):
            logits, _ = eng.serve_batch({"tokens": jnp.asarray(toks[i:i+1])})
        logits.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_batched(eng: CoInferenceEngine, toks: np.ndarray,
                  batch: int, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for lo in range(0, toks.shape[0], batch):
            logits, _ = eng.serve_batch(
                {"tokens": jnp.asarray(toks[lo:lo + batch])})
        logits.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _verify_bitwise(eng: CoInferenceEngine, toks: np.ndarray) -> bool:
    batched, _ = eng.serve_batch({"tokens": jnp.asarray(toks)})
    batched = np.asarray(batched)
    for i in range(toks.shape[0]):
        single, _ = eng.serve_batch({"tokens": jnp.asarray(toks[i:i+1])})
        if not np.array_equal(batched[i], np.asarray(single[0])):
            return False
    return True


def sweep_batch_size(model, params, path: str = "kernel",
                     sizes: Sequence[int] = (1, 2, 4, 8, 16),
                     n_requests: int = 16) -> List[dict]:
    eng = CoInferenceEngine(model, params, SYSP, path=path)
    eng.configure(8)
    toks = _tokens(model.cfg, n_requests)
    # warm up every shape the sweep will dispatch
    for b in set(sizes) | {1}:
        eng.serve_batch({"tokens": jnp.asarray(toks[:b])})
    t_seq = _time_sequential(eng, toks)
    rows = []
    for b in sizes:
        t = _time_batched(eng, toks, b)
        rows.append({
            "batch": b,
            "req_per_s": n_requests / t,
            "speedup": t_seq / t,
            "bitwise": _verify_bitwise(eng, toks[:b]),
        })
    rows[0]["seq_req_per_s"] = n_requests / t_seq
    return rows


def sweep_bitwidth(model, params, batch: int = 8,
                   n_requests: int = 16) -> List[dict]:
    toks = _tokens(model.cfg, n_requests, seed=1)
    # full-precision reference for the distortion column (b_emb=16 so the
    # uplink quantizer does not blur the weight-quantization signal)
    ref_eng = CoInferenceEngine(model, params, SYSP, path="fake", b_emb=16)
    ref_eng.configure(16)
    ref, _ = ref_eng.serve_batch({"tokens": jnp.asarray(toks[:batch])})
    rows = []
    for b_hat, path in ((4, "kernel"), (8, "kernel"), (8, "fake"),
                        (16, "fake")):
        eng = CoInferenceEngine(model, params, SYSP, path=path)
        eng.configure(b_hat)
        eng.serve_batch({"tokens": jnp.asarray(toks[:batch])})  # warm
        eng.serve_batch({"tokens": jnp.asarray(toks[:1])})
        t_seq = _time_sequential(eng, toks)
        t_bat = _time_batched(eng, toks, batch)
        eng.b_emb = 16   # eng is per-iteration; only the distortion read
        logits, _ = eng.serve_batch({"tokens": jnp.asarray(toks[:batch])})
        rows.append({
            "b_hat": b_hat, "path": path,
            "seq_rps": n_requests / t_seq,
            "batched_rps": n_requests / t_bat,
            "speedup": t_seq / t_bat,
            "distortion": float(jnp.sum(jnp.abs(logits - ref))) / batch,
        })
    return rows


def sweep_qos_mix(model, params, n_requests: int = 24,
                  max_batch: int = 8) -> List[dict]:
    rows = []
    cache = CodesignCache()   # shared: later mixes hit earlier solves
    for mix_name, mix in MIXES.items():
        eng = BatchedCoInferenceEngine(
            model, params, SYSP, classes=CLASSES, max_batch=max_batch,
            path="kernel", codesign_cache=cache)
        rng = np.random.default_rng(7)
        for i in range(n_requests):
            toks = rng.integers(0, model.cfg.vocab_size,
                                size=int(rng.integers(SEQ // 2, SEQ + 1)))
            eng.submit(toks, mix[i % len(mix)])
        eng.drain()
        rep = eng.report()
        rows.append({
            "mix": mix_name,
            "batches": rep.batches_served,
            "mean_batch": rep.mean_batch_size,
            "occupancy": rep.mean_occupancy,
            "amort_delay_s": rep.total_delay_s / rep.requests_served,
            "amort_energy_j": rep.total_energy_j / rep.requests_served,
            "model_rps": rep.throughput_rps,
            "p1_solves": rep.codesign_misses,
        })
    rows[-1]["cache"] = f"{cache.hits} hits / {cache.misses} misses"
    return rows


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} seq={SEQ} (smoke scale; CPU interpret kernels)")

    bs = sweep_batch_size(model, params)
    print(f"\nbatch-size sweep, kernel path, b_hat=8 "
          f"(sequential: {bs[0]['seq_req_per_s']:.1f} req/s):")
    table(["batch", "req/s", "speedup vs sequential", "bitwise == seq"],
          [[r["batch"], f"{r['req_per_s']:.1f}", f"{r['speedup']:.2f}x",
            "yes" if r["bitwise"] else "NO"] for r in bs])
    at8 = next(r for r in bs if r["batch"] == 8)
    ok = at8["speedup"] >= 2.0 and at8["bitwise"]
    print(f"acceptance (>=2x at batch 8, bitwise-identical): "
          f"{'PASS' if ok else 'FAIL'} ({at8['speedup']:.2f}x)")

    bw = sweep_bitwidth(model, params)
    print("\nbit-width sweep at batch 8 (distortion: sum|Δlogits|/request "
          "vs full precision at b_emb=16):")
    table(["b_hat", "path", "seq req/s", "batched req/s", "speedup",
           "distortion"],
          [[r["b_hat"], r["path"], f"{r['seq_rps']:.1f}",
            f"{r['batched_rps']:.1f}", f"{r['speedup']:.2f}x",
            f"{r['distortion']:.2f}"] for r in bw])

    qm = sweep_qos_mix(model, params)
    print("\nQoS-mix sweep through the batched queue (modeled time):")
    table(["mix", "batches", "mean batch", "occupancy", "amort T (s)",
           "amort E (J)", "model req/s", "(P1) solves"],
          [[r["mix"], r["batches"], f"{r['mean_batch']:.2f}",
            f"{r['occupancy']:.2f}", f"{r['amort_delay_s']:.3e}",
            f"{r['amort_energy_j']:.3e}", f"{r['model_rps']:.0f}",
            r["p1_solves"]] for r in qm])
    print(f"shared codesign cache across mixes: {qm[-1]['cache']} — "
          "every request after the first of a class reuses its solve")

    results = {"arch": cfg.name, "seq": SEQ,
               "batch_size_sweep": bs, "bitwidth_sweep": bw,
               "qos_mix_sweep": qm}
    out = write_json(results)
    print(f"\nwrote {out}")
    return results


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the serving-benchmark numbers as ``BENCH_serve.json`` at the
    repo root — the machine-readable perf record diffed across PRs."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_serve.json"
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
