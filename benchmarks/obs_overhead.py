"""Observability overhead gate: decode throughput traced vs untraced
(DESIGN.md §14).

The obs layer's contract is two-sided:

  1. *Disabled is free.*  Engines built without a tracer/metrics get the
     module-level no-op singletons (``NULL_TRACER`` / ``NULL_METRICS``)
     — verified by identity, plus a microbenchmark that the null span
     costs nanoseconds and buffers nothing.
  2. *Enabled is cheap.*  The same decode stream served through a live
     ``Tracer`` + ``MetricsRegistry`` must stay within
     ``OVERHEAD_TOLERANCE`` (3%) of the untraced wall-clock tok/s,
     best-of-``REPEATS`` to absorb machine jitter, and the generated
     tokens must be **bitwise identical** — instrumentation observes the
     run, it never perturbs it.

``run()`` RAISES when either side fails, so CI's extras job turns an
obs-layer regression into a red build.  Results (including the traced
run's metrics snapshot) land in ``BENCH_obs.json`` and, via
``benchmarks/run.py``, on the BENCH_history.jsonl row.

Run:  PYTHONPATH=src python -m benchmarks.run --only obs_overhead
  or  PYTHONPATH=src python benchmarks/obs_overhead.py
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.models.registry import build_model
from repro.obs import NULL_METRICS, NULL_TRACER, MetricsRegistry, Tracer
from repro.runtime import CompiledForwardCache, DecodeEngine, QosClass

try:
    from .common import table
except ImportError:  # executed as a script, not via benchmarks.run
    from common import table

ARCH = "qwen2-0.5b"
SEQ = 16
MAX_NEW = 8
MAX_BATCH = 4
N_REQUESTS = 10
REPEATS = 3              # best-of, alternating modes to decorrelate drift
OVERHEAD_TOLERANCE = 0.03
# null-span microbench: generous per-call ceiling — the no-op singleton
# is two attribute lookups and a constant return, ~100x under this
NULL_SPAN_BUDGET_S = 2.0e-6
CLASSES = [
    QosClass("realtime", t0=1.2, e0=1.0),
    QosClass("interactive", t0=3.5, e0=2.0),
]


def make_sysp(cfg) -> SystemParams:
    per_layer = cfg.active_param_count() / max(cfg.n_layers, 1)
    tokens = MAX_BATCH * SEQ
    kv_full = (2.0 * cfg.n_layers * MAX_BATCH * (SEQ + MAX_NEW)
               * cfg.n_kv_heads * cfg.head_dim
               * np.dtype(cfg.dtype).itemsize)
    return SystemParams(
        n_flop_agent=2.0 * per_layer * cfg.split_layer * tokens,
        n_flop_server=2.0 * per_layer
        * (cfg.n_layers - cfg.split_layer) * tokens,
        kv_bytes_full=kv_full, kv_bw_bps=kv_full, kv_power_w=2.0)


def traffic(cfg, seed: int = 11):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(SEQ // 2, SEQ + 1)))
        out.append((toks.astype(np.int32), CLASSES[i % len(CLASSES)].name,
                    int(rng.integers(2, MAX_NEW + 1)), 0.01 * i))
    return out


def serve_once(model, params, sysp, cache, tracer, metrics):
    """One full drain of the shared stream; returns (wall_s, tokens)."""
    eng = DecodeEngine(model, params, sysp, classes=CLASSES,
                       max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                       compile_cache=cache, tracer=tracer, metrics=metrics)
    eng.warmup(SEQ)        # hits the shared cache after the first engine
    for toks, qos, n_new, t in traffic(model.cfg):
        eng.submit(toks, qos, max_new_tokens=n_new, arrival_s=t)
    t0 = time.perf_counter()
    responses = eng.drain()
    wall_s = time.perf_counter() - t0
    tokens = [np.asarray(r.tokens)
              for r in sorted(responses, key=lambda r: r.request_id)]
    return wall_s, tokens


def null_span_cost(n: int = 100_000) -> float:
    """Seconds per NULL_TRACER.span(...) enter/exit round trip."""
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL_TRACER.span("x", qos="a", n=4):
            pass
    return (time.perf_counter() - t0) / n


def run() -> dict:
    cfg = get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = make_sysp(cfg)
    cache = CompiledForwardCache()   # shared: every mode runs warm
    print(f"arch={cfg.name} max_batch={MAX_BATCH} prompts<= {SEQ} "
          f"new<= {MAX_NEW} ({N_REQUESTS} requests, best of {REPEATS})")

    # --- disabled is free: structural + microbenched -------------------
    eng = DecodeEngine(model, params, sysp, classes=CLASSES,
                       max_batch=MAX_BATCH, max_new_tokens=MAX_NEW,
                       compile_cache=cache)
    default_is_null = (eng.tracer is NULL_TRACER
                       and eng.metrics is NULL_METRICS)
    span_cost = null_span_cost()
    null_buffers_nothing = len(NULL_TRACER.events) == 0
    print(f"disabled path: default tracer is the no-op singleton="
          f"{default_is_null}, null span {span_cost * 1e9:.0f} ns/call "
          f"(budget {NULL_SPAN_BUDGET_S * 1e9:.0f} ns), "
          f"buffered events={len(NULL_TRACER.events)}")

    # --- enabled overhead: alternate modes, keep the best of each ------
    walls = {"off": [], "on": []}
    tokens_by = {}
    metrics = None
    for rep in range(REPEATS):
        w, toks = serve_once(model, params, sysp, cache,
                             NULL_TRACER, NULL_METRICS)
        walls["off"].append(w)
        tokens_by.setdefault("off", toks)
        tr, metrics = Tracer(), MetricsRegistry()
        w, toks = serve_once(model, params, sysp, cache, tr, metrics)
        walls["on"].append(w)
        tokens_by.setdefault("on", toks)

    n_tok = sum(len(t) for t in tokens_by["off"])
    best = {k: min(v) for k, v in walls.items()}
    overhead = best["on"] / best["off"] - 1.0
    bitwise = (len(tokens_by["off"]) == len(tokens_by["on"])
               and all(np.array_equal(a, b)
                       for a, b in zip(tokens_by["off"], tokens_by["on"])))
    table(["tracing", "best drain", "tok/s wall"],
          [[k, f"{best[k] * 1e3:.1f} ms", f"{n_tok / best[k]:.1f}"]
           for k in ("off", "on")])
    print(f"enabled overhead: {overhead * 100:+.2f}% "
          f"(tolerance {OVERHEAD_TOLERANCE * 100:.0f}%), "
          f"bitwise-identical tokens={bitwise}")

    acceptance = {
        "default_obs_is_noop_singleton": default_is_null,
        "null_tracer_buffers_nothing": null_buffers_nothing,
        "null_span_within_budget": span_cost < NULL_SPAN_BUDGET_S,
        "enabled_overhead_within_tolerance":
            overhead <= OVERHEAD_TOLERANCE,
        "traced_equals_untraced_bitwise": bitwise,
    }
    ok = all(v for v in acceptance.values() if isinstance(v, bool))
    print(f"\nacceptance: {'PASS' if ok else 'FAIL'}")
    for k, v in acceptance.items():
        print(f"  {k}: {v}")

    results = {
        "acceptance_ok": ok,
        "arch": cfg.name, "requests": N_REQUESTS, "repeats": REPEATS,
        # the tracked ratio: traced / untraced wall clock (1.0 = free)
        "ratio": best["on"] / best["off"],
        "overhead_frac": overhead,
        "overhead_tolerance": OVERHEAD_TOLERANCE,
        "null_span_seconds": span_cost,
        "wall_s": {k: {"best": best[k], "all": walls[k]}
                   for k in ("off", "on")},
        "tokens_generated": n_tok,
        "acceptance": acceptance,
        "metrics": metrics.snapshot() if metrics is not None else {},
    }
    out = write_json(results)
    print(f"\nwrote {out}")
    if not ok:
        # CI runs this section in the extras job; a 3%+ tracing tax or a
        # single perturbed token must fail the build (DESIGN.md §14)
        raise RuntimeError(f"obs overhead acceptance failed: {acceptance}")
    return results


def write_json(results: dict,
               path: "pathlib.Path | None" = None) -> pathlib.Path:
    """Dump the overhead numbers as ``BENCH_obs.json`` at the repo root
    — the machine-readable obs perf record diffed across PRs."""
    if path is None:
        path = pathlib.Path(__file__).resolve().parent.parent \
            / "BENCH_obs.json"
    path.write_text(json.dumps(results, indent=2, sort_keys=True) + "\n",
                    encoding="utf-8")
    return path


if __name__ == "__main__":
    run()
