#!/usr/bin/env python3
"""Fail if any ``DESIGN.md §N`` reference in ``src/`` points at a section
that does not exist in DESIGN.md.

Usage:  python tools/check_design_refs.py [--root <repo-root>]

Sections are headings of the form ``## §N <title>``.  References matched:
``DESIGN.md §N`` (also ``DESIGN.md §N.M``, which resolves to section N).
Exit code 0 when every reference resolves, 1 otherwise (each dangling
reference is printed as file:line).
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

SECTION_RE = re.compile(r"^##\s*§(\d+)\b", re.MULTILINE)
REF_RE = re.compile(r"DESIGN\.md\s*§(\d+)")


def design_sections(design_path: pathlib.Path) -> set:
    return {int(m) for m in SECTION_RE.findall(
        design_path.read_text(encoding="utf-8"))}


def find_refs(src_root: pathlib.Path):
    """Yields (path, line_number, section) for every DESIGN.md §N mention."""
    for path in sorted(src_root.rglob("*.py")):
        for i, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            for m in REF_RE.finditer(line):
                yield path, i, int(m.group(1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: parent of this script's dir)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root \
        else pathlib.Path(__file__).resolve().parent.parent

    design = root / "DESIGN.md"
    if not design.is_file():
        print(f"FAIL: {design} does not exist")
        return 1
    sections = design_sections(design)
    if not sections:
        print(f"FAIL: no '## §N' sections found in {design}")
        return 1

    n_refs, dangling = 0, []
    for path, line, sec in find_refs(root / "src"):
        n_refs += 1
        if sec not in sections:
            dangling.append((path, line, sec))

    for path, line, sec in dangling:
        print(f"{path.relative_to(root)}:{line}: DESIGN.md §{sec} "
              f"does not exist (have §{sorted(sections)})")
    if dangling:
        print(f"FAIL: {len(dangling)}/{n_refs} DESIGN.md references dangle")
        return 1
    print(f"OK: {n_refs} DESIGN.md references resolve into sections "
          f"{sorted(sections)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
