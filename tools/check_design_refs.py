#!/usr/bin/env python3
"""Docs reference linter.

Checks, over the whole repo:

1. every ``DESIGN.md §N`` reference — in ``src/`` source files AND in
   the documentation set (DESIGN.md itself, README.md,
   docs/ARCHITECTURE.md) — points at a section that exists in DESIGN.md
   (headings of the form ``## §N <title>``);
2. every backtick file citation in the documentation set (a
   `path/with/slashes.ext` for ext in py/md/json/toml/yml) resolves to a
   real file, tried relative to the repo root, ``src/``, and
   ``src/repro/`` (so DESIGN.md can keep citing ``core/codesign.py``).
   Citations without a ``/`` are skipped — they are module mentions or
   placeholder names (``spec.json``), not paths.

Usage:  python tools/check_design_refs.py [--root <repo-root>]

Exit code 0 when every reference resolves, 1 otherwise (each dangling
reference is printed as file:line).
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

SECTION_RE = re.compile(r"^##\s*§(\d+)\b", re.MULTILINE)
REF_RE = re.compile(r"DESIGN\.md\s*§(\d+)")
# `benchmarks/fleet.py`, `docs/ARCHITECTURE.md`, `.github/workflows/ci.yml`
FILE_RE = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
                     r"\.(?:py|md|json|toml|yml))`")

DOC_FILES = ("DESIGN.md", "README.md", "docs/ARCHITECTURE.md")


def design_sections(design_path: pathlib.Path) -> set:
    return {int(m) for m in SECTION_RE.findall(
        design_path.read_text(encoding="utf-8"))}


def find_refs(src_root: pathlib.Path):
    """Yields (path, line_number, section) for every DESIGN.md §N mention."""
    for path in sorted(src_root.rglob("*.py")):
        yield from file_refs(path)


def file_refs(path: pathlib.Path):
    for i, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        for m in REF_RE.finditer(line):
            yield path, i, int(m.group(1))


def file_citations(path: pathlib.Path):
    """Yields (path, line_number, cited_path) for every backtick file
    citation with at least one '/' in the given document."""
    for i, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        for m in FILE_RE.finditer(line):
            yield path, i, m.group(1)


def resolves(root: pathlib.Path, cited: str) -> bool:
    return any((base / cited).is_file()
               for base in (root, root / "src", root / "src" / "repro"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: parent of this script's dir)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root \
        else pathlib.Path(__file__).resolve().parent.parent

    design = root / "DESIGN.md"
    if not design.is_file():
        print(f"FAIL: {design} does not exist")
        return 1
    sections = design_sections(design)
    if not sections:
        print(f"FAIL: no '## §N' sections found in {design}")
        return 1

    docs = [root / d for d in DOC_FILES if (root / d).is_file()]

    n_refs, dangling = 0, []
    sources = list(find_refs(root / "src"))
    for doc in docs:
        sources.extend(file_refs(doc))
    for path, line, sec in sources:
        n_refs += 1
        if sec not in sections:
            dangling.append((path, line, f"DESIGN.md §{sec} does not "
                             f"exist (have §{sorted(sections)})"))

    n_cites = 0
    for doc in docs:
        for path, line, cited in file_citations(doc):
            n_cites += 1
            if not resolves(root, cited):
                dangling.append((path, line,
                                 f"cited file {cited} does not exist"))

    for path, line, msg in dangling:
        print(f"{path.relative_to(root)}:{line}: {msg}")
    if dangling:
        print(f"FAIL: {len(dangling)} dangling references "
              f"({n_refs} §-refs, {n_cites} file citations checked)")
        return 1
    print(f"OK: {n_refs} DESIGN.md references resolve into sections "
          f"{sorted(sections)}; {n_cites} file citations across "
          f"{len(docs)} docs resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
