#!/usr/bin/env python
"""Measure tier-1 line coverage of src/repro with a stdlib-only tracer.

CI runs the real thing (`pytest --cov=src/repro --cov-fail-under=N` via
pytest-cov), but the floor N baked into .github/workflows/ci.yml has to
come from somewhere reproducible without installing coverage locally.
This script is that somewhere: a sys.settrace harness that

  1. builds the line universe by compiling every src/repro/**/*.py and
     walking the code objects' co_lines(),
  2. runs the tier-1 pytest suite under a global tracer that installs a
     local line-tracer only for frames whose code lives under src/repro
     (call-event filtering keeps the overhead tolerable), and
  3. prints per-package and total line coverage.

Because settrace line events and coverage.py's arc/line accounting agree
on which lines are executable (both read co_lines()), the totals here
track `coverage report` closely; the CI floor is set 2 points below the
local measurement to absorb residual accounting drift and the
hypothesis-only tests that skip locally.

Usage:
  PYTHONPATH=src python tools/measure_coverage.py [--dump F] [pytest args...]
  PYTHONPATH=src python tools/measure_coverage.py --report-dump F [F2 ...]

--dump writes the accumulated hit-lines to F every few tests (and at
exit), so a crash late in the run loses at most the tail increment;
--report-dump unions one or more dump files and prints the table.  Long
tier-1 runs under the tracer have been seen to segfault inside XLA's
compiler late in the suite (cumulative process state, not any one
test) — measuring in per-chunk processes and merging the dumps
sidesteps that.
"""

from __future__ import annotations

import json
import os
import sys
from collections import defaultdict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")


def line_universe() -> dict[str, set[int]]:
    """All executable lines per file, from compiled code objects."""
    universe: dict[str, set[int]] = {}
    for dirpath, dirnames, filenames in os.walk(SRC):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, "r", encoding="utf-8") as f:
                try:
                    code = compile(f.read(), path, "exec")
                except SyntaxError:
                    continue
            lines: set[int] = set()
            stack = [code]
            while stack:
                co = stack.pop()
                lines.update(ln for _, _, ln in co.co_lines()
                             if ln is not None)
                stack.extend(c for c in co.co_consts
                             if hasattr(c, "co_lines"))
            universe[path] = lines
    return universe


class _PeriodicDump:
    """pytest plugin: persist the hit set every few tests."""

    def __init__(self, hit: dict[str, set[int]], path: str, every: int = 20):
        self.hit, self.path, self.every, self.n = hit, path, every, 0

    def flush(self):
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({p: sorted(ls) for p, ls in self.hit.items()}, f)
        os.replace(tmp, self.path)

    def pytest_runtest_logfinish(self, nodeid, location):
        self.n += 1
        if self.n % self.every == 0:
            self.flush()


def report(universe: dict[str, set[int]], hit: dict[str, set[int]]):
    per_pkg: dict[str, list[int]] = defaultdict(lambda: [0, 0])
    total_hit = total_lines = 0
    for path, lines in sorted(universe.items()):
        pkg = os.path.relpath(path, SRC).split(os.sep)[0]
        h = len(lines & hit.get(path, set()))
        per_pkg[pkg][0] += h
        per_pkg[pkg][1] += len(lines)
        total_hit += h
        total_lines += len(lines)

    print()
    print(f"{'package':<16} {'lines':>7} {'hit':>7} {'cover':>7}")
    for pkg, (h, n) in sorted(per_pkg.items()):
        print(f"{pkg:<16} {n:>7} {h:>7} {100.0 * h / max(n, 1):>6.1f}%")
    pct = 100.0 * total_hit / max(total_lines, 1)
    print(f"{'TOTAL':<16} {total_lines:>7} {total_hit:>7} {pct:>6.1f}%")
    print(f"\nsuggested CI floor (measured - 2pts, rounded down): "
          f"{int(pct) - 2}")


def run(argv: list[str]) -> int:
    if argv[:1] == ["--report-dump"]:
        hit = defaultdict(set)
        for path in argv[1:]:
            with open(path, "r", encoding="utf-8") as f:
                for p, ls in json.load(f).items():
                    hit[p].update(ls)
        report(line_universe(), hit)
        return 0

    dump = None
    if argv[:1] == ["--dump"]:
        dump, argv = argv[1], argv[2:]

    universe = line_universe()
    hit: dict[str, set[int]] = defaultdict(set)

    # co_filename is relative when src/ entered sys.path relatively
    # (PYTHONPATH=src); memoize the abspath so the per-call check stays
    # a dict lookup
    norm: dict[str, str] = {}

    def _abs(fn: str) -> str:
        ap = norm.get(fn)
        if ap is None:
            ap = norm[fn] = os.path.abspath(fn)
        return ap

    def local_tracer(frame, event, arg):
        if event == "line":
            hit[_abs(frame.f_code.co_filename)].add(frame.f_lineno)
        return local_tracer

    def global_tracer(frame, event, arg):
        # only frames whose code lives under src/repro get line events
        if event == "call" and _abs(frame.f_code.co_filename).startswith(SRC):
            return local_tracer
        return None

    import pytest

    plugins = [_PeriodicDump(hit, dump)] if dump else []
    sys.settrace(global_tracer)
    try:
        rc = pytest.main(argv or ["-q", "-x"], plugins=plugins)
    finally:
        sys.settrace(None)
        if plugins:
            plugins[0].flush()

    report(universe, hit)
    return rc


if __name__ == "__main__":
    sys.exit(run(sys.argv[1:]))
