#!/usr/bin/env python3
"""Summarize (or validate) a Chrome trace-event JSON file.

    python tools/trace_summary.py TRACE.json            # summary tables
    python tools/trace_summary.py TRACE.json --validate # schema check only
    python tools/trace_summary.py TRACE.json --top 20

Works on any trace ``launch/serve.py --trace-out`` writes (DESIGN.md
§14): prints the top spans by *self* time (span duration minus the time
spent in its nested children — the number that says where the wall clock
actually went), and a per-QoS-class latency table built from span/event
``args`` carrying a ``qos`` key.  ``--validate`` runs the trace-event
schema checker (``repro.obs.validate_chrome_trace``) and exits nonzero
on any problem — the mode CI's trace-smoke step drives.

Stdlib only when validating is not needed; the validator is imported
from ``src/repro`` with a path fallback so the tool runs from the repo
root without PYTHONPATH.
"""

from __future__ import annotations

import argparse
import collections
import json
import pathlib
import sys


def _load_validator():
    try:
        from repro.obs import validate_chrome_trace
    except ImportError:
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent
                               .parent / "src"))
        from repro.obs import validate_chrome_trace
    return validate_chrome_trace


def span_stats(events):
    """Per-name {count, total_us, self_us} from balanced B/E pairs.

    Self time = a span's duration minus its children's durations,
    computed with one stack per (pid, tid) lane.  Unbalanced tails are
    ignored (the validator, not the summarizer, is the schema gate).
    """
    stats = collections.defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "self_us": 0.0})
    stacks = collections.defaultdict(list)   # lane -> [[name, t0, child]]
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("B", "E"):
            continue
        lane = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks[lane].append([ev.get("name"), float(ev.get("ts", 0)),
                                 0.0])
        elif stacks[lane]:
            name, t0, child = stacks[lane].pop()
            dur = float(ev.get("ts", 0)) - t0
            s = stats[name]
            s["count"] += 1
            s["total_us"] += dur
            s["self_us"] += dur - child
            if stacks[lane]:
                stacks[lane][-1][2] += dur
    return dict(stats)


def qos_latency(events):
    """Per-QoS span-duration aggregates from spans whose args carry
    ``qos``: {qos: {name: [durations_us]}}."""
    out = collections.defaultdict(lambda: collections.defaultdict(list))
    stacks = collections.defaultdict(list)
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("B", "E"):
            continue
        lane = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks[lane].append((ev.get("name"), float(ev.get("ts", 0)),
                                 (ev.get("args") or {}).get("qos")))
        elif stacks[lane]:
            name, t0, qos = stacks[lane].pop()
            if qos is not None:
                out[qos][name].append(float(ev.get("ts", 0)) - t0)
    return {q: dict(v) for q, v in out.items()}


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f} s"
    if us >= 1e3:
        return f"{us / 1e3:.3f} ms"
    return f"{us:.0f} us"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize/validate a Chrome trace-event JSON file")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--top", type=int, default=15,
                    help="rows in the top-spans table (default 15)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only; exit 1 on any problem")
    args = ap.parse_args(argv)

    with open(args.trace, encoding="utf-8") as f:
        obj = json.load(f)

    if args.validate:
        problems = _load_validator()(obj)
        if problems:
            for p in problems:
                print(f"INVALID: {p}")
            return 1
        n = len(obj.get("traceEvents", []))
        print(f"OK: {args.trace} is valid Chrome trace-event JSON "
              f"({n} events)")
        return 0

    events = obj.get("traceEvents", [])
    if not isinstance(events, list):
        print("not a trace-event file (no traceEvents list)")
        return 1

    stats = span_stats(events)
    n_inst = sum(1 for e in events if isinstance(e, dict)
                 and e.get("ph") == "i")
    print(f"{args.trace}: {len(events)} events "
          f"({sum(s['count'] for s in stats.values())} spans, "
          f"{n_inst} instants)\n")

    print(f"top spans by self time "
          f"(span minus nested children)\n{'-' * 64}")
    print(f"{'span':<28} {'count':>6} {'self':>12} {'total':>12}")
    rows = sorted(stats.items(), key=lambda kv: -kv[1]["self_us"])
    for name, s in rows[:args.top]:
        print(f"{name:<28} {s['count']:>6} {_fmt_us(s['self_us']):>12} "
              f"{_fmt_us(s['total_us']):>12}")

    per_qos = qos_latency(events)
    if per_qos:
        print(f"\nper-QoS-class span latency\n{'-' * 64}")
        print(f"{'qos':<12} {'span':<20} {'count':>6} {'mean':>12} "
              f"{'max':>12}")
        for qos in sorted(per_qos):
            for name in sorted(per_qos[qos]):
                ds = per_qos[qos][name]
                print(f"{qos:<12} {name:<20} {len(ds):>6} "
                      f"{_fmt_us(sum(ds) / len(ds)):>12} "
                      f"{_fmt_us(max(ds)):>12}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
