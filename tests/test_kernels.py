"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the assignment: sweep shapes/dtypes and assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.kernels import ops, ref

SHAPES = [
    (128, 256, 128, 128),     # M, K, N, G — minimal aligned
    (256, 512, 256, 128),
    (64, 1024, 384, 256),     # non-square, bigger groups
    (512, 256, 128, 64),      # small group
    (1, 896, 4864, 128),      # decode row  x  qwen2 MLP
    (7, 512, 256, 128),       # ragged M (padding path)
    (33, 640, 256, 128),      # K not multiple of default block_k
]

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n,g", SHAPES)
def test_qmm_int8_matches_ref(m, k, n, g):
    dtype = jnp.float32
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    codes, scales = ref.group_quantize_ref(w, g)
    out = ops.quantized_matmul(x, codes, scales)
    want = ref.qmm_ref(x, codes, scales)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_qmm_dtype_sweep(dtype):
    m, k, n, g = 128, 512, 256, 128
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    codes, scales = ref.group_quantize_ref(w, g)
    out = ops.quantized_matmul(x, codes, scales)
    assert out.dtype == dtype
    want = ref.qmm_ref(x, codes, scales)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,k,n,g", SHAPES)
def test_qmm_int4_matches_ref(m, k, n, g):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    codes, scales = ref.group_quantize_ref(w, g, bits=4)
    packed = ref.pack_int4_ref(codes)
    out = ops.quantized_matmul_int4(x, packed, scales)
    want = ref.qmm_int4_ref(x, packed, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,n,g,bits", [
    (256, 128, 128, 8), (512, 256, 64, 8), (1024, 384, 256, 8),
    (256, 128, 128, 4), (512, 512, 128, 4),
])
def test_group_quantize_matches_ref(k, n, g, bits):
    w = jax.random.normal(jax.random.PRNGKey(k + n), (k, n))
    codes, scales = ops.group_quantize(w, group_size=g, bits=bits)
    codes_r, scales_r = ref.group_quantize_ref(w, g, bits=bits)
    assert bool(jnp.all(codes == codes_r))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-6)


def test_group_quantize_fallback_k_smaller_than_group():
    """k < group_size (and n 128-misaligned doesn't matter): one group
    spanning the whole contraction axis."""
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 128))
    codes, scales = ops.group_quantize(w, group_size=128)
    codes_r, scales_r = ref.group_quantize_ref(w, 96)
    assert scales.shape == (1, 128)
    assert bool(jnp.all(codes == codes_r))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-6)


def test_group_quantize_fallback_k_not_tileable():
    """k >= group_size but k % group_size != 0: degenerates to per-element
    groups (group_size 1) — every code then sits exactly on a level."""
    w = jax.random.normal(jax.random.PRNGKey(1), (192, 128))
    codes, scales = ops.group_quantize(w, group_size=128)
    codes_r, scales_r = ref.group_quantize_ref(w, 1)
    assert scales.shape == (192, 128)
    assert bool(jnp.all(codes == codes_r))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-6)
    # per-element quantization is exact: dequant reproduces w (where w!=0)
    np.testing.assert_allclose(
        np.asarray(codes, np.float32) * np.asarray(scales), np.asarray(w),
        rtol=1e-5, atol=1e-6)


def test_group_quantize_fallback_n_misaligned():
    """k tiles but n % 128 != 0: the reference quantizer runs with the
    requested group size (the Pallas fast path needs 128-aligned N)."""
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 100))
    codes, scales = ops.group_quantize(w, group_size=128, bits=4)
    codes_r, scales_r = ref.group_quantize_ref(w, 128, bits=4)
    assert scales.shape == (2, 100)
    assert bool(jnp.all(codes == codes_r))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-6)


def test_quantized_matmul_row_bucket_padding_invisible():
    """ops.py pads M to the geometric row ladder outside the jitted core;
    any two row counts in one bucket share a trace and every real row's
    bits are unchanged by the pad."""
    from repro.kernels.bucketing import row_bucket
    k, n, g = 256, 128, 128
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n))
    codes, scales = ref.group_quantize_ref(w, g)
    x = jax.random.normal(jax.random.PRNGKey(4), (300, k))
    assert row_bucket(300) == 512
    out = ops.quantized_matmul(x, codes, scales)
    assert out.shape == (300, n)
    for m in (1, 130, 300):
        sub = ops.quantized_matmul(x[:m], codes, scales)
        np.testing.assert_array_equal(np.asarray(sub),
                                      np.asarray(out[:m]))


def test_pack_unpack_int4_roundtrip():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-7, 8, (256, 128)), jnp.int8)
    packed = ref.pack_int4_ref(codes)
    assert packed.shape == (128, 128)
    out = ref.unpack_int4_ref(packed)
    assert bool(jnp.all(out == codes))


def test_leading_dims_flattened():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 256))
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128))
    codes, scales = ops.group_quantize(w, group_size=128)
    out = ops.quantized_matmul(x, codes, scales)
    assert out.shape == (4, 8, 128)
    want = ref.qmm_ref(x.reshape(-1, 256), codes, scales).reshape(4, 8, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_leading_dims_flattened_int4():
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 2, 8, 256))
    w = jax.random.normal(jax.random.PRNGKey(6), (256, 128))
    codes, scales = ref.group_quantize_ref(w, 128, bits=4)
    packed = ref.pack_int4_ref(codes)
    out = ops.quantized_matmul_int4(x, packed, scales)
    assert out.shape == (3, 2, 8, 128)
    want = ref.qmm_int4_ref(x.reshape(-1, 256), packed,
                            scales).reshape(3, 2, 8, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bits", [8, 4])
def test_batch_rows_independent(bits):
    """Serving invariant (DESIGN.md §7): each batch row's result equals the
    row served alone — bitwise, so batching requests never changes
    per-request logits."""
    b, s, k, n = 5, 16, 256, 128
    x = jax.random.normal(jax.random.PRNGKey(7), (b, s, k))
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n))
    ql = ops.quantize_linear(w, bits=bits)
    batched = np.asarray(ql.apply(x))
    for i in range(b):
        single = np.asarray(ql.apply(x[i:i + 1]))
        np.testing.assert_array_equal(batched[i], single[0])


def test_quantize_linear_end_to_end_error_scales_with_bits():
    """int4 residency must cost more accuracy than int8 — and both must be
    within the analytic per-group error bound."""
    k, n = 512, 256
    x = jax.random.normal(jax.random.PRNGKey(3), (32, k))
    w = jax.random.normal(jax.random.PRNGKey(4), (k, n))
    exact = x @ w
    err8 = float(jnp.mean(jnp.abs(
        ops.quantize_linear(w, bits=8).apply(x) - exact)))
    err4 = float(jnp.mean(jnp.abs(
        ops.quantize_linear(w, bits=4).apply(x) - exact)))
    assert err8 < err4 < 10 * err8 * 16 + 1.0
    assert err8 < 0.05 * float(jnp.mean(jnp.abs(exact)))


@settings(max_examples=10, deadline=None)
@given(mm=st.sampled_from([1, 3, 64, 128]),
       kk=st.sampled_from([256, 512]),
       nn=st.sampled_from([128, 384]),
       seed=st.integers(0, 1000))
def test_prop_qmm_random_shapes(mm, kk, nn, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (mm, kk))
    w = jax.random.normal(kw, (kk, nn))
    codes, scales = ref.group_quantize_ref(w, 128)
    np.testing.assert_allclose(
        np.asarray(ops.quantized_matmul(x, codes, scales)),
        np.asarray(ref.qmm_ref(x, codes, scales)), rtol=1e-4, atol=1e-4)
