"""Rate-distortion bounds (paper §IV, Props 4.1/4.2, Fig. 4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core.rate_distortion import (blahut_arimoto_distortion_rate,
                                        distortion_lower_bound,
                                        distortion_upper_bound,
                                        exponential_entropy, exponential_mle,
                                        rate_lower_bound, rate_upper_bound)


def test_entropy_closed_form():
    # h(Exp(lam)) = log2(e/lam)
    assert float(exponential_entropy(1.0)) == pytest.approx(
        np.log2(np.e), rel=1e-6)
    assert float(exponential_entropy(2.0)) == pytest.approx(
        np.log2(np.e / 2), rel=1e-6)


def test_mle_recovers_lambda():
    rng = np.random.default_rng(0)
    for lam in (0.5, 3.0, 40.0):
        sample = rng.exponential(1.0 / lam, size=200_000)
        lam_hat = float(exponential_mle(jnp.asarray(sample)))
        assert lam_hat == pytest.approx(lam, rel=0.02)


@settings(max_examples=40, deadline=None)
@given(lam=st.floats(0.1, 500.0), rate=st.floats(0.25, 12.0))
def test_prop_bounds_ordering(lam, rate):
    """D^L(R) <= D^U(R) for every (lam, R) — Props 4.1 vs 4.2."""
    dl = float(distortion_lower_bound(rate, lam))
    du = float(distortion_upper_bound(rate, lam))
    assert 0 < dl <= du * (1 + 1e-6)


@settings(max_examples=40, deadline=None)
@given(lam=st.floats(0.1, 500.0), d=st.floats(1e-6, 0.49))
def test_prop_rate_bounds_consistent(lam, d):
    """R^L and D^L are inverses; same for the upper pair."""
    dd = d / lam  # keep lam*D < 0.5 so R^L > 0
    rl = float(rate_lower_bound(dd, lam))
    dl = float(distortion_lower_bound(rl, lam))
    assert dl == pytest.approx(dd, rel=1e-4)
    ru = float(rate_upper_bound(dd, lam))
    du = float(distortion_upper_bound(ru, lam))
    # D^U(R^U(D)) returns D by construction of the test channel (f32 slack)
    assert du == pytest.approx(dd, rel=2e-2)


def test_bounds_decay_and_converge():
    """Both bounds decrease in R and the gap shrinks (paper Fig. 4)."""
    lam = 30.0
    rates = np.linspace(1.0, 10.0, 19)
    dl = np.array([float(distortion_lower_bound(r, lam)) for r in rates])
    du = np.array([float(distortion_upper_bound(r, lam)) for r in rates])
    assert np.all(np.diff(dl) < 0) and np.all(np.diff(du) < 0)
    gap = du - dl
    assert gap[-1] < gap[0] * 0.02


def test_blahut_arimoto_between_bounds():
    """Numerical D(R) must sit in [D^L, D^U] in the rate window where the
    discretized source is a faithful stand-in (rates well below
    log2(n_source) ~ 7.6 bits, exactly how paper Fig. 4 sweeps it)."""
    lam = 20.0
    res = blahut_arimoto_distortion_rate(lam, n_source=192, n_repro=192,
                                         n_iters=150)
    mask = (res.rates > 0.5) & (res.rates < 3.5)
    assert mask.sum() >= 5
    for r, d in zip(res.rates[mask], res.distortions[mask]):
        dl = float(distortion_lower_bound(r, lam))
        du = float(distortion_upper_bound(r, lam))
        assert d >= dl * 0.90, (r, d, dl)   # 10% discretization slack
        assert d <= du * 1.10, (r, d, du)


def test_blahut_arimoto_monotone():
    res = blahut_arimoto_distortion_rate(20.0, n_source=128, n_repro=128,
                                         n_iters=100)
    mask = (res.rates > 0.25) & (res.rates < 3.5)
    order = np.argsort(res.rates[mask])
    d_sorted = res.distortions[mask][order]
    # distortion decreases (weakly) as rate grows
    assert np.all(np.diff(d_sorted) <= 1e-4)


def test_lambda_scaling_insight():
    """Remark 4.1: larger lam (sharper peak at 0) -> less distortion at the
    same rate — quantization-sensitivity is captured by lam."""
    for r in (2.0, 4.0, 6.0):
        d_small = float(distortion_upper_bound(r, 5.0))
        d_large = float(distortion_upper_bound(r, 50.0))
        assert d_large < d_small
