"""Flash-attention Pallas kernel + fused-accounting path tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import (_ref_attention, flash_attention,
                                 flash_attention_fwd)
from repro.models import layers as L
from repro.parallel.sharding import flash_attention_mode


CASES = [
    # B, H, KV, S, dh, causal, window, bq, bk
    (2, 4, 4, 256, 64, True, 0, 128, 128),
    (1, 8, 2, 512, 64, True, 0, 256, 256),      # GQA 4:1
    (2, 4, 1, 128, 32, True, 0, 64, 64),        # MQA
    (1, 4, 4, 256, 64, False, 0, 128, 128),     # bidirectional
    (1, 4, 4, 256, 64, True, 64, 128, 128),     # sliding window
    (1, 2, 2, 384, 128, True, 0, 128, 128),     # dh=128, 3 blocks
]


@pytest.mark.parametrize("b,h,kv,s,dh,causal,win,bq,bk", CASES)
def test_flash_fwd_matches_oracle(b, h, kv, s, dh, causal, win, bq, bk):
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(kq, (b, h, s, dh), jnp.float32)
    k = jax.random.normal(kk, (b, kv, s, dh), jnp.float32)
    v = jax.random.normal(kv_, (b, kv, s, dh), jnp.float32)
    out = flash_attention_fwd(q, k, v, causal=causal, window=win,
                              block_q=bq, block_k=bk, interpret=True)
    ref = _ref_attention(q, k, v, causal, win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (1, 4, 256, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(kk, (1, 4, 256, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(kv_, (1, 4, 256, 64)).astype(jnp.bfloat16)
    out = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    ref = _ref_attention(q, k, v, True, 0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_grad_matches_oracle():
    """custom_vjp backward (blockwise recompute) vs autodiff of the ref."""
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (1, 4, 128, 32))
    k = jax.random.normal(kk, (1, 2, 128, 32))
    v = jax.random.normal(kv_, (1, 2, 128, 32))

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 0, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(_ref_attention(q, k, v, True, 0) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# numpy host oracles used by the accounting callbacks
# ---------------------------------------------------------------------------

def test_np_attention_fwd_matches_blockwise():
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (2, 48, 6, 16))
    k = jax.random.normal(kk, (2, 48, 2, 16))
    v = jax.random.normal(kv_, (2, 48, 2, 16))
    out, _ = L._np_attention_fwd(np.asarray(q), np.asarray(k),
                                 np.asarray(v), True, 0)
    ref = L.blockwise_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_np_attention_bwd_matches_autodiff():
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (1, 24, 4, 8))
    k = jax.random.normal(kk, (1, 24, 2, 8))
    v = jax.random.normal(kv_, (1, 24, 2, 8))
    g = jax.random.normal(jax.random.PRNGKey(4), (1, 24, 4, 8))
    dq, dk, dv = L._attention_bwd_host(True, 0, np.asarray(q),
                                       np.asarray(k), np.asarray(v),
                                       np.asarray(g))
    _, vjp = jax.vjp(lambda a, b, c: L.blockwise_attention(
        a, b, c, causal=True), q, k, v)
    rq, rk, rv = vjp(g)
    np.testing.assert_allclose(dq, np.asarray(rq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dk, np.asarray(rk), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dv, np.asarray(rv), rtol=2e-4, atol=2e-4)


def test_decode_partials_host_combine():
    """Two-shard flash-decoding partials merged with the LSE rule equal the
    monolithic decode attention."""
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(5), 3)
    B, H, KV, T, dh = 2, 4, 2, 32, 8
    q = jax.random.normal(kq, (B, 1, H, dh))
    k = jax.random.normal(kk, (B, T, KV, dh))
    v = jax.random.normal(kv_, (B, T, KV, dh))
    ln = np.asarray([32, 11], np.int32)
    ref = L.decode_attention(q, k, v, jnp.asarray(ln))

    half = T // 2
    parts = []
    for i in (0, 1):
        acc, m, l = L._decode_partials_host(
            0, np.asarray(q), np.asarray(k[:, i * half:(i + 1) * half]),
            np.asarray(v[:, i * half:(i + 1) * half]), ln,
            np.full((B,), i * half, np.int32))
        parts.append((acc, m, l))
    m_glob = np.maximum(parts[0][1], parts[1][1])
    acc = l = 0
    for a, m, lp in parts:
        c = np.where(np.isfinite(m), np.exp(m - m_glob), 0.0)
        acc = acc + a * c[:, None, :, :, :]
        l = l + lp * c
    out = (acc / np.maximum(l[:, None, :, :, :], 1e-30)).reshape(B, 1, H, dh)
    np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-4, atol=2e-4)
