"""Joint (b̂, f, f̃) co-design (paper §V, Algorithm 1) + baselines."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core.baselines import (solve_feasible_random,
                                  solve_fixed_frequency, solve_ppo)
from repro.core.codesign import (distortion_gap, feasible_bitwidth,
                                 min_energy_under_deadline, solve_oracle,
                                 solve_sca)
from repro.core.cost_model import (SystemParams, total_delay, total_energy)

# A self-consistent operating point for the paper's cost model: with the
# paper's (f_max, c, psi, eta) constants, 64 GFLOP on-agent / 192 GFLOP
# on-server puts t_a(b=16, f_max) at 1.0 s and makes the (T0, E0) region
# genuinely active (the paper's raw 533.66 GFLOP figure with c=32 FLOP/cycle
# would need >8 s even at f_max — its testbed numbers imply much higher
# effective FLOPs/cycle; see DESIGN.md §7).
P0 = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
LAM = 30.0


def test_gap_monotone_decreasing_in_bits():
    """The (P1) objective D^U - D^L decreases in b̂ — why the oracle scans
    from the top."""
    gaps = [distortion_gap(b, LAM) for b in range(2, 17)]
    assert all(g1 >= g2 for g1, g2 in zip(gaps, gaps[1:]))


def test_min_energy_deadline_unmeetable():
    e, f, fs = min_energy_under_deadline(1.0, P0, t0=1e-9)
    assert math.isinf(e)


def test_min_energy_monotone_in_deadline():
    prev = math.inf
    for t0 in (1.2, 1.5, 2.0, 3.0, 5.0):
        e, f, fs = min_energy_under_deadline(0.5, P0, t0)
        assert e <= prev * (1 + 1e-9)
        assert 0 <= f <= P0.f_max and 0 <= fs <= P0.f_server_max
        prev = e


def test_energy_optimal_frequencies_meet_deadline():
    for w in (0.1, 0.5, 1.0):
        t0 = 1.4
        e, f, fs = min_energy_under_deadline(w, P0, t0)
        assert math.isfinite(e)
        t = float(total_delay(w * P0.b_full, f, fs, P0))
        assert t <= t0 * (1 + 1e-6)


def test_oracle_picks_largest_feasible_bitwidth():
    sol = solve_oracle(LAM, P0, t0=1.2, e0=2.0)
    assert sol is not None
    ok_here, _, _, _ = feasible_bitwidth(sol.b_hat, P0, 3.5, 2.0)
    assert ok_here
    if sol.b_hat < 16:
        ok_up, _, _, _ = feasible_bitwidth(sol.b_hat + 1, P0, 1.2, 2.0)
        assert not ok_up


def test_sca_matches_oracle_on_paper_setup():
    """Algorithm 1 should land on (or next to) the oracle optimum across a
    (T0, E0) sweep like Figs. 5-8."""
    for t0 in (1.1, 1.2, 1.35, 1.5, 2.0):
        for e0 in (0.8, 1.2, 2.0, 3.0):
            o = solve_oracle(LAM, P0, t0, e0)
            s = solve_sca(LAM, P0, t0, e0)
            assert (o is None) == (s is None)
            if o is not None:
                assert abs(s.b_hat - o.b_hat) <= 1, (t0, e0, s.b_hat,
                                                     o.b_hat)
                assert s.objective <= distortion_gap(max(o.b_hat - 1, 1),
                                                     LAM) * (1 + 1e-9)


def test_sca_solution_feasible():
    sol = solve_sca(LAM, P0, t0=1.3, e0=2.0)
    assert sol is not None and sol.feasible
    assert sol.delay <= 1.3 * (1 + 1e-6)
    assert sol.energy <= 2.0 * (1 + 1e-6)
    assert 1 <= sol.b_hat <= 16
    assert sol.iterations >= 1


def test_infeasible_detected():
    assert solve_sca(LAM, P0, t0=1e-6, e0=1e-9) is None
    assert solve_oracle(LAM, P0, t0=1e-6, e0=1e-9) is None


def test_fixed_frequency_never_beats_oracle():
    for t0, e0 in ((1.2, 1.5), (1.4, 2.0), (1.3, 6.0)):
        o = solve_oracle(LAM, P0, t0, e0)
        f = solve_fixed_frequency(LAM, P0, t0, e0)
        if o is None:
            continue
        if f is None:
            continue
        assert f.b_hat <= o.b_hat
        assert f.objective >= o.objective * (1 - 1e-9)


def test_feasible_random_all_feasible():
    sols = solve_feasible_random(LAM, P0, t0=1.4, e0=2.0, trials=100)
    assert sols
    for s in sols:
        assert s.delay <= 1.4 * (1 + 1e-6)
        assert s.energy <= 2.0 * (1 + 1e-6)


def test_ppo_returns_feasible_and_suboptimal_or_equal():
    o = solve_oracle(LAM, P0, t0=1.4, e0=2.0)
    p = solve_ppo(LAM, P0, t0=1.4, e0=2.0, iters=150, seed=1)
    assert p is not None
    assert p.delay <= 1.4 * (1 + 1e-6) and p.energy <= 2.0 * (1 + 1e-6)
    assert p.objective >= o.objective * (1 - 1e-9)


@settings(max_examples=25, deadline=None)
@given(lam=st.floats(1.0, 200.0),
       t0=st.floats(1.0, 3.0),
       e0=st.floats(0.3, 4.0))
def test_prop_sca_never_worse_than_oracle_minus_rounding(lam, t0, e0):
    o = solve_oracle(lam, P0, t0, e0)
    s = solve_sca(lam, P0, t0, e0)
    assert (o is None) == (s is None)
    if o is not None:
        # rounding can cost at most one bit
        assert s.b_hat >= o.b_hat - 1


@settings(max_examples=25, deadline=None)
@given(lam=st.floats(1.0, 200.0), t0=st.floats(1.05, 3.0),
       e0=st.floats(0.5, 4.0))
def test_prop_relaxing_constraints_never_hurts(lam, t0, e0):
    a = solve_oracle(lam, P0, t0, e0)
    b = solve_oracle(lam, P0, t0 * 1.5, e0 * 1.5)
    if a is not None:
        assert b is not None and b.b_hat >= a.b_hat
