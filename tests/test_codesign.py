"""Joint (b̂, f, f̃) co-design (paper §V, Algorithm 1) + baselines."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips property tests when absent

from repro.core.baselines import (solve_feasible_random,
                                  solve_fixed_frequency, solve_ppo)
from repro.core.codesign import (distortion_gap, feasible_bitwidth,
                                 min_energy_under_deadline, solve_oracle,
                                 solve_sca)
from repro.core.cost_model import (SystemParams, total_delay, total_energy)

# A self-consistent operating point for the paper's cost model: with the
# paper's (f_max, c, psi, eta) constants, 64 GFLOP on-agent / 192 GFLOP
# on-server puts t_a(b=16, f_max) at 1.0 s and makes the (T0, E0) region
# genuinely active (the paper's raw 533.66 GFLOP figure with c=32 FLOP/cycle
# would need >8 s even at f_max — its testbed numbers imply much higher
# effective FLOPs/cycle; see DESIGN.md §7).
P0 = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
LAM = 30.0


def test_gap_monotone_decreasing_in_bits():
    """The (P1) objective D^U - D^L decreases in b̂ — why the oracle scans
    from the top."""
    gaps = [distortion_gap(b, LAM) for b in range(2, 17)]
    assert all(g1 >= g2 for g1, g2 in zip(gaps, gaps[1:]))


def test_min_energy_deadline_unmeetable():
    e, f, fs = min_energy_under_deadline(1.0, P0, t0=1e-9)
    assert math.isinf(e)


def test_min_energy_monotone_in_deadline():
    prev = math.inf
    for t0 in (1.2, 1.5, 2.0, 3.0, 5.0):
        e, f, fs = min_energy_under_deadline(0.5, P0, t0)
        assert e <= prev * (1 + 1e-9)
        assert 0 <= f <= P0.f_max and 0 <= fs <= P0.f_server_max
        prev = e


def test_energy_optimal_frequencies_meet_deadline():
    for w in (0.1, 0.5, 1.0):
        t0 = 1.4
        e, f, fs = min_energy_under_deadline(w, P0, t0)
        assert math.isfinite(e)
        t = float(total_delay(w * P0.b_full, f, fs, P0))
        assert t <= t0 * (1 + 1e-6)


def test_oracle_picks_largest_feasible_bitwidth():
    sol = solve_oracle(LAM, P0, t0=1.2, e0=2.0)
    assert sol is not None
    ok_here, _, _, _ = feasible_bitwidth(sol.b_hat, P0, 3.5, 2.0)
    assert ok_here
    if sol.b_hat < 16:
        ok_up, _, _, _ = feasible_bitwidth(sol.b_hat + 1, P0, 1.2, 2.0)
        assert not ok_up


def test_sca_matches_oracle_on_paper_setup():
    """Algorithm 1 should land on (or next to) the oracle optimum across a
    (T0, E0) sweep like Figs. 5-8."""
    for t0 in (1.1, 1.2, 1.35, 1.5, 2.0):
        for e0 in (0.8, 1.2, 2.0, 3.0):
            o = solve_oracle(LAM, P0, t0, e0)
            s = solve_sca(LAM, P0, t0, e0)
            assert (o is None) == (s is None)
            if o is not None:
                assert abs(s.b_hat - o.b_hat) <= 1, (t0, e0, s.b_hat,
                                                     o.b_hat)
                assert s.objective <= distortion_gap(max(o.b_hat - 1, 1),
                                                     LAM) * (1 + 1e-9)


def test_sca_solution_feasible():
    sol = solve_sca(LAM, P0, t0=1.3, e0=2.0)
    assert sol is not None and sol.feasible
    assert sol.delay <= 1.3 * (1 + 1e-6)
    assert sol.energy <= 2.0 * (1 + 1e-6)
    assert 1 <= sol.b_hat <= 16
    assert sol.iterations >= 1


def test_infeasible_detected():
    assert solve_sca(LAM, P0, t0=1e-6, e0=1e-9) is None
    assert solve_oracle(LAM, P0, t0=1e-6, e0=1e-9) is None


def test_fixed_frequency_never_beats_oracle():
    for t0, e0 in ((1.2, 1.5), (1.4, 2.0), (1.3, 6.0)):
        o = solve_oracle(LAM, P0, t0, e0)
        f = solve_fixed_frequency(LAM, P0, t0, e0)
        if o is None:
            continue
        if f is None:
            continue
        assert f.b_hat <= o.b_hat
        assert f.objective >= o.objective * (1 - 1e-9)


def test_feasible_random_all_feasible():
    sols = solve_feasible_random(LAM, P0, t0=1.4, e0=2.0, trials=100)
    assert sols
    for s in sols:
        assert s.delay <= 1.4 * (1 + 1e-6)
        assert s.energy <= 2.0 * (1 + 1e-6)


def test_ppo_returns_feasible_and_suboptimal_or_equal():
    o = solve_oracle(LAM, P0, t0=1.4, e0=2.0)
    p = solve_ppo(LAM, P0, t0=1.4, e0=2.0, iters=150, seed=1)
    assert p is not None
    assert p.delay <= 1.4 * (1 + 1e-6) and p.energy <= 2.0 * (1 + 1e-6)
    assert p.objective >= o.objective * (1 - 1e-9)


@settings(max_examples=25, deadline=None)
@given(lam=st.floats(1.0, 200.0),
       t0=st.floats(1.0, 3.0),
       e0=st.floats(0.3, 4.0))
def test_prop_sca_never_worse_than_oracle_minus_rounding(lam, t0, e0):
    o = solve_oracle(lam, P0, t0, e0)
    s = solve_sca(lam, P0, t0, e0)
    assert (o is None) == (s is None)
    if o is not None:
        # rounding can cost at most one bit
        assert s.b_hat >= o.b_hat - 1


@settings(max_examples=25, deadline=None)
@given(lam=st.floats(1.0, 200.0), t0=st.floats(1.05, 3.0),
       e0=st.floats(0.5, 4.0))
def test_prop_relaxing_constraints_never_hurts(lam, t0, e0):
    a = solve_oracle(lam, P0, t0, e0)
    b = solve_oracle(lam, P0, t0 * 1.5, e0 * 1.5)
    if a is not None:
        assert b is not None and b.b_hat >= a.b_hat


# ---------------------------------------------------------------------------
# uplink transport terms (link-aware co-design, DESIGN.md §9)
# ---------------------------------------------------------------------------

P_LINK = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11,
                      emb_bytes_full=4.0e5, link_bps=1.0e6, tx_power_w=0.5)


def test_transport_energy_symmetric_with_delay_and_zero_by_default():
    from repro.core.cost_model import transport_delay, transport_energy
    assert float(transport_energy(8, P0)) == 0.0      # faithful default
    t_x = float(transport_delay(8, P_LINK))
    assert t_x == pytest.approx((8 / 16) * 4.0e5 / 1.0e6)
    assert float(transport_energy(8, P_LINK)) == pytest.approx(0.5 * t_x)
    # tx energy rides total_energy only when b_emb is passed, like delay
    e_plain = float(total_energy(8, 2.0e9, 1.0e10, P_LINK))
    e_link = float(total_energy(8, 2.0e9, 1.0e10, P_LINK, b_emb=8))
    assert e_link == pytest.approx(e_plain + 0.5 * t_x)


def test_net_budgets_shrink_by_transport_share():
    from repro.core.codesign import net_budgets
    assert net_budgets(P0, 1.3, 1.5, 8) == (1.3, 1.5)  # link disabled
    t0n, e0n = net_budgets(P_LINK, 1.3, 1.5, 8)
    assert t0n == pytest.approx(1.3 - 0.2)
    assert e0n == pytest.approx(1.5 - 0.1)
    assert net_budgets(P_LINK, 1.3, 1.5, None) == (1.3, 1.5)


def test_link_aware_solve_spends_fewer_bits_and_stays_feasible():
    s_free = solve_sca(LAM, P_LINK, 1.3, 1.5)            # ignores the link
    s_link = solve_sca(LAM, P_LINK, 1.3, 1.5, b_emb=8)
    assert s_link is not None and s_link.b_hat <= s_free.b_hat
    # realized totals include the transport share and respect the budgets
    assert s_link.delay <= 1.3 * (1 + 1e-9)
    assert s_link.energy <= 1.5 * (1 + 1e-9)
    assert s_link.delay == pytest.approx(float(
        total_delay(s_link.b_hat, s_link.f, s_link.f_server, P_LINK,
                    b_emb=8)))
    # oracle agrees with SCA on the link-aware optimum
    o = solve_oracle(LAM, P_LINK, 1.3, 1.5, b_emb=8)
    assert o.b_hat == s_link.b_hat


def test_transport_dominated_budget_is_infeasible():
    # uplink alone eats the whole deadline -> nothing is feasible
    slow = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11,
                        emb_bytes_full=4.0e5, link_bps=1.0e5)
    assert solve_sca(LAM, slow, 1.3, 1.5, b_emb=8) is None
    ok, f, fs, e = feasible_bitwidth(1, slow, 1.3, 1.5, b_emb=8)
    assert not ok


def test_feasible_bitwidth_unmeetable_deadline_infeasible_even_at_inf_e0():
    # regression: e_min = inf must not pass an infinite energy budget
    ok, f, fs, e = feasible_bitwidth(16, P0, t0=1e-6, e0=math.inf)
    assert not ok and math.isnan(f)


def test_mixed_precision_link_aware_budget():
    from repro.core.mixed_precision import max_mean_bits
    free = max_mean_bits(P_LINK, 1.3, 1.5)
    link = max_mean_bits(P_LINK, 1.3, 1.5, b_emb=8)
    assert link < free
