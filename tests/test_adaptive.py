"""Online adaptive serving (DESIGN.md §9): bitwise identity on constant
traces, controller hysteresis, infeasible-window degradation, and the
environment-keyed codesign cache."""

import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.env import Battery, Environment, TraceReplay
from repro.models.registry import build_model
from repro.runtime import (AdaptiveCoInferenceEngine,
                           BatchedCoInferenceEngine, CodesignCache,
                           QosClass)

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
QOS = QosClass("interactive", t0=1.30, e0=1.5)


def _model(arch="stablelm-3b", split=None):
    cfg = get_smoke(arch)
    if split is not None:
        cfg = dataclasses.replace(cfg, split_layer=split)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(1))


def _submit(eng, cfg, n=6, seed=0, qos=QOS.name, spacing_s=0.0):
    rng = np.random.default_rng(seed)
    sent = {}
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 17)))
        sent[eng.submit(toks, qos, arrival_s=i * spacing_s)] = toks
    return sent


def _throttle_env(f_lo=0.6e9, dwell_s=4.0, horizon_s=40.0):
    """f_max steps 2.0 -> f_lo GHz and stays there."""
    return Environment(seed=0, dt_s=0.5, horizon_s=horizon_s,
                       f_cap=TraceReplay(values=(2.0e9, f_lo),
                                         dwell_s=dwell_s))


# ---------------------------------------------------------------------------
# identity with the static engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("environment", [None, "constant"])
def test_bitwise_identical_to_batched_on_constant_trace(environment):
    cfg, model, params = _model()
    env = Environment(seed=0, dt_s=0.5, horizon_s=20.0) \
        if environment == "constant" else None
    a = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[QOS],
                                  max_batch=2, environment=env)
    b = BatchedCoInferenceEngine(model, params, SYSP, classes=[QOS],
                                 max_batch=2)
    _submit(a, cfg)
    _submit(b, cfg)
    ra, rb = a.drain(), b.drain()
    assert len(ra) == len(rb) == 6
    assert a.batch_history == b.batch_history
    for x, y in zip(ra, rb):
        assert x.stats == y.stats
        np.testing.assert_array_equal(np.asarray(x.logits),
                                      np.asarray(y.logits))
    rep = a.adaptive_report()
    assert rep.plan_switches == 0 and rep.degraded_batches == 0


# ---------------------------------------------------------------------------
# drift detection and hysteresis
# ---------------------------------------------------------------------------

def test_sustained_drift_triggers_replan_and_switch():
    cfg, model, params = _model()
    eng = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=_throttle_env(), hysteresis_steps=2)
    # arrivals spaced 1 s apart: several observations per env regime
    _submit(eng, cfg, n=10, spacing_s=1.0)
    eng.drain()
    rep = eng.adaptive_report()
    assert rep.replans >= 1 and rep.plan_switches >= 1
    assert rep.env_keys_seen == 2
    b0 = eng.batch_history[0].b_hat
    assert eng.batch_history[-1].b_hat < b0       # shed bits when capped
    ev = eng.replan_events[0]
    assert ev.reason == "env-drift" and ev.b_after < ev.b_before


def test_hysteresis_no_flapping_on_boundary_oscillation():
    """A state oscillating across the quantization boundary every single
    observation never sustains a drift streak: zero replans."""
    cfg, model, params = _model()
    osc = Environment(seed=0, dt_s=1.0, horizon_s=40.0,
                      f_cap=TraceReplay(values=(2.0e9, 1.2e9) * 10,
                                        dwell_s=1.0))
    eng = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=osc, hysteresis_steps=2)
    # one batch per env step: every observation sees the other state
    _submit(eng, cfg, n=10, spacing_s=1.0)
    eng.drain()
    rep = eng.adaptive_report()
    assert rep.env_keys_seen == 2
    assert rep.replans == 0                       # no flapping
    # the oracle policy *does* chase the oscillation — the hysteresis is
    # what suppresses it, not the scenario
    osc2 = Environment(seed=0, dt_s=1.0, horizon_s=40.0,
                       f_cap=TraceReplay(values=(2.0e9, 1.2e9) * 10,
                                         dwell_s=1.0))
    oracle = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=osc2, policy="oracle")
    _submit(oracle, cfg, n=10, spacing_s=1.0)
    oracle.drain()
    assert oracle.adaptive_report().replans >= 5


def test_replans_bounded_by_hysteresis():
    cfg, model, params = _model()
    env = Environment(seed=0, dt_s=0.5, horizon_s=40.0,
                      f_cap=TraceReplay(values=(2.0e9, 1.2e9, 2.0e9,
                                                0.6e9, 2.0e9),
                                        dwell_s=4.0))
    eng = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=env, hysteresis_steps=3)
    _submit(eng, cfg, n=12, spacing_s=1.0)
    eng.drain()
    rep = eng.adaptive_report()
    assert rep.replans <= len(eng.batch_history) // 3


def test_static_policy_never_replans_but_is_billed_by_the_env():
    cfg, model, params = _model()
    eng = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=_throttle_env(), policy="static")
    _submit(eng, cfg, n=8, spacing_s=1.0)
    eng.drain()
    assert eng.adaptive_report().replans == 0
    assert eng.batch_history[0].f == pytest.approx(
        eng.solution_for(QOS.name).f)
    assert eng.batch_history[-1].f <= 0.6e9 * (1 + 1e-9)  # clipped


# ---------------------------------------------------------------------------
# infeasible windows degrade instead of raising
# ---------------------------------------------------------------------------

def test_infeasible_window_degrades_to_lowest_distortion_feasible_plan():
    cfg, model, params = _model()
    # a cap so low the class is infeasible: t_agent(b=1) alone > T0
    tight = QosClass("tight", t0=0.12, e0=1.5)
    env = Environment(seed=0, dt_s=0.5, horizon_s=20.0,
                      f_cap=TraceReplay(values=(0.05e9,), dwell_s=1.0))
    # the static engine refuses outright under the same state...
    with pytest.raises(ValueError):
        BatchedCoInferenceEngine(
            model, params,
            dataclasses.replace(SYSP, f_max=0.05e9),
            classes=[tight])
    # ...the adaptive engine constructs, serves, and reports the damage
    eng = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[tight],
                                    max_batch=2, environment=env)
    sol = eng.solution_for("tight")
    assert not sol.feasible
    assert sol.b_hat == 1                         # fastest plan there is
    assert math.isfinite(sol.f) and sol.f > 0
    _submit(eng, cfg, n=4, qos="tight")
    responses = eng.drain()
    assert len(responses) == 4
    rep = eng.adaptive_report()
    assert rep.degraded_batches == len(eng.batch_history)


def test_degraded_plan_meets_deadline_when_only_energy_is_impossible():
    cfg, model, params = _model()
    # deadline loose, energy budget absurd: degrade keeps the deadline
    # and maximizes bits under it (lowest distortion feasible)
    weird = QosClass("weird", t0=2.0, e0=1e-12)
    env = Environment(seed=0, dt_s=0.5, horizon_s=10.0)
    eng = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[weird],
                                    max_batch=1, environment=env)
    sol = eng.solution_for("weird")
    assert not sol.feasible
    assert sol.b_hat == 16                        # deadline admits full width
    assert sol.delay <= 2.0 * (1 + 1e-9)


def test_infeasible_window_mixed_precision_mode():
    cfg, model, params = _model(split=2)
    tight = QosClass("tight", t0=0.12, e0=1.5)
    env = Environment(seed=0, dt_s=0.5, horizon_s=20.0,
                      f_cap=TraceReplay(values=(0.05e9,), dwell_s=1.0))
    eng = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[tight],
                                    max_batch=2, environment=env,
                                    mixed_precision=True)
    sol = eng.solution_for("tight")
    assert not sol.feasible and sol.bits == (1, 1)
    _submit(eng, cfg, n=2, qos="tight")
    assert len(eng.drain()) == 2


# ---------------------------------------------------------------------------
# adaptive beats static on a throttling trace
# ---------------------------------------------------------------------------

def _smoke_scale_setup():
    """Per-request workload scale so realized batch delays are
    commensurate with the QoS deadline (as in benchmarks/adaptive_serve)."""
    cfg, model, params = _model("qwen2-0.5b")
    from repro.runtime import CoInferenceEngine
    probe = CoInferenceEngine(model, params, SYSP)
    n_a, n_s = probe.flop_split(16)
    sysp = SystemParams(n_flop_agent=n_a, n_flop_server=n_s)
    t_ref = n_a / (sysp.c_agent * sysp.f_max) \
        + n_s / (sysp.c_server * sysp.f_server_max)
    qos = QosClass("rt", t0=0.78 * t_ref, e0=2.0e-3)
    return cfg, model, params, sysp, qos


def test_adaptive_strictly_fewer_violations_than_static():
    cfg, model, params, sysp, qos = _smoke_scale_setup()
    horizon = 12.0e-3
    reports = {}
    for policy in ("static", "adaptive"):
        env = Environment(seed=0, dt_s=0.5e-3, horizon_s=horizon,
                          f_cap=TraceReplay(values=(2.0e9, 0.6e9),
                                            dwell_s=horizon / 2))
        eng = AdaptiveCoInferenceEngine(
            model, params, sysp, classes=[qos], max_batch=1,
            environment=env, policy=policy, hysteresis_steps=2)
        rng = np.random.default_rng(2)
        for i in range(12):
            toks = rng.integers(0, cfg.vocab_size, size=16)
            eng.submit(toks, "rt", arrival_s=i * horizon / 12)
        eng.drain()
        reports[policy] = eng.adaptive_report()
    assert reports["static"].deadline_violations \
        > reports["adaptive"].deadline_violations
    assert reports["adaptive"].replans >= 1


# ---------------------------------------------------------------------------
# environment-keyed codesign cache
# ---------------------------------------------------------------------------

def test_codesign_cache_env_key_separates_and_memoizes():
    cache = CodesignCache()
    a = cache.solve(30.0, SYSP, QOS, b_max=16, env_key=("good",))
    b = cache.solve(30.0, SYSP, QOS, b_max=16, env_key=("bad",))
    assert cache.misses == 2 and cache.hits == 0   # distinct entries
    assert a == b                                  # same inputs, same solve
    cache.solve(30.0, SYSP, QOS, b_max=16, env_key=("good",))
    assert cache.hits == 1                         # revisit is a hit


def test_revisited_env_state_hits_cache_through_engine():
    cfg, model, params = _model()
    cache = CodesignCache()
    # 2.0 -> 0.6 -> 2.0: the recovery replan must reuse the first solve
    env = Environment(seed=0, dt_s=0.5, horizon_s=40.0,
                      f_cap=TraceReplay(values=(2.0e9, 0.6e9, 2.0e9),
                                        dwell_s=5.0))
    eng = AdaptiveCoInferenceEngine(
        model, params, SYSP, classes=[QOS], max_batch=1,
        environment=env, hysteresis_steps=2, codesign_cache=cache)
    _submit(eng, cfg, n=14, spacing_s=1.0)
    eng.drain()
    rep = eng.adaptive_report()
    assert rep.plan_switches >= 2                  # down and back up
    assert cache.hits >= 1                         # the way back was free
    assert len(cache) == 2                         # one entry per env state


def test_battery_derate_tightens_energy_budget():
    cfg, model, params = _model()
    # battery below reserve from the start: E0 is derated, so the chosen
    # b̂ can only be <= the full-battery plan's
    env_full = Environment(seed=0, dt_s=0.5, horizon_s=10.0)
    # soc 0.085 of a 0.25 reserve -> energy scale ~0.5: E0 halves but the
    # class stays feasible (the derate tightens, it does not break)
    env_low = Environment(seed=0, dt_s=0.5, horizon_s=10.0,
                          battery=Battery(capacity_j=1e9, drain_w=0.0,
                                          soc0=0.085),
                          battery_reserve_soc=0.25)
    tight = QosClass("tight-e", t0=1.3, e0=1.5)
    full = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[tight],
                                     environment=env_full)
    low = AdaptiveCoInferenceEngine(model, params, SYSP, classes=[tight],
                                    environment=env_low)
    assert env_low.state_at(0.0).energy_scale < 1.0
    s_full, s_low = full.solution_for("tight-e"), low.solution_for("tight-e")
    assert s_full.feasible and s_low.feasible
    assert s_low.b_hat < s_full.b_hat
