"""Compiled fast-path serving (DESIGN.md §10): bitwise identity with the
eager engines across paths/plans/buckets, the compile-count bound, and the
shape-bucket ladders."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.core.quantization import QuantPlan
from repro.kernels.bucketing import (next_geometric, row_bucket, seq_bucket,
                                     seq_ladder)
from repro.models.registry import build_model
from repro.runtime import (BatchedCoInferenceEngine, CoInferenceEngine,
                           CompiledForwardCache, QosClass)

SYSP = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
CLASSES = [
    QosClass("realtime", t0=1.10, e0=0.9),
    QosClass("interactive", t0=1.30, e0=1.5),
    QosClass("batch", t0=2.50, e0=4.0),
]


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_split3():
    cfg = dataclasses.replace(get_smoke("qwen2-0.5b"), split_layer=3)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ragged(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    toks = np.zeros((len(lens), max(lens)), np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(0, cfg.vocab_size, l)
    return toks


def _assert_compiled_matches_eager(model, params, target, *, path,
                                   b_emb=8, lens=(6, 13, 16, 23)):
    cfg = model.cfg
    toks = _ragged(cfg, lens)
    eager = CoInferenceEngine(model, params, SYSP, path=path, b_emb=b_emb)
    comp = CoInferenceEngine(model, params, SYSP, path=path, b_emb=b_emb,
                             compiled=True)
    eager.configure(target)
    comp.configure(target)
    le, se = eager.serve_batch({"tokens": jnp.asarray(toks)},
                               lengths=list(lens))
    lc, sc = comp.serve_batch({"tokens": jnp.asarray(toks)},
                              lengths=list(lens))
    assert lc.shape == le.shape  # sliced back from the bucket
    for i, l in enumerate(lens):
        np.testing.assert_array_equal(np.asarray(le[i, :l]),
                                      np.asarray(lc[i, :l]))
    # per-request uplink accounting is padding-independent
    assert se.emb_row_bytes == sc.emb_row_bytes


# ---------------------------------------------------------------------------
# bitwise identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b_hat", [4, 8])
def test_compiled_bitwise_uniform_kernel(qwen, b_hat):
    _, model, params = qwen
    _assert_compiled_matches_eager(model, params, b_hat, path="kernel")


@pytest.mark.parametrize("b_emb", [4, 6, 16])
def test_compiled_bitwise_across_b_emb(qwen, b_emb):
    _, model, params = qwen
    _assert_compiled_matches_eager(model, params, 8, path="kernel",
                                   b_emb=b_emb)


def test_compiled_bitwise_fake_path(qwen):
    _, model, params = qwen
    _assert_compiled_matches_eager(model, params, 6, path="fake")


@pytest.mark.parametrize("bits", [(4, 8, 12), (4, 4, 6)])
def test_compiled_bitwise_mixed_plan(qwen_split3, bits):
    """Mixed plans restack into per-container segments (int4 / int8 /
    >8-bit fake) — every segment combination must stay bitwise equal."""
    _, model, params = qwen_split3
    plan = QuantPlan.from_layer_bits(list(bits))
    _assert_compiled_matches_eager(model, params, plan, path="kernel")


def test_batched_compiled_bitwise_vs_sequential_eager(qwen):
    """The acceptance invariant: batched + bucket-padded + compiled
    serving returns, per request, the exact logits of the sequential
    eager engine — including lengths crossing bucket boundaries."""
    cfg, model, params = qwen
    seq = CoInferenceEngine(model, params, SYSP, path="kernel",
                            cache_weights=True)
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4, path="kernel",
                                   compiled=True)
    rng = np.random.default_rng(4)
    sent = {}
    for i in range(9):
        toks = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(5, 60)))
        sent[eng.submit(toks, CLASSES[i % 3].name)] = (toks,
                                                       CLASSES[i % 3].name)
    responses = eng.drain()
    assert len(responses) == 9
    for r in responses:
        toks, qos = sent[r.request_id]
        sol = eng.solution_for(qos)
        seq.configure(sol.b_hat, sol.f, sol.f_server)
        want, _ = seq.serve_batch(
            {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(want[0]))


def test_eager_bucket_padding_invisible(qwen):
    """The §10 extension of the §7 argument, eager-on-eager: right-padding
    a request to its seq bucket cannot change its logits (this used to
    break for lengths crossing an attention-vectorization boundary before
    blockwise_attention snapped its blocks to the bucket ladder)."""
    cfg, model, params = qwen
    eng = CoInferenceEngine(model, params, SYSP, path="kernel")
    eng.configure(4)
    rng = np.random.default_rng(4)
    for l in (10, 23, 40):
        toks = rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
        a, _ = eng.serve_batch({"tokens": jnp.asarray(toks)[None]})
        sp = seq_bucket(l)
        padded = np.zeros((1, sp), np.int32)
        padded[0, :l] = toks
        b, _ = eng.serve_batch({"tokens": jnp.asarray(padded)},
                               lengths=[l])
        np.testing.assert_array_equal(np.asarray(a[0]),
                                      np.asarray(b[0, :l]))


# ---------------------------------------------------------------------------
# compile-count bound + warmup
# ---------------------------------------------------------------------------

def test_compile_count_bounded_and_warm_traffic_never_recompiles(qwen):
    cfg, model, params = qwen
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                   max_batch=4, path="kernel",
                                   compiled=True)
    max_seq = 64
    warm = eng.warmup(max_seq)
    cc = eng.engine.compile_cache
    bound = len(seq_ladder(max_seq)) * len(CLASSES)
    assert warm <= bound
    miss0 = cc.misses

    # >= 8 distinct raw (batch, seq) shapes: per class, one full batch
    # around each length scale plus a ragged tail batch
    rng = np.random.default_rng(11)
    raw_shapes = set()
    for ci, c in enumerate(CLASSES):
        for group, top in ((4, 12 + ci), (4, 30 + ci), (2, 55 + ci)):
            for j in range(group):
                l = top - j
                eng.submit(rng.integers(0, cfg.vocab_size, size=l), c.name)
    while eng.pending():
        rs = eng.step()
        raw_shapes.add((len(rs), max(len(r.logits) for r in rs)))
    assert len(raw_shapes) >= 8
    assert cc.misses == miss0          # warm traffic never recompiles
    assert len(cc) <= bound            # <= buckets x active plans
    rep = eng.report()
    assert rep.compile_misses == cc.misses
    assert rep.compiled_variants == len(cc)
    assert rep.compile_hits == cc.hits > 0


def test_warmup_requires_compiled(qwen):
    _, model, params = qwen
    eng = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES)
    with pytest.raises(RuntimeError):
        eng.warmup(32)
    assert eng.report().compiled_variants == 0


def test_shared_compile_cache_across_engines(qwen):
    """Two engines sharing one CompiledForwardCache reuse executables:
    the second engine's warmup compiles nothing new."""
    _, model, params = qwen
    cache = CompiledForwardCache()
    a = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                 max_batch=4, path="kernel",
                                 compiled=True, compile_cache=cache)
    n_a = a.warmup(32)
    assert n_a == len(cache) > 0
    b = BatchedCoInferenceEngine(model, params, SYSP, classes=CLASSES,
                                 max_batch=4, path="kernel",
                                 compiled=True, compile_cache=cache)
    assert b.warmup(32) == 0


# ---------------------------------------------------------------------------
# bucket ladders
# ---------------------------------------------------------------------------

def test_bucket_ladders():
    assert next_geometric(1, 16) == 16
    assert next_geometric(16, 16) == 16
    assert next_geometric(17, 16) == 32
    assert seq_bucket(40) == 64
    assert seq_ladder(64) == (16, 32, 64)
    assert seq_ladder(65) == (16, 32, 64, 128)
    assert row_bucket(1) == 128
    assert row_bucket(128) == 128
    assert row_bucket(129) == 256
    assert row_bucket(300) == 512
    with pytest.raises(ValueError):
        next_geometric(0, 16)


def test_engine_bucket_shape(qwen):
    _, model, params = qwen
    eng = CoInferenceEngine(model, params, SYSP, compiled=True,
                            batch_quantum=4)
    assert eng.bucket_shape(1, 5) == (4, 16)
    assert eng.bucket_shape(4, 17) == (4, 32)
    assert eng.bucket_shape(5, 16) == (8, 16)
    free = CoInferenceEngine(model, params, SYSP, compiled=True)
    assert free.bucket_shape(3, 5) == (4, 16)   # pow-2 batch, no quantum
