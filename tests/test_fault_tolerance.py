"""Fault-tolerance: checkpoint/restart supervisor, stragglers, corruption,
the decode engine's mid-flight retirement paths, and the serving
supervisor's crash-recoverable decode (DESIGN.md §15)."""

import hashlib
import json
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CorruptCheckpointError,
                              available_steps, load_tree, save_tree)
from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.env import ChaosTrace, ServerPreemption
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime import (DecodeEngine, HostFailure, HostSet, QosClass,
                           ServingSupervisor, SpeculativeDecodeEngine,
                           StragglerMonitor, Supervisor, TrainConfig,
                           Trainer, greedy_decode_reference)


class _Session:
    """A restartable training session for the Supervisor tests."""

    def __init__(self, ckpt_dir: str, n_hosts: int):
        cfg = get_smoke("qwen2-0.5b")
        self.model = build_model(cfg)
        self.tr = Trainer(self.model, AdamW(learning_rate=1e-3),
                          make_host_mesh(), TrainConfig(log_every=100),
                          ckpt=CheckpointManager(ckpt_dir, save_interval=5))
        self.loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
            vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)))
        self.n_hosts = n_hosts
        self.state = None
        self.losses = []

    @property
    def step(self):
        return self.tr.step

    def run_until(self, target: int, hosts):
        params, opt, err = self.tr.init_state(jax.random.PRNGKey(0))
        params, opt, err, start = self.tr.maybe_restore(params, opt, err)
        self.loader.seek(start)
        self.tr.build_step(self.loader.peek_structure())
        state = (params, opt, err)
        while self.tr.step < target:
            hosts.check(self.tr.step)       # may raise HostFailure
            state, hist = self.tr.fit(self.loader, 1, state=state)
            self.losses.extend(h["loss"] for h in hist)
            if self.tr.ckpt.should_save(self.tr.step):
                self.tr.ckpt.save(self.tr.step,
                                  {"params": state[0], "opt": state[1],
                                   "err": state[2]},
                                  metadata={"data_step": self.tr.step})


def test_supervisor_survives_host_failures():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=4, fail_at={7: 3, 13: 2})
        sup = Supervisor(lambda n: _Session(d, n), hosts)
        report = sup.run(target_steps=20)
        assert report.final_step >= 20
        assert report.restarts == 2
        assert report.failures == [3, 2]
        assert hosts.n_alive == 2
        assert report.remesh_history == [4, 3, 2]


def test_supervisor_restart_budget():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=4, fail_at={1: 0, 2: 1, 3: 2})
        sup = Supervisor(lambda n: _Session(d, n), hosts, max_restarts=1)
        with pytest.raises(RuntimeError, match="restart budget"):
            sup.run(target_steps=20)


def test_supervisor_resumes_from_checkpoint_not_zero():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=2, fail_at={8: 1})
        sessions = []

        def make(n):
            s = _Session(d, n)
            sessions.append(s)
            return s

        Supervisor(make, hosts).run(target_steps=12)
        # second session must have started from the step-5 checkpoint
        assert len(sessions) == 2
        assert sessions[1].step == 12
        assert available_steps(d)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(factor=3.0)
    rng = np.random.default_rng(0)
    for step in range(30):
        for host in range(8):
            base = 1.0 + 0.05 * rng.standard_normal()
            mon.report(host, base * (10.0 if host == 5 else 1.0))
    assert mon.stragglers() == [5]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(factor=3.0)
    for step in range(20):
        for host in range(4):
            mon.report(host, 1.0 + 0.01 * host)
    assert mon.stragglers() == []


def _decode_engine(max_batch=2, max_new=6):
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
    eng = DecodeEngine(model, params, sysp,
                       classes=[QosClass("c", t0=3.0, e0=2.0)],
                       auto=False, max_batch=max_batch,
                       max_new_tokens=max_new)
    eng.set_operating_point("c", 8, 8)
    return model, eng


def test_decode_request_retired_mid_decode():
    """cancel() mid-flight frees the slot for the queue, and the
    survivors still decode bitwise what they would have alone — a dead
    request must not perturb its former batch-mates (DESIGN.md §12)."""
    model, eng = _decode_engine(max_batch=2)
    rng = np.random.default_rng(5)
    prompts = {}
    for i in range(3):
        # prompt+budget all snap to one cache bucket -> one slot group
        toks = rng.integers(0, model.cfg.vocab_size, size=20 + i)
        prompts[eng.submit(toks, "c", arrival_s=0.0)] = toks
    rids = list(prompts)
    # two in flight, one queued; kill an in-flight request mid-decode
    # (one-token steps so the fused chunk cannot run anyone to budget)
    for _ in range(3):
        eng.step(max_decode_steps=1)
    assert eng.in_flight == 2
    dead = eng.cancel(rids[0])
    assert dead is not None and dead.cancelled
    assert dead.request_id == rids[0]
    assert len(dead.tokens) < eng.max_new_tokens
    assert eng.cancel(rids[0]) is None      # already retired
    survivors = {r.request_id: r for r in eng.drain()}
    assert set(survivors) == set(rids[1:])
    for rid, r in survivors.items():
        assert not r.cancelled
        ref = greedy_decode_reference(model, eng.class_params("c"),
                                      prompts[rid], len(r.tokens),
                                      b_kv=8,
                                      compile_cache=eng.compile_cache)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    # the cancelled prefix it did emit is also the reference's prefix
    if len(dead.tokens):
        ref = greedy_decode_reference(model, eng.class_params("c"),
                                      prompts[rids[0]], len(dead.tokens),
                                      b_kv=8,
                                      compile_cache=eng.compile_cache)
        np.testing.assert_array_equal(np.asarray(dead.tokens), ref)
    rep = eng.report()
    assert rep.cancelled == 1
    assert rep.requests_served == 2


def test_decode_cancel_queued_request_never_admits():
    model, eng = _decode_engine(max_batch=2)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), "c")
    dead = eng.cancel(rid)
    assert dead.cancelled and len(dead.tokens) == 0
    assert eng.pending == 0 and eng.in_flight == 0
    assert eng.drain() == []
    assert eng.report().cancelled == 1


def test_decode_step_on_empty_admission_queue():
    """step()/drain() on an idle engine is a no-op, not a crash — the
    serving loop may tick with nothing admitted."""
    _, eng = _decode_engine()
    assert eng.step() == []
    assert eng.drain() == []
    assert eng.pending == 0 and eng.in_flight == 0
    rep = eng.report()
    assert rep.requests_served == 0
    assert rep.decode_rounds == 0
    assert rep.total_delay_s == 0.0


def test_decode_crash_recovery_parity_matrix():
    """ServingSupervisor crash recovery (DESIGN.md §15): preempt the
    server at three phases of the run — during admission, mid-stream,
    near retirement — and in every case the supervisor must wait out
    the repair window, restore each snapshotted request, and deliver
    token streams bitwise identical to the uninterrupted reference
    (zero lost, zero duplicated)."""
    model, probe = _decode_engine(max_batch=2)
    cache = probe.compile_cache
    t_round = probe.decode_round_cost("c", 32)[0]

    rng = np.random.default_rng(11)
    streams = [(rng.integers(0, model.cfg.vocab_size,
                             size=int(rng.integers(6, 17))).astype(np.int32),
                int(rng.integers(3, 7)), 10.0 * t_round * i)
               for i in range(3)]

    def make_eng():
        eng = DecodeEngine(
            model, model.init(jax.random.PRNGKey(0)),
            SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11),
            classes=[QosClass("c", t0=3.0, e0=2.0)], auto=False,
            max_batch=2, max_new_tokens=6, compile_cache=cache)
        eng.set_operating_point("c", 8, 8)
        return eng

    # uninterrupted reference (quantized weights, like the engine)
    wq = probe.class_params("c")
    ref = {i: np.asarray(greedy_decode_reference(
        model, wq, toks, n_new, b_kv=8, compile_cache=cache))
        for i, (toks, n_new, _) in enumerate(streams)}

    # measure the uninterrupted virtual span to place crash windows
    eng0 = make_eng()
    for toks, n_new, t in streams:
        eng0.submit(toks, "c", max_new_tokens=n_new, arrival_s=t)
    eng0.drain()
    span = eng0.clock_s

    total_recoveries = 0
    for lo, hi in [(0.05, 0.25), (0.35, 0.60), (0.70, 0.95)]:
        chaos = ChaosTrace(dt_s=t_round, horizon_s=4.0 * span, seed=0,
                           preemption=ServerPreemption(mtbf_s=1e9,
                                                       mttr_s=1e9))
        # deterministic crash window, placed as a fraction of the span
        i0 = chaos.index_at(lo * span)
        i1 = max(i0 + 1, chaos.index_at(hi * span))
        chaos.server_up[:] = True
        chaos.server_up[i0:i1] = False
        assert not chaos.is_clean()

        eng = make_eng()
        sup = ServingSupervisor(eng, chaos=chaos, supervised=True, seed=3)
        rids = {}
        for i, (toks, n_new, t) in enumerate(streams):
            rids[sup.submit(toks, "c", max_new_tokens=n_new,
                            arrival_s=t)] = i
        out = {rids[r.request_id]: np.asarray(r.tokens)
               for r in sup.drain()}
        rep = sup.report()
        assert rep.delivered == len(streams) and rep.failed == 0, rep
        assert rep.tokens_lost == 0 and rep.tokens_duplicated == 0, rep
        assert out.keys() == ref.keys()
        for i in ref:
            np.testing.assert_array_equal(out[i], ref[i])
        total_recoveries += rep.recoveries
    # at least one window must have landed mid-flight and forced a
    # snapshot/restore (not just an idle wait)
    assert total_recoveries > 0


def test_decode_bare_engine_loses_work_under_same_crash():
    """The control arm: without the supervisor the same preemption
    strands the in-flight requests — the benchmark's goodput gap is a
    real difference, not an artifact of accounting."""
    model, eng = _decode_engine(max_batch=2)
    t_round = eng.decode_round_cost("c", 32)[0]
    rng = np.random.default_rng(11)
    chaos = ChaosTrace(dt_s=t_round, horizon_s=5000.0 * t_round, seed=0,
                       preemption=ServerPreemption(mtbf_s=1e9, mttr_s=1e9))
    chaos.server_up[:] = True
    chaos.server_up[2:] = False            # crash almost immediately
    sup = ServingSupervisor(eng, chaos=chaos, supervised=False, seed=3)
    for i in range(3):
        toks = rng.integers(0, model.cfg.vocab_size, size=8 + i)
        sup.submit(toks, "c", max_new_tokens=5, arrival_s=0.0)
    sup.drain()
    rep = sup.report()
    assert rep.failed > 0
    assert rep.tokens_lost > 0


def test_speculative_crash_recovery_parity():
    """ServingSupervisor around the speculative engine (DESIGN.md §15,
    §16): preempt the server mid-run at three phases — the supervisor
    must snapshot mid-ROUND (between a draft block and its next verify
    there is nothing to save: rounds are atomic host transactions),
    resume, and deliver bitwise the uninterrupted reference with zero
    tokens lost and zero duplicated.  Zero duplicates is the
    no-double-billing claim: work a round drafted but the verify
    rejected — or a crash discarded — never re-enters a delivered
    stream."""
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)

    def make_eng(cache=None):
        eng = SpeculativeDecodeEngine(
            model, params, sysp, classes=[QosClass("c", t0=3.0, e0=2.0)],
            auto=False, max_batch=2, max_new_tokens=6,
            draft_bits=4, lookahead=3, compile_cache=cache)
        eng.set_operating_point("c", 8, 8)
        return eng

    probe = make_eng()
    cache = probe.compile_cache
    t_round = probe.decode_round_cost("c", 32)[0]
    rng = np.random.default_rng(11)
    streams = [(rng.integers(0, cfg.vocab_size,
                             size=int(rng.integers(6, 17))).astype(np.int32),
                int(rng.integers(3, 7)), 10.0 * t_round * i)
               for i in range(3)]
    wq = probe.class_params("c")
    ref = {i: np.asarray(greedy_decode_reference(
        model, wq, toks, n_new, b_kv=8, compile_cache=cache))
        for i, (toks, n_new, _) in enumerate(streams)}

    eng0 = make_eng(cache)
    for toks, n_new, t in streams:
        eng0.submit(toks, "c", max_new_tokens=n_new, arrival_s=t)
    eng0.drain()
    span = eng0.clock_s

    total_recoveries = 0
    for lo, hi in [(0.05, 0.25), (0.35, 0.60), (0.70, 0.95)]:
        chaos = ChaosTrace(dt_s=t_round, horizon_s=4.0 * span, seed=0,
                           preemption=ServerPreemption(mtbf_s=1e9,
                                                       mttr_s=1e9))
        i0 = chaos.index_at(lo * span)
        i1 = max(i0 + 1, chaos.index_at(hi * span))
        chaos.server_up[:] = True
        chaos.server_up[i0:i1] = False
        assert not chaos.is_clean()

        eng = make_eng(cache)
        sup = ServingSupervisor(eng, chaos=chaos, supervised=True,
                                seed=3)
        rids = {}
        for i, (toks, n_new, t) in enumerate(streams):
            rids[sup.submit(toks, "c", max_new_tokens=n_new,
                            arrival_s=t)] = i
        out = {rids[r.request_id]: np.asarray(r.tokens)
               for r in sup.drain()}
        rep = sup.report()
        assert rep.delivered == len(streams) and rep.failed == 0, rep
        assert rep.tokens_lost == 0 and rep.tokens_duplicated == 0, rep
        assert out.keys() == ref.keys()
        for i in ref:
            np.testing.assert_array_equal(out[i], ref[i])
        # delivered accounting stays consistent across the restore:
        # every non-prefill token came out of exactly one spec round
        erep = eng.report()
        assert eng.spec_stats().delivered \
            == erep.tokens_generated - erep.prefills
        total_recoveries += rep.recoveries
    assert total_recoveries > 0


def test_checkpoint_content_corruption_detected():
    """A tampered payload whose *manifest blob sha was rewritten to
    match* still fails the content checksum (``sha256_raw``), raising
    CorruptCheckpointError — and restore_latest falls back."""
    with tempfile.TemporaryDirectory() as d:
        import jax.numpy as jnp
        tree = {"a": jnp.arange(8.0)}
        save_tree(tree, d, 10, compress=False)
        save_tree({"a": jnp.arange(8.0) * 3}, d, 20, compress=False)
        step_dir = os.path.join(d, "step_20")
        blob_path = os.path.join(step_dir, "tree.msgpack.zst")
        with open(blob_path, "rb") as f:
            blob = bytearray(f.read())
        blob[len(blob) // 2] ^= 0xFF       # flip one payload bit pattern
        with open(blob_path, "wb") as f:
            f.write(bytes(blob))
        mpath = os.path.join(step_dir, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["sha256"] = hashlib.sha256(bytes(blob)).hexdigest()
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(CorruptCheckpointError, match="content sha"):
            load_tree(d, 20, tree)
        restored, man = CheckpointManager(d).restore_latest(tree)
        assert man["step"] == 10
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(8.0))


def test_corrupt_checkpoint_falls_back():
    """Manifest sha mismatch on the newest checkpoint -> previous one."""
    with tempfile.TemporaryDirectory() as d:
        import jax.numpy as jnp
        tree = {"a": jnp.arange(4.0)}
        save_tree(tree, d, 10)
        save_tree({"a": jnp.arange(4.0) * 2}, d, 20)
        # corrupt step 20's payload
        with open(os.path.join(d, "step_20", "tree.msgpack.zst"), "ab") as f:
            f.write(b"garbage")
        mgr = CheckpointManager(d)
        restored, manifest = mgr.restore_latest(tree)
        assert manifest["step"] == 10
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(4.0))
