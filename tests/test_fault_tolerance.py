"""Fault-tolerance: checkpoint/restart supervisor, stragglers, corruption,
and the decode engine's mid-flight retirement paths."""

import os
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, available_steps, save_tree
from repro.configs import get_smoke
from repro.core.cost_model import SystemParams
from repro.data import MarkovLMConfig, MarkovLMDataset, ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.optim import AdamW
from repro.runtime import (DecodeEngine, HostFailure, HostSet, QosClass,
                           StragglerMonitor, Supervisor, TrainConfig,
                           Trainer, greedy_decode_reference)


class _Session:
    """A restartable training session for the Supervisor tests."""

    def __init__(self, ckpt_dir: str, n_hosts: int):
        cfg = get_smoke("qwen2-0.5b")
        self.model = build_model(cfg)
        self.tr = Trainer(self.model, AdamW(learning_rate=1e-3),
                          make_host_mesh(), TrainConfig(log_every=100),
                          ckpt=CheckpointManager(ckpt_dir, save_interval=5))
        self.loader = ShardedLoader(MarkovLMDataset(MarkovLMConfig(
            vocab_size=cfg.vocab_size, seq_len=16, batch_size=4)))
        self.n_hosts = n_hosts
        self.state = None
        self.losses = []

    @property
    def step(self):
        return self.tr.step

    def run_until(self, target: int, hosts):
        params, opt, err = self.tr.init_state(jax.random.PRNGKey(0))
        params, opt, err, start = self.tr.maybe_restore(params, opt, err)
        self.loader.seek(start)
        self.tr.build_step(self.loader.peek_structure())
        state = (params, opt, err)
        while self.tr.step < target:
            hosts.check(self.tr.step)       # may raise HostFailure
            state, hist = self.tr.fit(self.loader, 1, state=state)
            self.losses.extend(h["loss"] for h in hist)
            if self.tr.ckpt.should_save(self.tr.step):
                self.tr.ckpt.save(self.tr.step,
                                  {"params": state[0], "opt": state[1],
                                   "err": state[2]},
                                  metadata={"data_step": self.tr.step})


def test_supervisor_survives_host_failures():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=4, fail_at={7: 3, 13: 2})
        sup = Supervisor(lambda n: _Session(d, n), hosts)
        report = sup.run(target_steps=20)
        assert report.final_step >= 20
        assert report.restarts == 2
        assert report.failures == [3, 2]
        assert hosts.n_alive == 2
        assert report.remesh_history == [4, 3, 2]


def test_supervisor_restart_budget():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=4, fail_at={1: 0, 2: 1, 3: 2})
        sup = Supervisor(lambda n: _Session(d, n), hosts, max_restarts=1)
        with pytest.raises(RuntimeError, match="restart budget"):
            sup.run(target_steps=20)


def test_supervisor_resumes_from_checkpoint_not_zero():
    with tempfile.TemporaryDirectory() as d:
        hosts = HostSet(n_hosts=2, fail_at={8: 1})
        sessions = []

        def make(n):
            s = _Session(d, n)
            sessions.append(s)
            return s

        Supervisor(make, hosts).run(target_steps=12)
        # second session must have started from the step-5 checkpoint
        assert len(sessions) == 2
        assert sessions[1].step == 12
        assert available_steps(d)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(factor=3.0)
    rng = np.random.default_rng(0)
    for step in range(30):
        for host in range(8):
            base = 1.0 + 0.05 * rng.standard_normal()
            mon.report(host, base * (10.0 if host == 5 else 1.0))
    assert mon.stragglers() == [5]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(factor=3.0)
    for step in range(20):
        for host in range(4):
            mon.report(host, 1.0 + 0.01 * host)
    assert mon.stragglers() == []


def _decode_engine(max_batch=2, max_new=6):
    cfg = get_smoke("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = SystemParams(n_flop_agent=6.4e10, n_flop_server=1.92e11)
    eng = DecodeEngine(model, params, sysp,
                       classes=[QosClass("c", t0=3.0, e0=2.0)],
                       auto=False, max_batch=max_batch,
                       max_new_tokens=max_new)
    eng.set_operating_point("c", 8, 8)
    return model, eng


def test_decode_request_retired_mid_decode():
    """cancel() mid-flight frees the slot for the queue, and the
    survivors still decode bitwise what they would have alone — a dead
    request must not perturb its former batch-mates (DESIGN.md §12)."""
    model, eng = _decode_engine(max_batch=2)
    rng = np.random.default_rng(5)
    prompts = {}
    for i in range(3):
        # prompt+budget all snap to one cache bucket -> one slot group
        toks = rng.integers(0, model.cfg.vocab_size, size=20 + i)
        prompts[eng.submit(toks, "c", arrival_s=0.0)] = toks
    rids = list(prompts)
    # two in flight, one queued; kill an in-flight request mid-decode
    # (one-token steps so the fused chunk cannot run anyone to budget)
    for _ in range(3):
        eng.step(max_decode_steps=1)
    assert eng.in_flight == 2
    dead = eng.cancel(rids[0])
    assert dead is not None and dead.cancelled
    assert dead.request_id == rids[0]
    assert len(dead.tokens) < eng.max_new_tokens
    assert eng.cancel(rids[0]) is None      # already retired
    survivors = {r.request_id: r for r in eng.drain()}
    assert set(survivors) == set(rids[1:])
    for rid, r in survivors.items():
        assert not r.cancelled
        ref = greedy_decode_reference(model, eng.class_params("c"),
                                      prompts[rid], len(r.tokens),
                                      b_kv=8,
                                      compile_cache=eng.compile_cache)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    # the cancelled prefix it did emit is also the reference's prefix
    if len(dead.tokens):
        ref = greedy_decode_reference(model, eng.class_params("c"),
                                      prompts[rids[0]], len(dead.tokens),
                                      b_kv=8,
                                      compile_cache=eng.compile_cache)
        np.testing.assert_array_equal(np.asarray(dead.tokens), ref)
    rep = eng.report()
    assert rep.cancelled == 1
    assert rep.requests_served == 2


def test_decode_cancel_queued_request_never_admits():
    model, eng = _decode_engine(max_batch=2)
    rid = eng.submit(np.arange(1, 9, dtype=np.int32), "c")
    dead = eng.cancel(rid)
    assert dead.cancelled and len(dead.tokens) == 0
    assert eng.pending == 0 and eng.in_flight == 0
    assert eng.drain() == []
    assert eng.report().cancelled == 1


def test_decode_step_on_empty_admission_queue():
    """step()/drain() on an idle engine is a no-op, not a crash — the
    serving loop may tick with nothing admitted."""
    _, eng = _decode_engine()
    assert eng.step() == []
    assert eng.drain() == []
    assert eng.pending == 0 and eng.in_flight == 0
    rep = eng.report()
    assert rep.requests_served == 0
    assert rep.decode_rounds == 0
    assert rep.total_delay_s == 0.0


def test_corrupt_checkpoint_falls_back():
    """Manifest sha mismatch on the newest checkpoint -> previous one."""
    with tempfile.TemporaryDirectory() as d:
        import jax.numpy as jnp
        tree = {"a": jnp.arange(4.0)}
        save_tree(tree, d, 10)
        save_tree({"a": jnp.arange(4.0) * 2}, d, 20)
        # corrupt step 20's payload
        with open(os.path.join(d, "step_20", "tree.msgpack.zst"), "ab") as f:
            f.write(b"garbage")
        mgr = CheckpointManager(d)
        restored, manifest = mgr.restore_latest(tree)
        assert manifest["step"] == 10
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(4.0))
